"""AOT bridge: lower the L2 model (with its L1 Pallas kernel) to HLO TEXT
artifacts the Rust runtime loads via `HloModuleProto::from_text_file`.

HLO *text*, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (what the published `xla`
0.1.6 crate links) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Emits one artifact per (bm, bn, bk) tile variant of the GMM kernel — the
grid of *schedule points* the Rust search measures for real — plus the
fused-dense model, plus `manifest.json` with the VMEM-footprint and
MXU-utilization estimates per variant (real-TPU perf is estimated, not
measured: interpret-mode Pallas runs CPU numerics only).

Usage: python -m compile.aot --outdir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import matmul as kernels

# GMM workload shape (Appendix A.2).
GMM_M = GMM_N = GMM_K = 128
# fused-dense (Figure 10a): 128 x 768 -> 3072, tiled at the kernel default.
FD_M, FD_N, FD_K = 128, 3072, 768

# Tile-variant grid: the schedule points realized as real executables.
TILE_BMN = [16, 32, 64, 128]
TILE_BK = [16, 32, 64, 128]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gmm(bm: int, bn: int, bk: int) -> str:
    spec = jax.ShapeDtypeStruct((GMM_M, GMM_K), jnp.float32)
    spec2 = jax.ShapeDtypeStruct((GMM_K, GMM_N), jnp.float32)
    return to_hlo_text(model.gmm.lower(spec, spec2, bm=bm, bn=bn, bk=bk))


def lower_fused_dense(bm=32, bn=64, bk=32) -> str:
    x = jax.ShapeDtypeStruct((FD_M, FD_K), jnp.float32)
    w = jax.ShapeDtypeStruct((FD_N, FD_K), jnp.float32)
    b = jax.ShapeDtypeStruct((FD_N,), jnp.float32)
    return to_hlo_text(model.fused_dense.lower(x, w, b, bm=bm, bn=bn, bk=bk))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="only one variant")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    variants = []
    grid = (
        [(32, 32, 32)]
        if args.quick
        else [(bm, bm_n, bk) for bm in TILE_BMN for bm_n in [bm] for bk in TILE_BK]
    )
    # Square (bm = bn) x bk grid: 16 variants.
    for bm, bn, bk in grid:
        name = f"gmm_bm{bm}_bn{bn}_bk{bk}"
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        text = lower_gmm(bm, bn, bk)
        with open(path, "w") as f:
            f.write(text)
        est = kernels.variant_estimate(bm, bn, bk)
        est["artifact"] = f"{name}.hlo.txt"
        est["m"], est["n"], est["k"] = GMM_M, GMM_N, GMM_K
        variants.append(est)
        print(f"wrote {path} ({len(text)} chars, "
              f"vmem={est['vmem_bytes']}B mxu={est['mxu_utilization']})")

    fd_path = os.path.join(args.outdir, "fused_dense.hlo.txt")
    with open(fd_path, "w") as f:
        f.write(lower_fused_dense())
    print(f"wrote {fd_path}")

    manifest = {
        "gmm": {"m": GMM_M, "n": GMM_N, "k": GMM_K, "variants": variants},
        "fused_dense": {
            "m": FD_M,
            "n": FD_N,
            "k": FD_K,
            "artifact": "fused_dense.hlo.txt",
        },
    }
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(variants)} gmm variants")


if __name__ == "__main__":
    main()
