"""Layer-2 JAX model functions (build-time only, never on the Rust hot
path). Each function is jit-lowerable to HLO text by aot.py and calls the
Layer-1 Pallas kernels so the kernel lowers into the same HLO module.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import matmul as kernels


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gmm(x, y, *, bm=32, bn=32, bk=32):
    """The GMM workload (A.2: m=n=k=128) on the Pallas kernel. Returned as
    a 1-tuple because the AOT bridge lowers with return_tuple=True."""
    return (kernels.matmul(x, y, bm=bm, bn=bn, bk=bk),)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def fused_dense(x, w, b, *, bm=32, bn=32, bk=32):
    """The fused-dense BERT subgraph of Figure 10a: dense + bias + ReLU.
    The matmul hot-spot runs on the Pallas kernel; the elementwise epilogue
    stays in jnp and XLA fuses it — the same producer/consumer fusion the
    Rust-side `compute_at`/`compute_inline` express in TIR."""
    y = kernels.matmul(x, w.T, bm=bm, bn=bn, bk=bk)
    return (jnp.maximum(y + b, 0.0),)
