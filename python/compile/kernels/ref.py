"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package must match its oracle to numerical tolerance
under pytest + hypothesis sweeps (python/tests/test_kernel.py).
"""

import jax.numpy as jnp


def matmul(x, y):
    """Reference for kernels.matmul: plain jnp matmul in f32 accumulate."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def fused_dense(x, w, b):
    """Reference for the fused-dense subgraph: relu(x @ w^T + b)."""
    return jnp.maximum(jnp.dot(x, w.T) + b, 0.0)
