"""Layer-1 Pallas kernel: tiled matmul.

This is the *realization* of a MetaSchedule schedule point: the (bm, bn,
bk) block sizes are exactly the innermost tile extents that
`sample_perfect_tile` draws on the Rust side, and the BlockSpec grid is
the HBM<->VMEM schedule that `cache_read`/`compute_at` express in TIR
(DESIGN.md §Hardware-Adaptation: CUDA threadblock tiling -> Pallas
BlockSpec grid; shared memory -> VMEM).

Kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so correctness is validated through the interpret
path and real-TPU performance is *estimated* from the VMEM footprint and
MXU utilization numbers computed here (recorded in artifacts/manifest and
DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU hardware constants used by the estimates.
VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM
MXU_DIM = 128                  # 128x128 systolic array


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; the k grid axis accumulates in-place."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=32, bn=32, bk=32):
    """Tiled matmul ``x @ y`` with a (m/bm, n/bn, k/bk) Pallas grid."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"tiles ({bm},{bn},{bk}) must divide ({m},{n},{k})"
    )
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU path; real TPU would lower to Mosaic
    )(x, y)


def vmem_footprint_bytes(bm, bn, bk, dtype_bytes=4):
    """Resident VMEM per grid step: one x tile + one y tile + the
    accumulating output tile (double-buffered inputs would be 2x the input
    terms; we report the single-buffered lower bound)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization(bm, bn, bk):
    """Fraction of the 128x128 MXU the (bm, bn, bk) tile keeps busy:
    each dimension pads up to the systolic array edge."""
    def frac(d):
        pad = -d % MXU_DIM
        return d / (d + pad) if d < MXU_DIM else 1.0

    return frac(bm) * frac(bn) * frac(bk)


def variant_estimate(bm, bn, bk, dtype_bytes=4):
    """The perf-estimate record stored in the artifact manifest."""
    vmem = vmem_footprint_bytes(bm, bn, bk, dtype_bytes)
    return {
        "bm": bm,
        "bn": bn,
        "bk": bk,
        "vmem_bytes": vmem,
        "vmem_fits": vmem <= VMEM_BYTES,
        "mxu_utilization": round(mxu_utilization(bm, bn, bk), 4),
    }
