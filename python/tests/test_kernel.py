"""Kernel correctness: the Pallas matmul vs its pure-jnp oracle, swept over
shapes / tiles / dtypes with hypothesis, plus the L2 model functions and
the AOT perf-estimate helpers.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import matmul as kernels
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def rand(shape, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, dtype=dtype)


class TestMatmulKernel:
    def test_basic_128(self):
        x, y = rand((128, 128), seed=1), rand((128, 128), seed=2)
        out = kernels.matmul(x, y, bm=32, bn=32, bk=32)
        np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("bm", [16, 32, 64, 128])
    @pytest.mark.parametrize("bk", [16, 64, 128])
    def test_gmm_variant_grid(self, bm, bk):
        """Every tile variant shipped as an AOT artifact must be correct."""
        x, y = rand((128, 128), seed=3), rand((128, 128), seed=4)
        out = kernels.matmul(x, y, bm=bm, bn=bm, bk=bk)
        np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-5, atol=1e-5)

    @hypothesis.given(
        mi=st.integers(1, 4),
        ni=st.integers(1, 4),
        ki=st.integers(1, 4),
        bm=st.sampled_from([8, 16, 32]),
        bn=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_tile_sweep(self, mi, ni, ki, bm, bn, bk, seed):
        """Property: for every (m, n, k) divisible by the tiles, kernel ==
        oracle."""
        m, n, k = mi * bm, ni * bn, ki * bk
        x, y = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
        out = kernels.matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-4, atol=1e-4)

    def test_bf16_inputs_f32_accumulate(self):
        x = rand((64, 64)).astype(jnp.bfloat16)
        y = rand((64, 64), seed=9).astype(jnp.bfloat16)
        out = kernels.matmul(x, y, bm=16, bn=16, bk=16)
        expect = ref.matmul(x, y)
        # Per-tile bf16 accumulation rounds differently from the oracle's
        # single dot; tolerance sized for bf16's ~2^-8 mantissa over k=64.
        np.testing.assert_allclose(
            out.astype(jnp.float32), expect.astype(jnp.float32), rtol=5e-2, atol=2.5e-1
        )

    def test_non_dividing_tiles_rejected(self):
        x, y = rand((100, 100)), rand((100, 100))
        with pytest.raises(AssertionError):
            kernels.matmul(x, y, bm=32, bn=32, bk=32)

    def test_contraction_mismatch_rejected(self):
        with pytest.raises(AssertionError):
            kernels.matmul(rand((32, 32)), rand((64, 32)))


class TestModel:
    def test_gmm_model_wraps_kernel(self):
        x, y = rand((128, 128), seed=5), rand((128, 128), seed=6)
        (out,) = model.gmm(x, y)
        np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-5, atol=1e-5)

    def test_fused_dense_matches_reference(self):
        x = rand((128, 768), seed=7)
        w = rand((3072, 768), seed=8) * 0.02
        b = rand((3072,), seed=9)
        (out,) = model.fused_dense(x, w, b)
        np.testing.assert_allclose(
            out, ref.fused_dense(x, w, b), rtol=1e-4, atol=1e-4
        )
        assert (np.asarray(out) >= 0.0).all(), "ReLU output must be nonneg"

    @hypothesis.given(seed=st.integers(0, 2**16))
    @hypothesis.settings(max_examples=5, deadline=None)
    def test_fused_dense_small_sweep(self, seed):
        x = rand((32, 64), seed=seed)
        w = rand((64, 64), seed=seed + 1) * 0.05
        b = rand((64,), seed=seed + 2)
        (out,) = model.fused_dense(x, w, b, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(
            out, ref.fused_dense(x, w, b), rtol=1e-4, atol=1e-4
        )


class TestPerfEstimates:
    def test_vmem_footprint_formula(self):
        # (32*32 + 32*32 + 32*32) * 4B = 12 KiB
        assert kernels.vmem_footprint_bytes(32, 32, 32) == 3 * 32 * 32 * 4

    def test_all_grid_variants_fit_vmem(self):
        for bm in [16, 32, 64, 128]:
            for bk in [16, 32, 64, 128]:
                est = kernels.variant_estimate(bm, bm, bk)
                assert est["vmem_fits"], est

    def test_mxu_utilization_monotone(self):
        # Bigger tiles toward 128 use the systolic array better.
        u16 = kernels.mxu_utilization(16, 16, 16)
        u64 = kernels.mxu_utilization(64, 64, 64)
        u128 = kernels.mxu_utilization(128, 128, 128)
        assert u16 < u64 < u128 == 1.0

    def test_aot_lowering_produces_hlo_text(self):
        from compile import aot

        text = aot.lower_gmm(32, 32, 32)
        assert "HloModule" in text
        assert "f32[128,128]" in text
