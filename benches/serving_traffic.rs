//! Traffic replay against a *sharded* tuning database: ≥1M lookups at
//! mixed hit/miss ratios through the same per-shard snapshot path the
//! HTTP front serves from ([`metaschedule::serve::ShardedSnapshots`]),
//! with per-operation latency percentiles (p50/p99) split by hit vs
//! miss, written to `BENCH_serving.json` for CI artifact upload. Also
//! gates telemetry cost: the instrumented lookup path (one cached
//! relaxed-atomic counter increment per op) must stay within 5% of the
//! bare path, and the measured `overhead_pct` lands in the JSON.
//!
//! ```sh
//! cargo bench --bench serving_traffic             # full run (1.2M lookups)
//! cargo bench --bench serving_traffic -- --smoke  # CI: tiny replay, same shape
//! ```
//!
//! The replay measures the read path only — a "miss" here is a snapshot
//! probe that answers `None` (the server would then consult admission
//! control and possibly tune); tune-on-miss cost is a search benchmark,
//! not a serving one, and would drown the lookup numbers.

use std::collections::HashSet;
use std::time::Instant;

use metaschedule::db::{AnyDb, Database, ShardedDb, TuningRecord};
use metaschedule::serve::ShardedSnapshots;
use metaschedule::trace::{Inst, Trace};
use metaschedule::util::json::Json;
use metaschedule::util::rng::Rng;

/// Scratch directory holding the sharded db, removed on drop so repeat
/// runs start clean even after a panic.
struct DirGuard(std::path::PathBuf);
impl Drop for DirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Build a sharded on-disk db: `workloads` workloads x `records` records,
/// batch-committed the way the group-commit writer would.
fn synthetic_sharded_db(
    dir: &std::path::Path,
    shards: usize,
    workloads: usize,
    records: usize,
) -> (ShardedDb, Vec<(u64, &'static str)>) {
    let mut db = ShardedDb::create(dir, shards).expect("create sharded db");
    let mut rng = Rng::seed_from_u64(7);
    let mut keys = Vec::with_capacity(workloads);
    let mut batch = Vec::with_capacity(workloads * records);
    for w in 0..workloads {
        let shash = rng.next_u64();
        let target = if w % 2 == 0 { "cpu" } else { "gpu" };
        let wid = db.register_workload(&format!("w{w}"), shash, target);
        keys.push((shash, target));
        for r in 0..records {
            let lat = if r % 7 == 6 { None } else { Some((1.0 + rng.gen_f64()) * 1e-5) };
            batch.push(TuningRecord {
                workload: wid,
                trace: Trace {
                    insts: vec![Inst::GetBlock { name: format!("blk{w}"), out: 0 }],
                },
                latencies: lat.into_iter().collect(),
                target: target.to_string(),
                seed: 1,
                round: r as u64,
                cand_hash: rng.next_u64(),
                sim_version: "simtest".into(),
                rule_set: String::new(),
                objective: String::new(),
            });
        }
    }
    db.commit_batch(batch);
    (db, keys)
}

/// Nearest-rank percentile over a sorted sample.
fn pct(sorted_ns: &[u64], p: f64) -> f64 {
    if sorted_ns.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted_ns.len() - 1) as f64 * p).round() as usize;
    sorted_ns[idx] as f64
}

struct MixResult {
    name: String,
    hit_ratio: f64,
    lookups: usize,
    hits: usize,
    hit_p50: f64,
    hit_p99: f64,
    miss_p50: f64,
    miss_p99: f64,
    mops: f64,
}

/// Measure the cost the instrumented serving path adds per operation:
/// the same pre-generated lookup stream replayed bare ("metrics off")
/// and with the cached-`Arc<Counter>` increment the server pays per
/// request ("metrics on" — registry lookups happen at startup, the hot
/// path is one relaxed atomic add). Best-of-`reps` wall time per
/// variant so scheduler noise cannot fail the overhead gate spuriously.
/// Returns (off_ns_per_op, on_ns_per_op, overhead_pct).
fn telemetry_overhead(
    snaps: &ShardedSnapshots,
    keys: &[(u64, &'static str)],
    known: &HashSet<u64>,
    lookups: usize,
    reps: usize,
) -> (f64, f64, f64) {
    let mut rng = Rng::seed_from_u64(4242);
    let mut reqs: Vec<(u64, &'static str)> = Vec::with_capacity(lookups);
    for _ in 0..lookups {
        if rng.gen_f64() < 0.90 {
            let (shash, target) = keys[(rng.next_u64() as usize) % keys.len()];
            reqs.push((shash, target));
        } else {
            let mut shash = rng.next_u64();
            while known.contains(&shash) {
                shash = rng.next_u64();
            }
            reqs.push((shash, "cpu"));
        }
    }
    let counter = metaschedule::telemetry::global()
        .counter("bench_serving_lookups_total", "lookups replayed by the overhead bench");
    let mut best_off = u64::MAX;
    let mut best_on = u64::MAX;
    let mut hits_off = 0usize;
    let mut hits_on = 0usize;
    for _ in 0..reps {
        // Bare replay: identical loop body minus the counter increment.
        let t = Instant::now();
        let mut hits = 0usize;
        for &(shash, target) in &reqs {
            if snaps.get(shash).lookup(shash, target).is_some() {
                hits += 1;
            }
        }
        best_off = best_off.min(t.elapsed().as_nanos() as u64);
        hits_off = hits;

        // Instrumented replay.
        let t = Instant::now();
        let mut hits = 0usize;
        for &(shash, target) in &reqs {
            counter.inc();
            if snaps.get(shash).lookup(shash, target).is_some() {
                hits += 1;
            }
        }
        best_on = best_on.min(t.elapsed().as_nanos() as u64);
        hits_on = hits;
    }
    assert_eq!(hits_off, hits_on, "variants must do identical work");
    let off = best_off as f64 / lookups as f64;
    let on = best_on as f64 / lookups as f64;
    let overhead_pct = ((on - off) / off * 100.0).max(0.0);
    (off, on, overhead_pct)
}

/// Replay `lookups` requests at `hit_ratio` against the per-shard
/// snapshots, timing every operation individually.
fn replay(
    name: &str,
    snaps: &ShardedSnapshots,
    keys: &[(u64, &'static str)],
    known: &HashSet<u64>,
    hit_ratio: f64,
    lookups: usize,
    seed: u64,
) -> MixResult {
    let mut rng = Rng::seed_from_u64(seed);
    // Pre-generate the request stream so rng cost stays out of the
    // timed region.
    let mut reqs: Vec<(u64, &'static str, bool)> = Vec::with_capacity(lookups);
    for _ in 0..lookups {
        if rng.gen_f64() < hit_ratio {
            let (shash, target) = keys[(rng.next_u64() as usize) % keys.len()];
            reqs.push((shash, target, true));
        } else {
            // A shash outside the registered set: guaranteed miss.
            let mut shash = rng.next_u64();
            while known.contains(&shash) {
                shash = rng.next_u64();
            }
            reqs.push((shash, "cpu", false));
        }
    }
    let mut hit_ns: Vec<u64> = Vec::with_capacity(lookups);
    let mut miss_ns: Vec<u64> = Vec::with_capacity(lookups);
    let wall = Instant::now();
    for &(shash, target, expect_hit) in &reqs {
        let t = Instant::now();
        let found = snaps.get(shash).lookup(shash, target).is_some();
        let ns = t.elapsed().as_nanos() as u64;
        assert_eq!(found, expect_hit, "snapshot disagreed with the request plan");
        if found {
            hit_ns.push(ns);
        } else {
            miss_ns.push(ns);
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();
    hit_ns.sort_unstable();
    miss_ns.sort_unstable();
    MixResult {
        name: name.into(),
        hit_ratio,
        lookups,
        hits: hit_ns.len(),
        hit_p50: pct(&hit_ns, 0.50),
        hit_p99: pct(&hit_ns, 0.99),
        miss_p50: pct(&miss_ns, 0.50),
        miss_p99: pct(&miss_ns, 0.99),
        mops: lookups as f64 / wall_s / 1e6,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (workloads, records, per_mix) = if smoke { (16, 8, 5_000) } else { (256, 32, 600_000) };
    const SHARDS: usize = 8;

    let dir = std::env::temp_dir().join(format!("ms-bench-serving-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let guard = DirGuard(dir.clone());
    let (db, keys) = synthetic_sharded_db(&dir, SHARDS, workloads, records);
    let known: HashSet<u64> = keys.iter().map(|&(h, _)| h).collect();

    // Serve through the same reopened handle the server would use, so
    // the replay covers the on-disk round trip, not just in-memory state.
    drop(db);
    let db = AnyDb::open(&dir).expect("reopen sharded db");
    let snaps = ShardedSnapshots::build(&db, 8);
    println!(
        "serving traffic replay: {} workloads x {} records across {} shard(s), {} indexed{}",
        workloads,
        records,
        db.num_shards(),
        snaps.num_records(),
        if smoke { " [smoke mode]" } else { "" }
    );

    let mixes = [("hit90", 0.90), ("hit50", 0.50)];
    let mut results = Vec::new();
    for (i, &(name, ratio)) in mixes.iter().enumerate() {
        results.push(replay(name, &snaps, &keys, &known, ratio, per_mix, 1000 + i as u64));
    }
    let total: usize = results.iter().map(|r| r.lookups).sum();
    if !smoke {
        assert!(total >= 1_000_000, "full replay must cover >=1M lookups, got {total}");
    }

    // Telemetry overhead gate: the instrumented hot path must stay
    // within 5% of the bare one. The op count is fixed (not scaled by
    // --smoke) so the CI smoke run measures the same thing as full runs.
    let (off_ns, on_ns, overhead_pct) = telemetry_overhead(&snaps, &keys, &known, 200_000, 5);
    println!(
        "telemetry overhead: {off_ns:.1} ns/op off, {on_ns:.1} ns/op on ({overhead_pct:.2}% overhead)"
    );
    assert!(
        overhead_pct <= 5.0,
        "instrumented serving path exceeds the 5% overhead budget: {overhead_pct:.2}%"
    );

    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            format!("{} ({:.0}% hit)", r.name, r.hit_ratio * 100.0),
            format!("{}", r.lookups),
            format!("{:.0} / {:.0}", r.hit_p50, r.hit_p99),
            format!("{:.0} / {:.0}", r.miss_p50, r.miss_p99),
            format!("{:.1}M/s", r.mops),
        ]);
    }
    metaschedule::util::bench::print_table(
        "sharded serving traffic replay (per-op ns)",
        &["mix", "lookups", "hit p50/p99", "miss p50/p99", "throughput"],
        &rows,
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serving_traffic")),
        ("smoke", Json::Bool(smoke)),
        ("shards", Json::num(SHARDS as f64)),
        ("workloads", Json::num(workloads as f64)),
        ("records_per_workload", Json::num(records as f64)),
        ("total_lookups", Json::num(total as f64)),
        (
            "telemetry_overhead",
            Json::obj(vec![
                ("off_ns_per_op", Json::num(off_ns)),
                ("on_ns_per_op", Json::num(on_ns)),
                ("overhead_pct", Json::num(overhead_pct)),
            ]),
        ),
        (
            "mixes",
            Json::arr(results.iter().map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("hit_ratio", Json::num(r.hit_ratio)),
                    ("lookups", Json::num(r.lookups as f64)),
                    ("hits", Json::num(r.hits as f64)),
                    ("misses", Json::num((r.lookups - r.hits) as f64)),
                    (
                        "hit_ns",
                        Json::obj(vec![
                            ("p50", Json::num(r.hit_p50)),
                            ("p99", Json::num(r.hit_p99)),
                        ]),
                    ),
                    (
                        "miss_ns",
                        Json::obj(vec![
                            ("p50", Json::num(r.miss_p50)),
                            ("p99", Json::num(r.miss_p99)),
                        ]),
                    ),
                    ("throughput_mops", Json::num(r.mops)),
                ])
            })),
        ),
    ]);
    let out = "BENCH_serving.json";
    std::fs::write(out, format!("{}\n", json.to_string())).expect("write BENCH_serving.json");
    println!("wrote {out}");
    drop(guard);
}
