//! Figure 9 reproduction: end-to-end deep learning models.
//! {BERT-base, ResNet-50, MobileNet-v2} x {PyTorch, TVM, MetaSchedule,
//! MetaSchedule-fused}, CPU and GPU.
//!
//! ```sh
//! cargo bench --bench fig9_e2e -- --trials 32
//! cargo bench --bench fig9_e2e -- --fused-smoke [--model bert-base] [--trials 8]
//! ```
//!
//! `--fused-smoke` is the CI arm: it tunes one model's per-op and
//! graph-fused task sets under the SAME total trial budget (per-op gets
//! `trials` per task; the fused arm's fewer tasks split the identical
//! total), asserts the fused end-to-end latency is no worse, and writes
//! the comparison to `BENCH_e2e.json`.

use metaschedule::exp::{fig9, ExpConfig};
use metaschedule::graph;
use metaschedule::sim::Target;
use metaschedule::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 64),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    if args.has_switch("fused-smoke") {
        fused_smoke(&args, &cfg);
        return;
    }
    for target in [Target::cpu_avx512(), Target::gpu()] {
        let report = fig9::run(&target, &cfg, None);
        report.print();
        let _ = report.write("bench_results.jsonl");
    }
    println!("(rows appended to bench_results.jsonl)");
}

fn fused_smoke(args: &Args, cfg: &ExpConfig) {
    let model = args.flag_or("model", "bert-base");
    let target = Target::cpu_avx512();
    let g = graph::graph_by_name(&model).unwrap_or_else(|| {
        eprintln!("fused-smoke: unknown model {model}");
        std::process::exit(2);
    });
    let per_op_tasks = graph::extract_tasks(&g.ops());
    let groups = graph::fuse(&g);
    let fused_tasks = graph::extract_fused_tasks(&g);
    println!("{}", graph::summarize(&groups));
    assert!(
        fused_tasks.len() < per_op_tasks.len(),
        "fusion must shrink the task set: {} fused vs {} per-op",
        fused_tasks.len(),
        per_op_tasks.len()
    );
    // Same TOTAL budget for both arms: per-op spends `trials` per task;
    // the fused arm splits the identical total over its fewer tasks.
    let total = cfg.trials * per_op_tasks.len();
    let arm_cfg = |suffix: &str, trials: usize| ExpConfig {
        trials,
        db_path: cfg.db_path.as_ref().map(|p| format!("{p}.{suffix}")),
        ..cfg.clone()
    };
    let per_op = fig9::metaschedule_e2e(&model, &target, &arm_cfg("perop", cfg.trials));
    let fused = fig9::metaschedule_fused_e2e(
        &model,
        &target,
        &arm_cfg("fused", total / fused_tasks.len()),
    );
    println!(
        "{model} on {}: per-op e2e {:.3} ms ({} tasks) vs fused e2e {:.3} ms ({} tasks), {:.3}x",
        target.name,
        per_op * 1e3,
        per_op_tasks.len(),
        fused * 1e3,
        fused_tasks.len(),
        per_op / fused
    );
    let json = format!(
        "{{\"model\":\"{model}\",\"target\":\"{}\",\"total_trials\":{total},\
         \"per_op_tasks\":{},\"fused_tasks\":{},\"per_op_e2e_s\":{per_op},\"fused_e2e_s\":{fused}}}\n",
        target.name,
        per_op_tasks.len(),
        fused_tasks.len()
    );
    std::fs::write("BENCH_e2e.json", json).expect("write BENCH_e2e.json");
    println!("(comparison written to BENCH_e2e.json)");
    // Fusion removes whole-tensor round trips between ops; that structural
    // advantage must survive search noise (2% headroom for tie cases).
    assert!(
        fused <= per_op * 1.02,
        "fused e2e {fused} must be <= per-op e2e {per_op}"
    );
}
