//! Figure 9 reproduction: end-to-end deep learning models.
//! {BERT-base, ResNet-50, MobileNet-v2} x {PyTorch, TVM, MetaSchedule},
//! CPU and GPU.
//!
//! ```sh
//! cargo bench --bench fig9_e2e -- --trials 32
//! ```

use metaschedule::exp::{fig9, ExpConfig};
use metaschedule::sim::Target;
use metaschedule::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 64),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    for target in [Target::cpu_avx512(), Target::gpu()] {
        let report = fig9::run(&target, &cfg, None);
        report.print();
        let _ = report.write("bench_results.jsonl");
    }
    println!("(rows appended to bench_results.jsonl)");
}
