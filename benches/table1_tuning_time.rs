//! Table 1 reproduction: tuning time for 5 end-to-end models, TVM-Ansor
//! vs MetaSchedule at equal trial budgets (wall-clock seconds), plus a
//! time-to-quality curve per model (trials / best latency / wall-clock
//! milliseconds, from [`metaschedule::search::QualityPoint`]) written to
//! `BENCH_table1.json` for CI artifact upload.
//!
//! `--sched-trials N` (default 0 = skip) additionally runs the task
//! scheduler per model under each allocation/objective arm (greedy+mse
//! vs gradient+rank) at N trials/task, records the scheduler-level
//! time-to-quality curves under the `policy_curves` JSON key, and prints
//! the win count the CI sched-smoke job greps for.
//!
//! ```sh
//! cargo bench --bench table1_tuning_time -- --trials 16 --sched-trials 48
//! ```

use metaschedule::cost_model::Objective;
use metaschedule::ctx::TuneContext;
use metaschedule::db::InMemoryDb;
use metaschedule::exp::{self, table1, ExpConfig};
use metaschedule::graph::{self, extract_tasks};
use metaschedule::search::{Allocation, SearchConfig, SimMeasurer, TaskScheduler};
use metaschedule::sim::Target;
use metaschedule::util::cli::Args;
use metaschedule::util::json::Json;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 16),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    let target = Target::cpu_avx512();
    let report = table1::run(&target, &cfg, None);
    // Values are seconds of tuning wall-clock, not operator latency.
    report.print();
    let _ = report.write("bench_results.jsonl");

    // Time-to-quality: tune each model's heaviest task once and keep the
    // full (trials, best_latency_s, wall_ms) curve the search emits.
    let quality_cfg = ExpConfig { db_path: None, ..cfg.clone() };
    let mut curves = Vec::new();
    for m in table1::TABLE1_MODELS {
        let ops = graph::by_name(m).expect("unknown model");
        let tasks = extract_tasks(&ops);
        let task = tasks
            .iter()
            .max_by_key(|t| t.weight)
            .expect("model extracts at least one task");
        let res = exp::tune_metaschedule(&task.prog, &target, &quality_cfg);
        println!(
            "time-to-quality: {m} ({}): {} point(s), final {:.2}us",
            task.name,
            res.quality.len(),
            res.best_latency_s * 1e6
        );
        curves.push(Json::obj(vec![
            ("model", Json::str(m)),
            ("task", Json::str(task.name.clone())),
            (
                "points",
                Json::arr(res.quality.iter().map(|q| {
                    Json::obj(vec![
                        ("trials", Json::num(q.trials as f64)),
                        ("best_latency_s", Json::num(q.best_latency_s)),
                        ("wall_ms", Json::num(q.wall_ms)),
                    ])
                })),
            ),
        ]));
    }

    // Per-policy scheduler curves: greedy+mse (the compat default) vs
    // gradient+rank at an identical total budget per model. The budget
    // must exceed the warmup share (round_trials per task) or no
    // allocation rounds run and the arms tie trivially — hence a
    // separate, larger `--sched-trials` knob.
    let sched_trials = args.flag_usize("sched-trials", 0);
    let mut policy_curves = Vec::new();
    if sched_trials > 0 {
        let arms = [
            ("greedy", Allocation::Greedy, Objective::Regression),
            ("gradient", Allocation::Gradient, Objective::PairwiseRank),
        ];
        let mut wins = 0usize;
        for m in table1::TABLE1_MODELS {
            let ops = graph::by_name(m).expect("unknown model");
            let tasks = extract_tasks(&ops);
            let ctx = TuneContext::generic(target.clone());
            let total = sched_trials * tasks.len();
            let mut e2e = Vec::new();
            for (label, alloc, objective) in arms {
                let mut ts = TaskScheduler::new(SearchConfig {
                    threads: cfg.threads,
                    ..SearchConfig::default()
                });
                ts.allocation = alloc;
                ts.objective = objective;
                let mut meas = SimMeasurer::new(target.clone());
                let mut db = InMemoryDb::new();
                let (results, rep) =
                    ts.tune_tasks_report(&tasks, &ctx, &mut meas, &mut db, total, cfg.seed);
                let lat = TaskScheduler::e2e_latency(&tasks, &results);
                println!(
                    "sched[{label}+{}] {m}: e2e {:.2} us in {} trials over {} round(s){}",
                    rep.objective,
                    lat * 1e6,
                    rep.spent,
                    rep.rounds,
                    if rep.early_stop { ", early stop" } else { "" }
                );
                e2e.push(lat);
                policy_curves.push(Json::obj(vec![
                    ("model", Json::str(m)),
                    ("policy", Json::str(rep.policy)),
                    ("objective", Json::str(rep.objective)),
                    ("e2e_latency_s", Json::num(lat)),
                    ("spent", Json::num(rep.spent as f64)),
                    ("rounds", Json::num(rep.rounds as f64)),
                    (
                        "points",
                        Json::arr(rep.curve.iter().map(|q| {
                            Json::obj(vec![
                                ("trials", Json::num(q.trials as f64)),
                                ("best_latency_s", Json::num(q.best_latency_s)),
                                ("wall_ms", Json::num(q.wall_ms)),
                            ])
                        })),
                    ),
                ]));
            }
            if e2e[1] <= e2e[0] {
                wins += 1;
            }
        }
        // The CI sched-smoke job greps this line for `on [1-9]` — the
        // gradient+rank arm must reach parity-or-better end-to-end
        // latency on at least one model at the equal budget.
        println!(
            "sched-smoke: gradient+rank <= greedy+mse on {wins}/{} models at {sched_trials} trials/task",
            table1::TABLE1_MODELS.len()
        );
    }

    let json = Json::obj(vec![
        ("bench", Json::str("table1_tuning_time")),
        ("trials", Json::num(cfg.trials as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("sched_trials", Json::num(sched_trials as f64)),
        ("report", report.to_json()),
        ("time_to_quality", Json::arr(curves.into_iter())),
        ("policy_curves", Json::arr(policy_curves.into_iter())),
    ]);
    let out = "BENCH_table1.json";
    std::fs::write(out, format!("{}\n", json.to_string())).expect("write BENCH_table1.json");
    println!("wrote {out}");
    println!("(columns are tuning seconds; rows appended to bench_results.jsonl)");
}
