//! Table 1 reproduction: tuning time for 5 end-to-end models, TVM-Ansor
//! vs MetaSchedule at equal trial budgets (wall-clock seconds).
//!
//! ```sh
//! cargo bench --bench table1_tuning_time -- --trials 16
//! ```

use metaschedule::exp::{table1, ExpConfig};
use metaschedule::sim::Target;
use metaschedule::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 16),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    let report = table1::run(&Target::cpu_avx512(), &cfg, None);
    // Values are seconds of tuning wall-clock, not operator latency.
    report.print();
    let _ = report.write("bench_results.jsonl");
    println!("(columns are tuning seconds; rows appended to bench_results.jsonl)");
}
