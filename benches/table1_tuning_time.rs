//! Table 1 reproduction: tuning time for 5 end-to-end models, TVM-Ansor
//! vs MetaSchedule at equal trial budgets (wall-clock seconds), plus a
//! time-to-quality curve per model (trials / best latency / wall-clock
//! milliseconds, from [`metaschedule::search::QualityPoint`]) written to
//! `BENCH_table1.json` for CI artifact upload.
//!
//! ```sh
//! cargo bench --bench table1_tuning_time -- --trials 16
//! ```

use metaschedule::exp::{self, table1, ExpConfig};
use metaschedule::graph::{self, extract_tasks};
use metaschedule::sim::Target;
use metaschedule::util::cli::Args;
use metaschedule::util::json::Json;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 16),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    let target = Target::cpu_avx512();
    let report = table1::run(&target, &cfg, None);
    // Values are seconds of tuning wall-clock, not operator latency.
    report.print();
    let _ = report.write("bench_results.jsonl");

    // Time-to-quality: tune each model's heaviest task once and keep the
    // full (trials, best_latency_s, wall_ms) curve the search emits.
    let quality_cfg = ExpConfig { db_path: None, ..cfg.clone() };
    let mut curves = Vec::new();
    for m in table1::TABLE1_MODELS {
        let ops = graph::by_name(m).expect("unknown model");
        let tasks = extract_tasks(&ops);
        let task = tasks
            .iter()
            .max_by_key(|t| t.weight)
            .expect("model extracts at least one task");
        let res = exp::tune_metaschedule(&task.prog, &target, &quality_cfg);
        println!(
            "time-to-quality: {m} ({}): {} point(s), final {:.2}us",
            task.name,
            res.quality.len(),
            res.best_latency_s * 1e6
        );
        curves.push(Json::obj(vec![
            ("model", Json::str(m)),
            ("task", Json::str(task.name.clone())),
            (
                "points",
                Json::arr(res.quality.iter().map(|q| {
                    Json::obj(vec![
                        ("trials", Json::num(q.trials as f64)),
                        ("best_latency_s", Json::num(q.best_latency_s)),
                        ("wall_ms", Json::num(q.wall_ms)),
                    ])
                })),
            ),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("table1_tuning_time")),
        ("trials", Json::num(cfg.trials as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("report", report.to_json()),
        ("time_to_quality", Json::arr(curves.into_iter())),
    ]);
    let out = "BENCH_table1.json";
    std::fs::write(out, format!("{}\n", json.to_string())).expect("write BENCH_table1.json");
    println!("wrote {out}");
    println!("(columns are tuning seconds; rows appended to bench_results.jsonl)");
}
