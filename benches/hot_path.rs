//! Microbenchmarks of the search hot paths (§Perf in EXPERIMENTS.md):
//! trace replay, mutation+validation, feature extraction (single,
//! batched, and cached by canonical trace), trace interning, GBT
//! train/predict, simulator evaluation, and a full evolutionary-search
//! round at 1 vs N threads (the chain-parallel pipeline). These are
//! what bound tuning throughput (Table 1), so the perf pass optimizes
//! against this bench.
//!
//! ```sh
//! cargo bench --bench hot_path             # full run
//! cargo bench --bench hot_path -- --smoke  # CI: one pass, compile+run gate
//! ```

use metaschedule::cost_model::{extract, extract_batch, Gbt, GbtCostModel};
use metaschedule::ctx::TuneContext;
use metaschedule::search::{mutate, EvolutionarySearch, SearchConfig, SimMeasurer};
use metaschedule::sim::{simulate, Target};
use metaschedule::trace::replay::{replay, replay_fresh};
use metaschedule::util::bench::{bench, print_table};
use metaschedule::util::rng::Rng;
use metaschedule::workloads;

fn main() {
    // --smoke: single sample, minimal budget — run in CI so the hot path
    // can never silently stop compiling (or panicking).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (samples, budget_ms) = if smoke { (1, 0.0) } else { (30, 20.0) };

    let target = Target::cpu_avx512();
    let prog = if smoke {
        workloads::fused_dense(64, 128, 64)
    } else {
        workloads::fused_dense(128, 3072, 768)
    };
    let ctx = TuneContext::generic(target.clone());
    let designs = ctx.generate(&prog, 42);
    let sch = designs
        .iter()
        .max_by_key(|s| s.trace.len())
        .expect("non-empty design space")
        .clone();
    println!(
        "design space: {} traces; benchmarked trace has {} instructions{}\n",
        designs.len(),
        sch.trace.len(),
        if smoke { " [smoke mode]" } else { "" }
    );

    let mut rows = Vec::new();

    let s = bench("space_generate", samples.min(20), budget_ms, || {
        let _ = ctx.generate(&prog, 42);
    });
    rows.push(vec!["space generate (all traces)".into(), fmt(&s)]);

    let s = bench("trace_replay", samples, budget_ms, || {
        let _ = replay(&sch.trace, &prog, 0).unwrap();
    });
    let replay_ns = s.median_ns;
    rows.push(vec!["trace replay (recorded decisions)".into(), fmt(&s)]);

    let s = bench("trace_replay_fresh", samples, budget_ms, || {
        let _ = replay_fresh(&sch.trace, &prog, 1);
    });
    rows.push(vec!["trace replay (fresh sampling)".into(), fmt(&s)]);

    let mut rng = Rng::seed_from_u64(3);
    let s = bench("mutate_validate", samples, budget_ms, || {
        let _ = mutate(&sch.trace, &prog, &mut rng, 7);
    });
    rows.push(vec!["mutate + validate".into(), fmt(&s)]);

    let s = bench("feature_extract", samples, budget_ms, || {
        let _ = extract(&sch.prog);
    });
    rows.push(vec!["feature extraction".into(), fmt(&s)]);

    // Batched extraction over a candidate generation (the matrix the
    // parallel chains push through the cost model each generation).
    let cand_progs: Vec<&metaschedule::tir::Program> = vec![&sch.prog; 32];
    let s = bench("feature_extract_batch32", samples, budget_ms, || {
        let _ = extract_batch(&cand_progs);
    });
    rows.push(vec!["feature extraction (batch of 32)".into(), fmt(&s)]);

    // Interning a full trace into the arena (every population member
    // pays this once; after warm-up each instruction is a hit).
    let s = bench("trace_intern", samples, budget_ms, || {
        let _ = ctx.intern_trace(&sch.trace);
    });
    rows.push(vec!["trace intern (warm arena)".into(), fmt(&s)]);

    // The cached counterpart of batch-32 extraction: after the first
    // miss, every lookup is a hash of the canonical id chain.
    let interned = ctx.intern_trace(&sch.trace);
    let cache = ctx.feature_cache().expect("cache enabled by default");
    let key = ctx.feat_key(metaschedule::tir::structural_hash(&prog), &interned);
    let s = bench("feature_cache_batch32", samples, budget_ms, || {
        for _ in 0..32 {
            let _ = cache.get_or_extract(&key, &sch.prog);
        }
    });
    rows.push(vec!["feature lookup, cached (batch of 32)".into(), fmt(&s)]);

    let s = bench("simulate", samples, budget_ms, || {
        let _ = simulate(&sch.prog, &target);
    });
    rows.push(vec!["simulator f(e)".into(), fmt(&s)]);

    // GBT on a realistic database size.
    let n_db = if smoke { 64 } else { 512 };
    let xs: Vec<Vec<f64>> = (0..n_db)
        .map(|i| {
            let mut rng = Rng::seed_from_u64(i);
            (0..24).map(|_| rng.gen_f64() * 8.0).collect()
        })
        .collect();
    let ys: Vec<f64> = xs.iter().map(|x| x[0] * 2.0 + x[3] * x[5]).collect();
    let mut gbt = Gbt::new(50, 5, 0.2);
    let s = bench("gbt_train", samples.min(5), budget_ms.max(1.0), || {
        gbt.fit(&xs, &ys);
    });
    rows.push(vec![format!("GBT train ({n_db} x 24, 50 trees)"), fmt(&s)]);
    let s = bench("gbt_predict", samples.min(20), budget_ms, || {
        let _ = gbt.predict(&xs);
    });
    rows.push(vec![format!("GBT predict ({n_db} programs)"), fmt(&s)]);

    // Full search round, serial vs chain-parallel: same seed, identical
    // result, different wall-clock (the tentpole's payoff).
    let small = workloads::matmul(1, 128, 128, 128);
    let trials = if smoke { 16 } else { 48 };
    for threads in [1usize, 4] {
        let cfg = SearchConfig {
            population: 24,
            generations: 3,
            num_trials: trials,
            measure_batch: 8,
            threads,
            ..SearchConfig::default()
        };
        let s = bench(
            if threads == 1 { "search_1_thread" } else { "search_4_threads" },
            samples.min(3),
            budget_ms,
            || {
                let mut model = GbtCostModel::new();
                let mut measurer = SimMeasurer::new(target.clone());
                let _ = EvolutionarySearch::new(cfg.clone()).tune(
                    &small,
                    &ctx,
                    &mut model,
                    &mut measurer,
                    7,
                );
            },
        );
        rows.push(vec![
            format!("evolutionary round ({trials} trials, {threads} thr)"),
            fmt(&s),
        ]);
    }

    print_table("hot-path microbenchmarks", &["path", "median"], &rows);
    println!(
        "\nreplay throughput: {:.0} traces/s (target: >= 10k on GMM-class programs)",
        1e9 / replay_ns
    );
}

fn fmt(s: &metaschedule::util::bench::BenchStats) -> String {
    if s.median_ns < 1e3 {
        format!("{:.0} ns", s.median_ns)
    } else if s.median_ns < 1e6 {
        format!("{:.2} us", s.median_ns / 1e3)
    } else {
        format!("{:.2} ms", s.median_ns / 1e6)
    }
}
