//! Microbenchmarks of the search hot paths (§Perf in EXPERIMENTS.md):
//! trace replay, mutation+validation, feature extraction, GBT
//! train/predict, and simulator evaluation. These are what bound tuning
//! throughput (Table 1), so the perf pass optimizes against this bench.
//!
//! ```sh
//! cargo bench --bench hot_path
//! ```

use metaschedule::cost_model::{extract, Gbt};
use metaschedule::search::mutate;
use metaschedule::sim::{simulate, Target};
use metaschedule::space::SpaceComposer;
use metaschedule::trace::replay::{replay, replay_fresh};
use metaschedule::util::bench::{bench, print_table};
use metaschedule::util::rng::Rng;
use metaschedule::workloads;

fn main() {
    let target = Target::cpu_avx512();
    let prog = workloads::fused_dense(128, 3072, 768);
    let composer = SpaceComposer::generic(target.clone());
    let designs = composer.generate(&prog, 42);
    let sch = designs
        .iter()
        .max_by_key(|s| s.trace.len())
        .expect("non-empty design space")
        .clone();
    println!(
        "design space: {} traces; benchmarked trace has {} instructions\n",
        designs.len(),
        sch.trace.len()
    );

    let mut rows = Vec::new();

    let s = bench("space_generate", 20, 20.0, || {
        let _ = composer.generate(&prog, 42);
    });
    rows.push(vec!["space generate (all traces)".into(), fmt(&s)]);

    let s = bench("trace_replay", 30, 20.0, || {
        let _ = replay(&sch.trace, &prog, 0).unwrap();
    });
    let replay_ns = s.median_ns;
    rows.push(vec!["trace replay (recorded decisions)".into(), fmt(&s)]);

    let s = bench("trace_replay_fresh", 30, 20.0, || {
        let _ = replay_fresh(&sch.trace, &prog, 1);
    });
    rows.push(vec!["trace replay (fresh sampling)".into(), fmt(&s)]);

    let mut rng = Rng::seed_from_u64(3);
    let s = bench("mutate_validate", 30, 20.0, || {
        let _ = mutate(&sch.trace, &prog, &mut rng, 7);
    });
    rows.push(vec!["mutate + validate".into(), fmt(&s)]);

    let s = bench("feature_extract", 30, 20.0, || {
        let _ = extract(&sch.prog);
    });
    rows.push(vec!["feature extraction".into(), fmt(&s)]);

    let s = bench("simulate", 30, 20.0, || {
        let _ = simulate(&sch.prog, &target);
    });
    rows.push(vec!["simulator f(e)".into(), fmt(&s)]);

    // GBT on a realistic database size.
    let xs: Vec<Vec<f64>> = (0..512)
        .map(|i| {
            let mut rng = Rng::seed_from_u64(i);
            (0..24).map(|_| rng.gen_f64() * 8.0).collect()
        })
        .collect();
    let ys: Vec<f64> = xs.iter().map(|x| x[0] * 2.0 + x[3] * x[5]).collect();
    let mut gbt = Gbt::new(50, 5, 0.2);
    let s = bench("gbt_train", 5, 50.0, || {
        gbt.fit(&xs, &ys);
    });
    rows.push(vec!["GBT train (512 x 24, 50 trees)".into(), fmt(&s)]);
    let s = bench("gbt_predict", 20, 20.0, || {
        let _ = gbt.predict(&xs);
    });
    rows.push(vec!["GBT predict (512 programs)".into(), fmt(&s)]);

    print_table("hot-path microbenchmarks", &["path", "median"], &rows);
    println!(
        "\nreplay throughput: {:.0} traces/s (target: >= 10k on GMM-class programs)",
        1e9 / replay_ns
    );
}

fn fmt(s: &metaschedule::util::bench::BenchStats) -> String {
    if s.median_ns < 1e3 {
        format!("{:.0} ns", s.median_ns)
    } else if s.median_ns < 1e6 {
        format!("{:.2} us", s.median_ns / 1e3)
    } else {
        format!("{:.2} ms", s.median_ns / 1e6)
    }
}
