//! Serving-path microbenchmarks: `ServingCache::lookup` throughput (the
//! "heavy traffic" read path) against the log-replay alternative it
//! replaces (`Database::query_top_k` per request), plus the snapshot
//! build cost a publisher pays per refresh.
//!
//! ```sh
//! cargo bench --bench serving_lookup             # full run
//! cargo bench --bench serving_lookup -- --smoke  # CI: one pass, compile+run gate
//! ```

use metaschedule::db::{Database, InMemoryDb, TuningRecord};
use metaschedule::serve::ServingCache;
use metaschedule::trace::{Inst, Trace};
use metaschedule::util::bench::{bench, print_table};
use metaschedule::util::rng::Rng;

/// Synthetic database: `workloads` workloads x `records` records each,
/// split across two targets, with a small but real trace per record.
fn synthetic_db(workloads: usize, records: usize) -> (InMemoryDb, Vec<(u64, &'static str)>) {
    let mut db = InMemoryDb::new();
    let mut rng = Rng::seed_from_u64(7);
    let mut keys = Vec::with_capacity(workloads);
    for w in 0..workloads {
        let shash = rng.next_u64();
        let target = if w % 2 == 0 { "cpu" } else { "gpu" };
        let wid = db.register_workload(&format!("w{w}"), shash, target);
        keys.push((shash, target));
        for r in 0..records {
            let lat = if r % 7 == 6 { None } else { Some((1.0 + rng.gen_f64()) * 1e-5) };
            db.commit_record(TuningRecord {
                workload: wid,
                trace: Trace {
                    insts: vec![Inst::GetBlock { name: format!("blk{w}"), out: 0 }],
                },
                latencies: lat.into_iter().collect(),
                target: target.to_string(),
                seed: 1,
                round: r as u64,
                cand_hash: rng.next_u64(),
                sim_version: "simtest".into(),
                rule_set: String::new(),
                objective: String::new(),
            });
        }
    }
    (db, keys)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (samples, budget_ms) = if smoke { (1, 0.0) } else { (30, 20.0) };
    let (n_workloads, n_records) = if smoke { (8, 16) } else { (128, 64) };
    let (db, keys) = synthetic_db(n_workloads, n_records);

    let cache = ServingCache::build(&db, 8);
    println!(
        "serving snapshot: {} workloads, {} records indexed from {} on file{}\n",
        cache.num_workloads(),
        cache.num_records(),
        db.num_records(),
        if smoke { " [smoke mode]" } else { "" }
    );
    // The snapshot must answer (sanity-gate the numbers below).
    assert!(cache.lookup(keys[0].0, keys[0].1).is_some(), "snapshot lost workload 0");

    let mut rows = Vec::new();
    const BATCH: usize = 1000;

    let s = bench("serving_cache_build", samples.min(10), budget_ms, || {
        let _ = ServingCache::build(&db, 8);
    });
    rows.push(vec!["snapshot build (publisher cost)".into(), fmt(s.median_ns), "-".into()]);

    // Indexed lookups: a hash probe + short target scan per request.
    let mut hits = 0usize;
    let s = bench("serving_lookup", samples, budget_ms, || {
        for i in 0..BATCH {
            let (shash, target) = keys[i % keys.len()];
            if cache.lookup(shash, target).is_some() {
                hits += 1;
            }
        }
    });
    let lookup_ns = s.median_ns / BATCH as f64;
    rows.push(vec![
        format!("ServingCache::lookup (batch of {BATCH})"),
        fmt(lookup_ns),
        format!("{:.1}M lookups/s", 1e3 / lookup_ns),
    ]);
    assert!(hits > 0, "benchmark loop never hit");

    // The path it replaces: top-k query against the database per request
    // (sort + clone of the workload's records each time).
    let s = bench("db_query_top_k", samples.min(10), budget_ms, || {
        for w in 0..keys.len().min(64) {
            let _ = db.query_top_k(w, 1);
        }
    });
    let replay_ns = s.median_ns / keys.len().min(64) as f64;
    rows.push(vec![
        "Database::query_top_k per request".into(),
        fmt(replay_ns),
        format!("{:.0}x slower than lookup", replay_ns / lookup_ns.max(1e-9)),
    ]);

    print_table(
        "serving-path microbenchmarks",
        &["path", "median/op", "throughput"],
        &rows,
    );
}

fn fmt(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{:.2} ms", ns / 1e6)
    }
}
