//! Figure 8 reproduction: operator- and subgraph-level performance.
//! 12 workloads x {PyTorch, TVM, MetaSchedule} on CPU and GPU.
//!
//! ```sh
//! cargo bench --bench fig8_operators            # full, slower
//! cargo bench --bench fig8_operators -- --trials 32   # quicker
//! ```

use metaschedule::exp::{fig8, ExpConfig};
use metaschedule::sim::Target;
use metaschedule::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 64),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    for target in [Target::cpu_avx512(), Target::gpu()] {
        let report = fig8::run(&target, &cfg, None);
        report.print();
        let _ = report.write("bench_results.jsonl");
    }
    println!("(rows appended to bench_results.jsonl)");
}
