//! Figure 10 reproduction: (a) search-space composition ablation on the
//! fused-dense BERT subgraph; (b) BERT-large with the Use-Tensor-Core
//! module vs the AutoTVM-style baseline (paper: 48% speedup).
//!
//! ```sh
//! cargo bench --bench fig10_composition -- --trials 48
//! ```

use metaschedule::exp::{fig10, ExpConfig};
use metaschedule::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let cfg = ExpConfig {
        trials: args.flag_usize("trials", 48),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        ..ExpConfig::default()
    };
    let a = fig10::run_10a(&cfg);
    a.print();
    let _ = a.write("bench_results.jsonl");

    let b = fig10::run_10b(&cfg);
    b.print();
    let _ = b.write("bench_results.jsonl");
    println!("(rows appended to bench_results.jsonl)");
}
