//! Search-space composition (paper §3.2, Figure 5): progressively compose
//! transformation modules and watch the searched latency improve — the
//! Figure 10a experiment in miniature, on the GPU target.
//!
//! ```sh
//! cargo run --release --example compose_space
//! ```

use metaschedule::exp::{tune_with_composer, ExpConfig};
use metaschedule::sim::{simulate, Target};
use metaschedule::space::{
    AutoInline, CrossThreadReduction, MultiLevelTiling, RandomComputeLocation, SpaceComposer,
    ThreadBind, TransformModule, UseTensorCore,
};
use metaschedule::workloads;

fn main() {
    let target = Target::gpu();
    let prog = workloads::fused_dense(128, 3072, 768);
    let naive = simulate(&prog, &target).unwrap().total_s;
    println!("fused-dense on {}: naive {:.1} us\n", target.name, naive * 1e6);

    let cfg = ExpConfig { trials: 64, seed: 5, ..ExpConfig::default() };
    let steps: Vec<(&str, Vec<Box<dyn TransformModule>>)> = vec![
        ("thread-bind only", vec![Box::new(ThreadBind::new())]),
        (
            "+ auto-inline",
            vec![Box::new(AutoInline::new()), Box::new(ThreadBind::new())],
        ),
        (
            "+ multi-level-tiling",
            vec![
                Box::new(AutoInline::new()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(ThreadBind::new()),
            ],
        ),
        (
            "+ compute-location",
            vec![
                Box::new(AutoInline::new()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(RandomComputeLocation::new()),
                Box::new(ThreadBind::new()),
            ],
        ),
        (
            "+ use-tensor-core (hardware-specific)",
            vec![
                Box::new(AutoInline::new()),
                Box::new(UseTensorCore::wmma()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(RandomComputeLocation::new()),
                Box::new(ThreadBind::new()),
            ],
        ),
    ];

    println!("{:<42} {:>12} {:>10}", "composition", "latency(us)", "vs naive");
    for (name, modules) in steps {
        let composer = SpaceComposer::new(modules, target.clone());
        let r = tune_with_composer(&prog, &target, &composer, &cfg);
        println!(
            "{:<42} {:>12.1} {:>9.1}x",
            name,
            r.best_latency_s * 1e6,
            naive / r.best_latency_s
        );
    }
    println!("\neach row adds one module; richer spaces cover faster programs (Figure 10a).");
}
