//! Search-space composition (paper §3.2, Figure 5): progressively compose
//! schedule rules and watch the searched latency improve — the Figure 10a
//! experiment in miniature, on the GPU target.
//!
//! Each step is just a `--rules`-style spec resolved against the built-in
//! rule registry: growing the space is adding a name to a list, not
//! editing system code.
//!
//! ```sh
//! cargo run --release --example compose_space
//! ```

use metaschedule::ctx::TuneContext;
use metaschedule::exp::{tune_with_ctx, ExpConfig};
use metaschedule::sim::{simulate, Target};
use metaschedule::workloads;

fn main() {
    let target = Target::gpu();
    let prog = workloads::fused_dense(128, 3072, 768);
    let naive = simulate(&prog, &target).unwrap().total_s;
    println!("fused-dense on {}: naive {:.1} us\n", target.name, naive * 1e6);

    let cfg = ExpConfig { trials: 64, seed: 5, ..ExpConfig::default() };
    let steps: Vec<(&str, &str)> = vec![
        ("thread-bind only", "thread-bind"),
        ("+ auto-inline", "auto-inline,thread-bind"),
        (
            "+ multi-level-tiling",
            "auto-inline,multi-level-tiling,cross-thread-reduction,thread-bind",
        ),
        (
            "+ compute-location",
            "auto-inline,multi-level-tiling,cross-thread-reduction,random-compute-location,thread-bind",
        ),
        (
            "+ use-tensor-core (hardware-specific)",
            "auto-inline,use-tensor-core,multi-level-tiling,cross-thread-reduction,random-compute-location,thread-bind",
        ),
    ];

    println!("{:<42} {:>12} {:>10}", "composition", "latency(us)", "vs naive");
    for (name, spec) in steps {
        let ctx = TuneContext::from_specs(target.clone(), spec, "default", "default")
            .expect("built-in rule names");
        let r = tune_with_ctx(&prog, &ctx, &cfg);
        println!(
            "{:<42} {:>12.1} {:>9.1}x",
            name,
            r.best_latency_s * 1e6,
            naive / r.best_latency_s
        );
    }
    println!("\neach row adds one rule name; richer spaces cover faster programs (Figure 10a).");
    println!("the same specs work on the CLI: metaschedule tune --rules <spec>");
}
