//! Real-hardware measurement loop: tune the GMM workload where `f(e)` is
//! *actual wall-clock* of AOT-compiled Pallas tile variants executed via
//! PJRT — the full three-layer composition:
//!
//!   L1 python/compile/kernels/matmul.py  — Pallas tiled matmul
//!   L2 python/compile/model.py           — jax fn, AOT-lowered to HLO text
//!   L3 this binary                       — MetaSchedule search in Rust,
//!                                          measuring the real executables
//!
//! Requires `make artifacts` (build-time Python; never on this path).
//!
//! ```sh
//! cargo run --release --example tune_gmm_pjrt
//! ```

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::runtime::{scan_variants, PallasTileModule, PjrtGmmMeasurer, TileVariant};
use metaschedule::search::{EvolutionarySearch, Measurer, SearchConfig};
use metaschedule::sim::Target;
use metaschedule::workloads;

fn main() {
    let dir = std::path::Path::new("artifacts");
    let variants = scan_variants(dir);
    if variants.is_empty() {
        eprintln!("no artifacts found — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("== GMM (128x128x128) tuned against real PJRT wall-clock ==");
    println!("{} AOT Pallas tile variants available\n", variants.len());

    let mut measurer = PjrtGmmMeasurer::new(dir, 128, 128, 128).unwrap();

    // Correctness gate before any timing (the paper's validator morally
    // extends to the executable: never report a wrong kernel as fast).
    let err = measurer
        .runner
        .verify_gmm(TileVariant { bm: 32, bn: 32, bk: 32 }, 128, 128, 128)
        .unwrap();
    println!("numerics gate: max|err| vs host matmul = {err:.2e}\n");
    assert!(err < 1e-3);

    // Exhaustive reference: time every variant (the small grid allows it).
    println!("{:<10} {:>6} {:>6} {:>6} {:>12}", "variant", "bm", "bn", "bk", "latency(us)");
    let mut best_exhaustive = (f64::INFINITY, variants[0]);
    for v in &variants {
        let lat = measurer.time_variant(*v).unwrap();
        if lat < best_exhaustive.0 {
            best_exhaustive = (lat, *v);
        }
        println!(
            "{:<10} {:>6} {:>6} {:>6} {:>12.2}",
            "gmm", v.bm, v.bn, v.bk, lat * 1e6
        );
    }
    println!(
        "\nexhaustive best: bm{} bn{} bk{} at {:.2} us",
        best_exhaustive.1.bm,
        best_exhaustive.1.bn,
        best_exhaustive.1.bk,
        best_exhaustive.0 * 1e6
    );

    // Now the search: does MetaSchedule find (near-)exhaustive-best with a
    // fraction of the measurements? (Measurements are cached per variant,
    // so `count` counts proposals; distinct timings <= grid size.)
    let prog = workloads::matmul(1, 128, 128, 128);
    let ctx = TuneContext::from_rules(
        vec![Box::new(PallasTileModule::new())],
        Target::cpu_avx512(),
    );
    let cfg = SearchConfig {
        population: 24,
        generations: 3,
        num_trials: 24,
        measure_batch: 8,
        ..SearchConfig::default()
    };
    let mut model = GbtCostModel::new();
    let r = EvolutionarySearch::new(cfg).tune(&prog, &ctx, &mut model, &mut measurer, 3);
    let tile = metaschedule::runtime::tile_of(&r.best_prog).unwrap();
    let snapped = measurer.snap(tile);
    println!(
        "\nsearch best ({} trials): tile ({}, {}, {}) -> artifact bm{} bn{} bk{} at {:.2} us",
        r.trials, tile.bm, tile.bn, tile.bk, snapped.bm, snapped.bn, snapped.bk,
        r.best_latency_s * 1e6
    );
    println!(
        "search-found vs exhaustive-best: {:.2}x",
        r.best_latency_s / best_exhaustive.0
    );
    assert!(
        r.best_latency_s <= best_exhaustive.0 * 1.5,
        "search should land near the exhaustive optimum"
    );
    println!("\ntotal PJRT measurer invocations: {}", measurer.count());
}
