//! End-to-end driver (the repo's headline validation): optimize BERT-base
//! — task extraction from the full operator graph, budget allocation
//! across tasks, evolutionary search per task with a learned cost model,
//! and the final end-to-end latency vs the vendor-library baseline
//! (Figure 9's BERT-base bar). Logs the per-task tuning table and the
//! aggregate improvement curve.
//!
//! ```sh
//! cargo run --release --example e2e_bert [-- --trials 48 --target cpu]
//! ```

use metaschedule::ctx::TuneContext;
use metaschedule::graph::{self, extract_tasks};
use metaschedule::search::{SearchConfig, SimMeasurer, TaskScheduler};
use metaschedule::sim::{simulate, Target};
use metaschedule::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let trials_per_task = args.flag_usize("trials", 48);
    let target = Target::by_name(&args.flag_or("target", "cpu")).expect("target");

    println!("== BERT-base end-to-end on {} ==", target.name);
    let ops = graph::by_name("bert-base").unwrap();
    let tasks = extract_tasks(&ops);
    println!(
        "extracted {} unique tasks from {} operator instances\n",
        tasks.len(),
        ops.iter().map(|(_, c)| c).sum::<usize>()
    );

    // Baselines for context.
    let vendor = graph::vendor_e2e(&ops, &target);
    let naive: f64 = tasks
        .iter()
        .map(|t| {
            simulate(&t.prog, &target).map(|r| r.total_s).unwrap_or(0.0) * t.weight as f64
        })
        .sum();

    // Tune.
    let ctx = TuneContext::generic(target.clone());
    let mut measurer = SimMeasurer::new(target.clone());
    let ts = TaskScheduler::new(SearchConfig {
        threads: args.flag_usize("threads", 0),
        ..SearchConfig::default()
    });
    let total_budget = trials_per_task * tasks.len();
    let t0 = std::time::Instant::now();
    let results = ts.tune_tasks(&tasks, &ctx, &mut measurer, total_budget, 42);
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "{:<28} {:>6} {:>12} {:>12} {:>8}",
        "task", "weight", "naive(us)", "tuned(us)", "speedup"
    );
    for (t, r) in tasks.iter().zip(&results) {
        let naive_t = simulate(&t.prog, &target).map(|x| x.total_s).unwrap_or(f64::NAN);
        println!(
            "{:<28} {:>6} {:>12.2} {:>12.2} {:>7.1}x",
            t.name,
            t.weight,
            naive_t * 1e6,
            r.best_latency_s * 1e6,
            naive_t / r.best_latency_s
        );
    }

    let e2e = TaskScheduler::e2e_latency(&tasks, &results);
    println!("\nend-to-end latency:");
    println!("  naive (unscheduled)       {:>10.3} ms", naive * 1e3);
    println!("  PyTorch-class vendor      {:>10.3} ms", vendor * 1e3);
    println!(
        "  MetaSchedule              {:>10.3} ms   ({:.2}x vs vendor, {:.1}x vs naive)",
        e2e * 1e3,
        vendor / e2e,
        naive / e2e
    );
    println!(
        "  ({} measurement trials, {:.1}s tuning wall-clock)",
        measurer.count_public(),
        wall
    );
    assert!(e2e < vendor, "MetaSchedule must beat the vendor e2e (Figure 9)");
}

trait CountPublic {
    fn count_public(&self) -> usize;
}

impl CountPublic for SimMeasurer {
    fn count_public(&self) -> usize {
        use metaschedule::search::Measurer;
        self.count()
    }
}
