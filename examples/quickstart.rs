//! Quickstart: schedule the paper's Figure 2/3 running example by hand,
//! then let the learning-driven search find a better one.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::schedule::Schedule;
use metaschedule::search::{EvolutionarySearch, SearchConfig, SimMeasurer};
use metaschedule::sim::{simulate, Target};
use metaschedule::tir::{print_program, PrintOptions};
use metaschedule::trace::serde::trace_to_text;
use metaschedule::trace::FactorArg;
use metaschedule::workloads;

fn main() {
    let target = Target::cpu_avx512();

    // ---- 1. An initial program e_0: Dense + bias + ReLU -------------------
    let prog = workloads::fused_dense(128, 3072, 768);
    let naive = simulate(&prog, &target).unwrap().total_s;
    println!("e_0 (fused-dense 128x768->3072), naive latency {:.1} us\n", naive * 1e6);

    // ---- 2. Hand-write a stochastic schedule (the probabilistic language) --
    let mut sch = Schedule::new(prog.clone(), /*seed=*/ 7);
    // Fold bias into relu, then tile the dense block with *sampled* tiles.
    let bias = sch.get_block("bias_add").unwrap();
    sch.compute_inline(bias).unwrap();
    let dense = sch.get_block("dense").unwrap();
    let loops = sch.get_loops(dense).unwrap();
    let ti = sch.sample_perfect_tile(loops[0], 2, 64).unwrap(); // θ0, θ1
    let i = sch
        .split(loops[0], &[FactorArg::Rv(ti[0].0), FactorArg::Rv(ti[1].0)])
        .unwrap();
    let tj = sch.sample_perfect_tile(loops[1], 2, 64).unwrap(); // θ2, θ3
    let j = sch
        .split(loops[1], &[FactorArg::Rv(tj[0].0), FactorArg::Rv(tj[1].0)])
        .unwrap();
    sch.reorder(&[i[0], j[0], i[1], j[1]]).unwrap();
    sch.parallel(i[0]).unwrap();
    sch.vectorize(j[1]).unwrap();
    // Figure 3 step 2: sample where ReLU computes (a loop of dense).
    let relu = sch.get_block("relu").unwrap();
    let loc = sch.sample_compute_location(relu).unwrap();
    let _ = sch.reverse_compute_at(relu, loc);
    let hand = simulate(&sch.prog, &target).unwrap().total_s;
    println!("hand-written stochastic schedule -> {:.1} us", hand * 1e6);
    println!("its trace (a linearized probabilistic program):");
    for line in trace_to_text(&sch.trace).lines().take(10) {
        println!("  {line}");
    }
    println!("  ...\n");

    // ---- 3. Learning-driven search over the composed generic space --------
    let ctx = TuneContext::generic(target.clone());
    let search = EvolutionarySearch::new(SearchConfig {
        num_trials: 96,
        ..SearchConfig::default()
    });
    let mut model = GbtCostModel::new();
    let mut measurer = SimMeasurer::new(target.clone());
    let result = search.tune(&prog, &ctx, &mut model, &mut measurer, 1);
    println!(
        "evolutionary search ({} trials) -> {:.1} us  ({:.1}x over naive, {:.1}x over hand)",
        result.trials,
        result.best_latency_s * 1e6,
        naive / result.best_latency_s,
        hand / result.best_latency_s,
    );
    println!("\nbest program:\n{}", print_program(&result.best_prog, PrintOptions::default()));
}
