//! Writing a custom transformation module — the paper's headline
//! extensibility story (§6.3: a grad student wrote the 82-line
//! Use-Tensor-Core module in 2 days and composed it in without touching
//! the system).
//!
//! This example defines a new module from scratch — `SplitReorderCache`:
//! a deliberately quirky "expert rule" that tiles the reduction loop and
//! annotates a software-pipelining hint — and composes it with the stock
//! generic modules. No framework code changes required: implement
//! `TransformModule`, push it into the composer's list.
//!
//! ```sh
//! cargo run --release --example custom_module
//! ```

use metaschedule::exp::{tune_with_composer, ExpConfig};
use metaschedule::schedule::{SchResult, Schedule};
use metaschedule::sim::{simulate, Target};
use metaschedule::space::{self, try_transform, SpaceComposer, TransformModule};
use metaschedule::tir::analysis::{classify_loop, LoopClass};
use metaschedule::tir::LoopKind;
use metaschedule::trace::FactorArg;
use metaschedule::workloads;

/// A user-written expert rule: split the outermost serial reduction loop
/// with sampled factors, unroll the inner part, and leave a pipelining
/// annotation. ~40 lines, fully composable.
struct SplitUnrollReduction;

impl SplitUnrollReduction {
    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        let mut target = None;
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).kind == LoopKind::Serial
                && classify_loop(&s.prog, item) == LoopClass::Reduce
                && s.prog.loop_data(item).extent >= 8
            {
                target = Some(l);
                break;
            }
        }
        let l = target.ok_or(metaschedule::schedule::ScheduleError::NotReduction(
            "no reduction loop".into(),
        ))?;
        let t = s.sample_perfect_tile(l, 2, 16)?;
        let parts = s.split(l, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
        s.unroll(parts[1])?;
        s.annotate_loop(parts[0], "software_pipeline_stage", "0,1")?;
        Ok(())
    }
}

impl TransformModule for SplitUnrollReduction {
    fn name(&self) -> &'static str {
        "split-unroll-reduction"
    }

    fn apply(&self, sch: Schedule, block_name: &str, _t: &Target) -> Vec<Schedule> {
        let is_red = sch
            .prog
            .find_block(block_name)
            .map(|b| sch.prog.block_data(b).is_reduction())
            .unwrap_or(false);
        if !is_red {
            return vec![sch];
        }
        match try_transform(&sch, |s| self.transform(s, block_name)) {
            // Fork: with and without the expert rule.
            Some(out) => vec![out, sch],
            None => vec![sch],
        }
    }
}

fn main() {
    let target = Target::cpu_avx512();
    let prog = workloads::norm(1, 256, 256);
    let naive = simulate(&prog, &target).unwrap().total_s;
    println!("NRM workload, naive {:.2} us", naive * 1e6);

    let cfg = ExpConfig { trials: 64, seed: 2, ..ExpConfig::default() };

    // Stock generic space.
    let generic = SpaceComposer::generic(target.clone());
    let r0 = tune_with_composer(&prog, &target, &generic, &cfg);
    println!("generic space              -> {:.2} us", r0.best_latency_s * 1e6);

    // Generic space + the custom module, composed in one line.
    let mut modules: Vec<Box<dyn TransformModule>> = vec![
        Box::new(space::AutoInline::new()),
        Box::new(SplitUnrollReduction),
        Box::new(space::MultiLevelTiling::cpu()),
        Box::new(space::AddRfactor::new()),
        Box::new(space::RandomComputeLocation::new()),
        Box::new(space::ParallelVectorizeUnroll::new()),
    ];
    let composer = SpaceComposer::new(std::mem::take(&mut modules), target.clone());
    let r1 = tune_with_composer(&prog, &target, &composer, &cfg);
    println!("generic + custom module    -> {:.2} us", r1.best_latency_s * 1e6);
    println!(
        "\ncustom module composed without any framework change; best space wins ({})",
        if r1.best_latency_s <= r0.best_latency_s { "custom helped or tied" } else { "generic was already sufficient" }
    );
}
