//! Writing a custom schedule rule — the paper's headline extensibility
//! story (§6.3: a grad student wrote the 82-line Use-Tensor-Core module
//! in 2 days and composed it in without touching the system).
//!
//! This example defines a new rule from scratch — `SplitUnrollReduction`:
//! a deliberately quirky "expert rule" that tiles the reduction loop and
//! annotates a software-pipelining hint — registers it in a
//! [`RegistrySet`] under the name `split-unroll-reduction`, and invokes
//! it exactly like a CLI user would: `--rules
//! auto-inline,split-unroll-reduction,…`. No framework code changes
//! required: implement `ScheduleRule`, register, name it in a spec. The
//! rule then shows up in `--explain-space` diagnostics and in the
//! rule-set provenance stamped into every tuning record.
//!
//! ```sh
//! cargo run --release --example custom_module
//! ```

use metaschedule::ctx::{RegistrySet, TuneContext};
use metaschedule::exp::{tune_with_ctx, ExpConfig};
use metaschedule::schedule::{SchResult, Schedule};
use metaschedule::sim::{simulate, Target};
use metaschedule::space::{attempt, RuleOutcome, ScheduleRule};
use metaschedule::tir::analysis::{classify_loop, LoopClass};
use metaschedule::tir::LoopKind;
use metaschedule::trace::FactorArg;
use metaschedule::workloads;

/// A user-written expert rule: split the outermost serial reduction loop
/// with sampled factors, unroll the inner part, and leave a pipelining
/// annotation. ~40 lines, fully composable.
struct SplitUnrollReduction;

impl SplitUnrollReduction {
    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        let mut target = None;
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).kind == LoopKind::Serial
                && classify_loop(&s.prog, item) == LoopClass::Reduce
                && s.prog.loop_data(item).extent >= 8
            {
                target = Some(l);
                break;
            }
        }
        let l = target.ok_or(metaschedule::schedule::ScheduleError::NotReduction(
            "no reduction loop".into(),
        ))?;
        let t = s.sample_perfect_tile(l, 2, 16)?;
        let parts = s.split(l, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
        s.unroll(parts[1])?;
        s.annotate_loop(parts[0], "software_pipeline_stage", "0,1")?;
        Ok(())
    }
}

impl ScheduleRule for SplitUnrollReduction {
    fn name(&self) -> &str {
        "split-unroll-reduction"
    }

    fn describe(&self) -> String {
        "expert rule: sampled reduction split + inner unroll + pipeline hint".into()
    }

    fn apply(&self, sch: Schedule, block_name: &str, _t: &Target) -> RuleOutcome {
        let is_red = sch
            .prog
            .find_block(block_name)
            .map(|b| sch.prog.block_data(b).is_reduction())
            .unwrap_or(false);
        if !is_red {
            return RuleOutcome::Skip(sch);
        }
        // Fork: with and without the expert rule.
        match attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out, sch]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

fn main() {
    let target = Target::cpu_avx512();
    let prog = workloads::norm(1, 256, 256);
    let naive = simulate(&prog, &target).unwrap().total_s;
    println!("NRM workload, naive {:.2} us", naive * 1e6);

    let cfg = ExpConfig { trials: 64, seed: 2, ..ExpConfig::default() };

    // Stock generic space.
    let generic = TuneContext::generic(target.clone());
    let r0 = tune_with_ctx(&prog, &generic, &cfg);
    println!("generic space              -> {:.2} us", r0.best_latency_s * 1e6);

    // Register the custom rule, then compose it by NAME — the same spec
    // grammar the CLI's --rules flag takes.
    let mut reg = RegistrySet::builtin();
    reg.rules.register("split-unroll-reduction", |_| {
        Box::new(SplitUnrollReduction) as Box<dyn ScheduleRule>
    });
    let ctx = TuneContext::from_specs_in(
        &reg,
        target.clone(),
        "auto-inline,split-unroll-reduction,multi-level-tiling,add-rfactor,random-compute-location,parallel-vectorize-unroll",
        "default",
        "default",
    )
    .expect("registered rule resolves by name");
    let r1 = tune_with_ctx(&prog, &ctx, &cfg);
    println!("generic + custom rule      -> {:.2} us", r1.best_latency_s * 1e6);
    println!("rule-set provenance        -> {}", ctx.rule_set());
    println!("\n--explain-space view of the extended context:");
    print!("{}", ctx.explain());
    println!(
        "\ncustom rule composed without any framework change; best space wins ({})",
        if r1.best_latency_s <= r0.best_latency_s { "custom helped or tied" } else { "generic was already sufficient" }
    );
}
