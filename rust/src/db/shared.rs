//! Mutex adapter sharing one database across the task scheduler's
//! parallel warmup rounds — the same shape as
//! [`crate::search::parallel::SharedMeasurer`]: the backend stays free to
//! be single-threaded, each worker takes a `&SharedDb` and hands it to
//! APIs expecting `&mut dyn Database`.
//!
//! Determinism: concurrent tasks interleave their commits in the global
//! log, but every query the search makes ([`Database::records_for`],
//! [`Database::candidate_hashes`], [`Database::query_top_k`]) filters to
//! one workload, and each workload is only ever written by the one task
//! that owns it — per-workload order is each task's own commit order
//! regardless of thread count.

use std::sync::Mutex;

use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry, WorkloadId};

/// Thread-safe wrapper around an exclusive database borrow.
pub struct SharedDb<'a> {
    inner: Mutex<&'a mut dyn Database>,
}

impl<'a> SharedDb<'a> {
    pub fn new(inner: &'a mut dyn Database) -> SharedDb<'a> {
        SharedDb {
            inner: Mutex::new(inner),
        }
    }
}

/// Adapter so a `&SharedDb` can be handed to APIs that expect an
/// exclusive `&mut dyn Database` (each thread makes its own reference).
/// Every call takes the lock for exactly one backend operation; the
/// provided-method defaults are overridden to forward whole queries so a
/// top-k never interleaves with a concurrent commit mid-sort.
impl Database for &SharedDb<'_> {
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId {
        self.inner.lock().unwrap().register_workload(name, shash, target)
    }

    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId> {
        self.inner.lock().unwrap().find_workload(shash, target)
    }

    fn workload_entries(&self) -> Vec<WorkloadEntry> {
        self.inner.lock().unwrap().workload_entries()
    }

    fn commit_record(&mut self, rec: TuningRecord) {
        self.inner.lock().unwrap().commit_record(rec);
    }

    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord> {
        self.inner.lock().unwrap().records_for(workload)
    }

    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64> {
        self.inner.lock().unwrap().candidate_hashes(workload)
    }

    fn num_records(&self) -> usize {
        self.inner.lock().unwrap().num_records()
    }

    fn query_top_k(&self, workload: WorkloadId, k: usize) -> Vec<TuningRecord> {
        self.inner.lock().unwrap().query_top_k(workload, k)
    }

    fn best_latency(&self, workload: WorkloadId) -> Option<f64> {
        self.inner.lock().unwrap().best_latency(workload)
    }

    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        self.inner.lock().unwrap().has_candidate(workload, cand_hash)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::InMemoryDb;
    use crate::trace::Trace;

    #[test]
    fn concurrent_commits_land_and_partition_cleanly() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 1, "cpu");
        let b = db.register_workload("B", 2, "cpu");
        let base: &mut dyn Database = &mut db;
        let shared = SharedDb::new(base);
        std::thread::scope(|s| {
            for (wid, offset) in [(a, 0u64), (b, 1000u64)] {
                let shared = &shared;
                s.spawn(move || {
                    let mut local: &SharedDb = shared;
                    for i in 0..50u64 {
                        local.commit_record(TuningRecord {
                            workload: wid,
                            trace: Trace { insts: vec![] },
                            latencies: vec![(i + 1) as f64],
                            target: "cpu".into(),
                            seed: 0,
                            round: i,
                            cand_hash: offset + i,
                            sim_version: "simtest".into(),
                            rule_set: String::new(),
                            objective: String::new(),
                        });
                    }
                });
            }
        });
        // Per-workload commit order is each writer's program order.
        let local: &SharedDb = &shared;
        assert_eq!(local.num_records(), 100);
        let rounds: Vec<u64> = local.records_for(a).iter().map(|r| r.round).collect();
        assert_eq!(rounds, (0..50).collect::<Vec<u64>>());
        assert_eq!(local.best_latency(b), Some(1.0));
        assert!(local.has_candidate(b, 1000));
        assert!(!local.has_candidate(a, 1000));
    }
}
