//! Append-only JSONL persistence: one JSON object per line, either a
//! workload registration (`kind: "workload"`) or a tuning record
//! (`kind: "record"`, trace embedded in the [`crate::trace::serde`] line
//! format). Opening a file replays every line into an [`InMemoryDb`]
//! index; commits append + flush synchronously so a killed run is
//! resumable from everything it measured. Line order is registration/
//! commit order — re-opening reproduces the exact iteration order the
//! writing process saw, which is what keeps warm-started runs
//! deterministic.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::db::memory::InMemoryDb;
use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry, WorkloadId};
use crate::util::json::Json;

/// File-backed tuning database (`--db path.jsonl`).
pub struct JsonFileDb {
    path: PathBuf,
    file: File,
    mem: InMemoryDb,
}

impl JsonFileDb {
    /// Open (or create) a JSONL database file. Parent directories are
    /// created; a corrupt line fails the whole open with its line number
    /// rather than silently dropping history.
    pub fn open(path: impl AsRef<Path>) -> Result<JsonFileDb, String> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
            }
        }
        let mut mem = InMemoryDb::new();
        if path.exists() {
            let text =
                std::fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
            // Registered-workload count maintained inline: the bounds
            // check runs once per record line and must not clone the
            // registry each time.
            let mut n_workloads = 0usize;
            for (no, line) in text.lines().enumerate() {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let ctx = |e: String| format!("{}:{}: {e}", path.display(), no + 1);
                let j = Json::parse(line).map_err(ctx)?;
                match j.get("kind").and_then(Json::as_str) {
                    Some("workload") => {
                        let entry = WorkloadEntry::from_json(&j).map_err(ctx)?;
                        mem.insert_entry(entry).map_err(ctx)?;
                        n_workloads += 1;
                    }
                    Some("record") => {
                        let rec = TuningRecord::from_json(&j).map_err(ctx)?;
                        if rec.workload >= n_workloads {
                            return Err(ctx(format!("record references unknown workload {}", rec.workload)));
                        }
                        mem.commit_record(rec);
                    }
                    other => return Err(ctx(format!("unknown line kind {other:?}"))),
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        Ok(JsonFileDb { path, file, mem })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Size of the backing file in bytes (0 if unreadable).
    pub fn file_len(&self) -> u64 {
        std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0)
    }

    /// Append one JSON line and flush. Persistence failure is fatal: a
    /// tuning run that silently stops recording would poison every
    /// warm-started run after it.
    fn append_line(&mut self, j: &Json) {
        let line = j.to_string();
        debug_assert!(!line.contains('\n'), "JSONL line must be newline-free");
        writeln!(self.file, "{line}")
            .and_then(|()| self.file.flush())
            .unwrap_or_else(|e| panic!("tuning db append to {} failed: {e}", self.path.display()));
    }
}

impl Database for JsonFileDb {
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId {
        if let Some(id) = self.mem.find_workload(shash, target) {
            return id;
        }
        let id = self.mem.register_workload(name, shash, target);
        let entry = WorkloadEntry {
            id,
            name: name.to_string(),
            shash,
            target: target.to_string(),
        };
        self.append_line(&entry.to_json());
        id
    }

    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId> {
        self.mem.find_workload(shash, target)
    }

    fn workload_entries(&self) -> Vec<WorkloadEntry> {
        self.mem.workload_entries()
    }

    fn commit_record(&mut self, rec: TuningRecord) {
        self.append_line(&rec.to_json());
        self.mem.commit_record(rec);
    }

    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord> {
        self.mem.records_for(workload)
    }

    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64> {
        self.mem.candidate_hashes(workload)
    }

    fn num_records(&self) -> usize {
        self.mem.num_records()
    }

    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        self.mem.has_candidate(workload, cand_hash)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Inst, Trace};

    /// Unique temp path per test (process id + name), cleaned up by Guard.
    fn tmp(name: &str) -> (PathBuf, Guard) {
        let p = std::env::temp_dir().join(format!("ms-dbtest-{}-{name}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        (p.clone(), Guard(p))
    }

    struct Guard(PathBuf);
    impl Drop for Guard {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    fn rec(workload: WorkloadId, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace {
                insts: vec![Inst::GetBlock {
                    name: "blk with space".into(),
                    out: 0,
                }],
            },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 7,
            round: 1,
            cand_hash: cand,
        }
    }

    #[test]
    fn reopen_restores_registry_and_records() {
        let (path, _g) = tmp("reopen");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 11, "cpu");
            let b = db.register_workload("B", 22, "gpu");
            db.commit_record(rec(a, 1, Some(3.0)));
            db.commit_record(rec(b, 2, Some(1.0)));
            db.commit_record(rec(a, 3, None));
        }
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.workload_entries().len(), 2);
        assert_eq!(db.num_records(), 3);
        assert_eq!(db.find_workload(11, "cpu"), Some(0));
        assert_eq!(db.candidate_hashes(0), vec![1, 3]);
        assert_eq!(db.best_latency(0), Some(3.0));
        assert_eq!(db.best_latency(1), Some(1.0));
        assert!(db.has_candidate(0, 3), "failed candidate persisted for dedup");
    }

    #[test]
    fn appends_accumulate_across_opens() {
        let (path, _g) = tmp("accumulate");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 5, "cpu");
            db.commit_record(rec(a, 1, Some(2.0)));
        }
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            // Re-registration must not duplicate the registry line.
            let a = db.register_workload("A", 5, "cpu");
            assert_eq!(a, 0);
            db.commit_record(rec(a, 2, Some(1.5)));
        }
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.workload_entries().len(), 1);
        assert_eq!(db.candidate_hashes(0), vec![1, 2]);
        assert_eq!(db.best_latency(0), Some(1.5));
    }

    #[test]
    fn corrupt_line_fails_open_with_location() {
        let (path, _g) = tmp("corrupt");
        let good = "{\"kind\":\"workload\",\"id\":0,\"name\":\"A\",\"shash\":\"05\",\"target\":\"cpu\"}";
        std::fs::write(&path, format!("{good}\nnot json\n")).unwrap();
        let err = JsonFileDb::open(&path).unwrap_err();
        assert!(err.contains(":2:"), "error should name the line: {err}");
    }

    #[test]
    fn record_for_unknown_workload_fails_open() {
        let (path, _g) = tmp("orphan");
        let r = rec(4, 1, Some(1.0));
        std::fs::write(&path, format!("{}\n", r.to_json().to_string())).unwrap();
        let err = JsonFileDb::open(&path).unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
    }

    #[test]
    fn blank_lines_tolerated() {
        let (path, _g) = tmp("blank");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            db.register_workload("A", 9, "cpu");
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push('\n');
        std::fs::write(&path, text).unwrap();
        assert_eq!(JsonFileDb::open(&path).unwrap().workload_entries().len(), 1);
    }
}
