//! Append-only JSONL persistence: one JSON object per line, either a
//! workload registration (`kind: "workload"`) or a tuning record
//! (`kind: "record"`, trace embedded in the [`crate::trace::serde`] line
//! format). Opening a file replays every line into an [`InMemoryDb`]
//! index; commits append + flush synchronously so a killed run is
//! resumable from everything it measured. Line order is registration/
//! commit order — re-opening reproduces the exact iteration order the
//! writing process saw, which is what keeps warm-started runs
//! deterministic.
//!
//! # Corruption recovery
//!
//! A crash mid-append leaves a truncated final line; stray editors leave
//! garbage ones. [`JsonFileDb::open`] recovers every intact line and
//! counts the rest ([`JsonFileDb::skipped_lines`]) instead of refusing
//! the whole file — losing one line must not orphan a campaign's worth
//! of history. Recovery never rewrites the file on open (an open must be
//! read-safe on a file it merely mis-identified); skipped lines linger
//! until the next [`JsonFileDb::compact`], whose canonical rewrite drops
//! them. Two guards bound the lossiness: a non-empty file where *no*
//! line parses is rejected as not-a-tuning-db (opening the wrong path
//! must never append records into someone's unrelated file), and
//! workload-*registry* damage (a registration line missing from the
//! middle of the file) fails the open outright — recovering past it
//! would silently drop every later workload's intact records, and a
//! subsequent compaction would make that loss permanent.
//!
//! # Auto-GC
//!
//! With [`JsonFileDb::set_auto_gc`], a commit that pushes the file past
//! `max_bytes` triggers an in-place [`JsonFileDb::compact`] (only when
//! the plan would actually drop something, so a file of all-live records
//! is not rewritten once per commit). Off by default: auto-GC shrinks
//! the candidate-dedup set, which is a policy choice, not a default.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::db::compact::{is_stale, keep_mask, CompactionPolicy, CompactionReport};
use crate::db::memory::InMemoryDb;
use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry, WorkloadId};
use crate::telemetry::{self, Counter};
use crate::util::json::Json;

/// Size-triggered GC configuration (see [`JsonFileDb::set_auto_gc`]).
#[derive(Debug, Clone)]
pub struct AutoGc {
    /// Compact when a commit leaves the file larger than this. When the
    /// live records alone (top-k + failures) already exceed the budget,
    /// the runtime ratchets this up to twice the current file size so
    /// the (futile) plan is not recomputed on every commit.
    pub max_bytes: u64,
    pub policy: CompactionPolicy,
}

/// Result of replaying a JSONL file into an in-memory index without
/// opening it for writing — shared by [`JsonFileDb::open`] and the
/// read-only serving loader ([`crate::serve::ServingCache::load`]).
pub(crate) struct LoadedIndex {
    pub mem: InMemoryDb,
    /// Lines that failed to parse/apply and were skipped.
    pub skipped: usize,
    /// `file:line: error` for the first few skipped lines.
    pub notes: Vec<String>,
    /// Whether the file ends in a newline (false after a crash truncated
    /// the final line — the next append must not concatenate onto it).
    pub ends_with_newline: bool,
}

/// Cap on retained skip diagnostics (the count is always exact).
const MAX_SKIP_NOTES: usize = 8;

/// Per-line recovery outcome: applied, or skipped with a reason. A
/// `Result::Err` from [`apply_line`] is *fatal* to the whole open.
enum LineOutcome {
    Applied,
    Skipped(String),
}

/// Parse and apply one JSONL line against the index under construction.
///
/// Record-level damage is skippable: losing one record loses one
/// measurement. Registry-level damage is NOT — an intact workload line
/// that no longer fits the registry (out-of-order id, duplicate key)
/// proves an *earlier* registration went missing, and "recovering" past
/// it would misbind or silently drop every later workload's records
/// (and a subsequent compaction would make that loss permanent). That
/// case fails the open instead.
fn apply_line(mem: &mut InMemoryDb, line: &str) -> Result<LineOutcome, String> {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return Ok(LineOutcome::Skipped(e)),
    };
    match j.get("kind").and_then(Json::as_str) {
        Some("workload") => {
            let entry = match WorkloadEntry::from_json(&j) {
                Ok(e) => e,
                Err(e) => return Ok(LineOutcome::Skipped(format!("workload line: {e}"))),
            };
            mem.insert_entry(entry)
                .map_err(|e| format!("workload registry damaged ({e}); refusing lossy recovery"))?;
            Ok(LineOutcome::Applied)
        }
        Some("record") => {
            let rec = match TuningRecord::from_json(&j) {
                Ok(r) => r,
                Err(e) => return Ok(LineOutcome::Skipped(format!("record line: {e}"))),
            };
            if rec.workload >= mem.num_workloads() {
                return Ok(LineOutcome::Skipped(format!(
                    "record references unknown workload {}",
                    rec.workload
                )));
            }
            mem.commit_record(rec);
            Ok(LineOutcome::Applied)
        }
        other => Ok(LineOutcome::Skipped(format!("unknown line kind {other:?}"))),
    }
}

/// Replay `path` into an index, recovering over corrupt lines. Errors
/// on I/O failure, on a non-empty file yielding no recognizable line at
/// all (wrong file), and on workload-registry damage (see
/// [`apply_line`]). A missing file is an empty index.
pub(crate) fn read_index(path: &Path) -> Result<LoadedIndex, String> {
    let mut out = LoadedIndex {
        mem: InMemoryDb::new(),
        skipped: 0,
        notes: Vec::new(),
        ends_with_newline: true,
    };
    if !path.exists() {
        return Ok(out);
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    out.ends_with_newline = text.is_empty() || text.ends_with('\n');
    let mut recognized = 0usize;
    for (no, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match apply_line(&mut out.mem, line) {
            Ok(LineOutcome::Applied) => recognized += 1,
            Ok(LineOutcome::Skipped(e)) => {
                out.skipped += 1;
                if out.notes.len() < MAX_SKIP_NOTES {
                    out.notes.push(format!("{}:{}: {e}", path.display(), no + 1));
                }
            }
            Err(e) => return Err(format!("{}:{}: {e}", path.display(), no + 1)),
        }
    }
    if recognized == 0 && out.skipped > 0 {
        return Err(format!(
            "{}: no recognizable tuning-db lines ({} unparseable) — refusing to treat it as a database",
            path.display(),
            out.skipped
        ));
    }
    Ok(out)
}

/// A cheap change signature for a database file: `(length, mtime,
/// content fingerprint)`. The JSONL write path is append-only (and
/// compaction rewrites change length in practice), so an unchanged
/// signature means "nothing to re-index" for a cross-process watcher —
/// the probe costs one `stat` plus three bounded reads, no parse.
///
/// `(len, mtime)` alone is not enough: a compaction's atomic rename can
/// land a same-length rewrite inside the same mtime tick on coarse-mtime
/// filesystems, and a watcher keyed on those two fields would serve the
/// stale snapshot forever. The content fingerprint (an FNV-1a hash over
/// the head, middle, and tail [`PROBE_CHUNK`]-byte windows) discriminates
/// that case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileSignature {
    pub len: u64,
    /// Modification time as nanoseconds since the epoch (0 when the
    /// platform reports a pre-epoch or unavailable mtime — `len` and the
    /// fingerprint still catch every append and rewrite).
    pub mtime_nanos: u128,
    /// FNV-1a over the head/middle/tail windows of the file (0 when the
    /// file cannot be opened between the `stat` and the read).
    pub content_fp: u64,
}

/// Bytes sampled per window (head, middle, tail) by the probe
/// fingerprint. Large enough that any realistic JSONL rewrite perturbs
/// at least one window — record lines are ~150 bytes — while keeping a
/// probe three small reads.
pub const PROBE_CHUNK: u64 = 1024;

fn fnv1a_eat(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h = (*h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
    }
}

/// Hash the head/middle/tail windows of the file at `path`. Best-effort:
/// a file that vanishes between `stat` and read fingerprints as 0, and
/// the next poll re-probes.
fn content_fingerprint(path: &Path, len: u64) -> u64 {
    use std::io::{Read as _, Seek as _, SeekFrom};
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let Ok(mut f) = File::open(path) else {
        return 0;
    };
    let mut window = |f: &mut File, start: u64, h: &mut u64| {
        let mut buf = Vec::with_capacity(PROBE_CHUNK as usize);
        if f.seek(SeekFrom::Start(start)).is_ok() {
            let _ = f.by_ref().take(PROBE_CHUNK).read_to_end(&mut buf);
            fnv1a_eat(h, &buf);
        }
        // Window separator, so shifted content cannot alias.
        fnv1a_eat(h, &[0x1f]);
    };
    window(&mut f, 0, &mut h);
    if len > PROBE_CHUNK {
        window(&mut f, len - PROBE_CHUNK, &mut h);
    }
    if len > 2 * PROBE_CHUNK {
        window(&mut f, len / 2 - PROBE_CHUNK / 2, &mut h);
    }
    // Head + tail + middle cover every byte of files up to
    // 3 * PROBE_CHUNK; larger files are sampled (any realistic JSONL
    // rewrite moves bytes in at least one window, and `len` is a
    // separate signature field anyway).
    h
}

/// Probe the change signature of `path`; `None` when the file is absent
/// or unreadable.
pub fn probe(path: impl AsRef<Path>) -> Option<FileSignature> {
    let path = path.as_ref();
    let md = std::fs::metadata(path).ok()?;
    let mtime_nanos = md
        .modified()
        .ok()
        .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    Some(FileSignature {
        len: md.len(),
        mtime_nanos,
        content_fp: content_fingerprint(path, md.len()),
    })
}

/// Load a JSONL database file into a read-only in-memory index: no
/// append handle is opened and the file is never created or modified
/// (works off a read-only mount). Returns the index plus the number of
/// corrupt lines recovered over; a missing file loads as an empty index.
/// This is how a *donor* database is opened for cross-target transfer —
/// reading priors from an archive must never register the destination
/// workload into it.
pub fn load_readonly(path: impl AsRef<Path>) -> Result<(InMemoryDb, usize), String> {
    let loaded = read_index(path.as_ref())?;
    Ok((loaded.mem, loaded.skipped))
}

/// File-backed tuning database (`--db path.jsonl`).
pub struct JsonFileDb {
    path: PathBuf,
    file: File,
    mem: InMemoryDb,
    /// Corrupt lines recovered over at open time.
    skipped: usize,
    skip_notes: Vec<String>,
    /// The file ends mid-line (crash-truncated tail): the next append
    /// must start on a fresh line or it would corrupt itself too.
    needs_newline: bool,
    auto_gc: Option<AutoGc>,
    /// Monotonic count of lines appended through this handle (workload
    /// registrations + record commits). A serving process holding the
    /// same handle can compare this against the value captured at its
    /// last snapshot build to refresh on change instead of on a timer;
    /// cross-process watchers use [`probe`] instead.
    commit_counter: u64,
    /// Process-wide telemetry handles ([`telemetry::global`]), cached at
    /// open so the commit hot path pays one relaxed atomic increment and
    /// never touches the registry mutex. Cumulative across every handle
    /// in the process — `/metrics` observability, not per-file state.
    tel_commits: Arc<Counter>,
    tel_compactions: Arc<Counter>,
}

impl JsonFileDb {
    /// Open (or create) a JSONL database file. Parent directories are
    /// created. Corrupt lines (truncated final line after a crash,
    /// interleaved garbage) are skipped and counted — see
    /// [`Self::skipped_lines`] — rather than failing the open; only I/O
    /// errors and files with no recognizable line at all are errors.
    pub fn open(path: impl AsRef<Path>) -> Result<JsonFileDb, String> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
            }
        }
        let loaded = read_index(&path)?;
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        let m = telemetry::global();
        Ok(JsonFileDb {
            path,
            file,
            mem: loaded.mem,
            skipped: loaded.skipped,
            skip_notes: loaded.notes,
            needs_newline: !loaded.ends_with_newline,
            auto_gc: None,
            commit_counter: 0,
            tel_commits: m.counter(
                "db_commits_total",
                "lines appended to tuning databases (registrations + record commits)",
            ),
            tel_compactions: m.counter("db_compactions_total", "database compaction rewrites"),
        })
    }

    /// Lines appended through this handle since open (registrations +
    /// commits). Monotonic; never reset, not even by compaction.
    pub fn commit_counter(&self) -> u64 {
        self.commit_counter
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Corrupt lines skipped while opening (0 for a healthy file). The
    /// skipped bytes stay in the file until the next [`Self::compact`].
    pub fn skipped_lines(&self) -> usize {
        self.skipped
    }

    /// `file:line: error` diagnostics for the first few skipped lines.
    pub fn skip_notes(&self) -> &[String] {
        &self.skip_notes
    }

    /// Enable (`Some`) or disable (`None`) size-triggered auto-GC.
    pub fn set_auto_gc(&mut self, gc: Option<AutoGc>) {
        self.auto_gc = gc;
    }

    /// Size of the backing file in bytes (0 if unreadable).
    pub fn file_len(&self) -> u64 {
        std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0)
    }

    /// All records across workloads in commit order (the compaction
    /// planner's view; also backs the stale-rules refusal gate).
    pub(crate) fn records(&self) -> &[TuningRecord] {
        self.mem.records()
    }

    /// Rewrite the file atomically with only the [`keep_mask`] survivors
    /// (top-k successful records per workload + every failure), in
    /// canonical serialization: temp file in the same directory, fsync,
    /// rename over the original. The in-memory index is pruned to match,
    /// so the open handle and a fresh re-open agree. Skipped corrupt
    /// lines and blank lines do not survive the rewrite.
    pub fn compact(&mut self, policy: &CompactionPolicy) -> Result<CompactionReport, String> {
        let bytes_before = self.file_len();
        let mask = keep_mask(self.mem.records(), policy);
        let kept: Vec<TuningRecord> = self
            .mem
            .records()
            .iter()
            .zip(&mask)
            .filter(|(_, keep)| **keep)
            .map(|(r, _)| r.clone())
            .collect();
        let dropped = mask.len() - kept.len();
        let kept_failures = kept.iter().filter(|r| r.is_failed()).count();
        let stale_dropped = self.mem.records().iter().filter(|r| is_stale(r, policy)).count();

        let mut tmp_name = self.path.file_name().unwrap_or_default().to_os_string();
        tmp_name.push(".compact-tmp");
        let tmp = self.path.with_file_name(tmp_name);
        let write_all = || -> std::io::Result<()> {
            let mut f = File::create(&tmp)?;
            for e in self.mem.workload_entries() {
                writeln!(f, "{}", e.to_json().to_string())?;
            }
            for r in &kept {
                writeln!(f, "{}", r.to_json().to_string())?;
            }
            f.sync_all()
        };
        if let Err(e) = write_all() {
            let _ = std::fs::remove_file(&tmp);
            return Err(format!("compact write {}: {e}", tmp.display()));
        }
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| format!("compact rename {} -> {}: {e}", tmp.display(), self.path.display()))?;
        // Past the rename, failure is fatal rather than an Err: the old
        // handle points at the now-unlinked inode, so carrying on would
        // have every later append land in a file nobody can ever open —
        // exactly the silent-record-loss append_line refuses to allow.
        self.file = OpenOptions::new().append(true).open(&self.path).unwrap_or_else(|e| {
            panic!("tuning db {} unusable after compaction (reopen failed: {e})", self.path.display())
        });
        self.needs_newline = false;
        let corrupt_dropped = std::mem::take(&mut self.skipped);
        self.skip_notes.clear();
        self.mem.replace_records(kept);
        self.tel_compactions.inc();
        Ok(CompactionReport {
            kept: self.mem.num_records(),
            dropped,
            kept_failures,
            stale_dropped,
            corrupt_dropped,
            bytes_before,
            bytes_after: self.file_len(),
        })
    }

    /// Append one JSON line and flush. Persistence failure is fatal: a
    /// tuning run that silently stops recording would poison every
    /// warm-started run after it.
    fn append_line(&mut self, j: &Json) {
        let line = j.to_string();
        debug_assert!(!line.contains('\n'), "JSONL line must be newline-free");
        // A file ending in a crash-truncated partial line needs a fresh
        // line first, or this append would corrupt itself too (the
        // partial tail is skipped on every open until compaction).
        let res = if self.needs_newline {
            self.needs_newline = false;
            writeln!(self.file).and_then(|()| writeln!(self.file, "{line}"))
        } else {
            writeln!(self.file, "{line}")
        };
        res.and_then(|()| self.file.flush())
            .unwrap_or_else(|e| panic!("tuning db append to {} failed: {e}", self.path.display()));
        self.commit_counter += 1;
        self.tel_commits.inc();
    }

    /// Group commit: append a whole batch of records with a single write
    /// and a single flush, then run the auto-GC check once for the batch.
    /// Equivalent to committing each record in order (same bytes, same
    /// index state, same crash-recovery properties — every line is still
    /// a self-contained record), but one syscall pair instead of one per
    /// record. This is the write amplification fix behind the sharded
    /// database's dedicated writer
    /// ([`crate::db::sharded::group_commit_writer`]).
    pub fn commit_batch(&mut self, recs: Vec<TuningRecord>) {
        if recs.is_empty() {
            return;
        }
        let mut buf = String::new();
        if self.needs_newline {
            self.needs_newline = false;
            buf.push('\n');
        }
        for r in &recs {
            let line = r.to_json().to_string();
            debug_assert!(!line.contains('\n'), "JSONL line must be newline-free");
            buf.push_str(&line);
            buf.push('\n');
        }
        self.file
            .write_all(buf.as_bytes())
            .and_then(|()| self.file.flush())
            .unwrap_or_else(|e| panic!("tuning db append to {} failed: {e}", self.path.display()));
        self.commit_counter += recs.len() as u64;
        self.tel_commits.add(recs.len() as u64);
        for r in recs {
            self.mem.commit_record(r);
        }
        self.maybe_auto_gc();
    }
}

impl Database for JsonFileDb {
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId {
        if let Some(id) = self.mem.find_workload(shash, target) {
            return id;
        }
        let id = self.mem.register_workload(name, shash, target);
        let entry = WorkloadEntry {
            id,
            name: name.to_string(),
            shash,
            target: target.to_string(),
        };
        self.append_line(&entry.to_json());
        id
    }

    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId> {
        self.mem.find_workload(shash, target)
    }

    fn workload_entries(&self) -> Vec<WorkloadEntry> {
        self.mem.workload_entries()
    }

    fn commit_record(&mut self, rec: TuningRecord) {
        self.append_line(&rec.to_json());
        self.mem.commit_record(rec);
        self.maybe_auto_gc();
    }

    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord> {
        self.mem.records_for(workload)
    }

    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64> {
        self.mem.candidate_hashes(workload)
    }

    fn num_records(&self) -> usize {
        self.mem.num_records()
    }

    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        self.mem.has_candidate(workload, cand_hash)
    }
}

impl JsonFileDb {
    /// Size-triggered auto-GC check, run after every commit (single or
    /// batched). See [`Self::set_auto_gc`] for the policy discussion.
    fn maybe_auto_gc(&mut self) {
        if let Some(gc) = self.auto_gc.clone() {
            if self.file_len() > gc.max_bytes {
                if self.skipped > 0 {
                    // Compacting now would permanently drop the corrupt
                    // lines the open recovered over — the CLI refuses
                    // that without `--repair`, and auto-GC must not be
                    // the back door. Stand down for this run.
                    crate::log_warn!(
                        "tuning db auto-GC paused: {} corrupt line(s) recovered at open; \
                         run `db compact --repair` first",
                        self.skipped
                    );
                    self.auto_gc = None;
                    return;
                }
                // Rewrite only when the plan actually shrinks: a file of
                // all-live records must not be rewritten on every commit.
                let droppable = keep_mask(self.mem.records(), &gc.policy).iter().any(|&k| !k);
                if droppable {
                    match self.compact(&gc.policy) {
                        Ok(report) if report.bytes_after.saturating_mul(2) > gc.max_bytes => {
                            // The compacted floor is at (or within 2x of)
                            // the budget: without a ratchet the file
                            // re-crosses the trigger after a commit or
                            // two and every commit pays a full rewrite.
                            // Re-arm at double the compacted size so the
                            // file must grow meaningfully between GCs.
                            if let Some(gc) = &mut self.auto_gc {
                                gc.max_bytes = report.bytes_after.saturating_mul(2).max(gc.max_bytes);
                            }
                        }
                        Ok(_) => {}
                        Err(e) => {
                            // A pre-rename failure (tmp write) leaves the
                            // file untouched — recoverable, but retrying
                            // every commit would spam the same failure,
                            // so GC stands down.
                            crate::log_warn!("tuning db auto-GC failed (disabled for this run): {e}");
                            self.auto_gc = None;
                        }
                    }
                } else {
                    // Nothing to drop: top-k + failures alone exceed the
                    // budget. Ratchet so the (futile) plan is not
                    // recomputed on every commit forever.
                    if let Some(gc) = &mut self.auto_gc {
                        gc.max_bytes = self.file_len().saturating_mul(2);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Inst, Trace};

    /// Unique temp path per test (process id + name), cleaned up by Guard.
    fn tmp(name: &str) -> (PathBuf, Guard) {
        let p = std::env::temp_dir().join(format!("ms-dbtest-{}-{name}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        (p.clone(), Guard(p))
    }

    struct Guard(PathBuf);
    impl Drop for Guard {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    fn rec(workload: WorkloadId, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace {
                insts: vec![Inst::GetBlock {
                    name: "blk with space".into(),
                    out: 0,
                }],
            },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 7,
            round: 1,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        }
    }

    #[test]
    fn reopen_restores_registry_and_records() {
        let (path, _g) = tmp("reopen");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 11, "cpu");
            let b = db.register_workload("B", 22, "gpu");
            db.commit_record(rec(a, 1, Some(3.0)));
            db.commit_record(rec(b, 2, Some(1.0)));
            db.commit_record(rec(a, 3, None));
        }
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.workload_entries().len(), 2);
        assert_eq!(db.num_records(), 3);
        assert_eq!(db.skipped_lines(), 0);
        assert_eq!(db.find_workload(11, "cpu"), Some(0));
        assert_eq!(db.candidate_hashes(0), vec![1, 3]);
        assert_eq!(db.best_latency(0), Some(3.0));
        assert_eq!(db.best_latency(1), Some(1.0));
        assert!(db.has_candidate(0, 3), "failed candidate persisted for dedup");
    }

    #[test]
    fn appends_accumulate_across_opens() {
        let (path, _g) = tmp("accumulate");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 5, "cpu");
            db.commit_record(rec(a, 1, Some(2.0)));
        }
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            // Re-registration must not duplicate the registry line.
            let a = db.register_workload("A", 5, "cpu");
            assert_eq!(a, 0);
            db.commit_record(rec(a, 2, Some(1.5)));
        }
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.workload_entries().len(), 1);
        assert_eq!(db.candidate_hashes(0), vec![1, 2]);
        assert_eq!(db.best_latency(0), Some(1.5));
    }

    #[test]
    fn corrupt_line_is_skipped_and_counted() {
        let (path, _g) = tmp("corrupt");
        let good = "{\"kind\":\"workload\",\"id\":0,\"name\":\"A\",\"shash\":\"05\",\"target\":\"cpu\"}";
        std::fs::write(&path, format!("{good}\nnot json\n")).unwrap();
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.workload_entries().len(), 1);
        assert_eq!(db.skipped_lines(), 1);
        assert!(db.skip_notes()[0].contains(":2:"), "note should name the line: {:?}", db.skip_notes());
    }

    #[test]
    fn record_for_unknown_workload_is_skipped() {
        let (path, _g) = tmp("orphan");
        let good = "{\"kind\":\"workload\",\"id\":0,\"name\":\"A\",\"shash\":\"05\",\"target\":\"cpu\"}";
        let orphan = rec(4, 1, Some(1.0)).to_json().to_string();
        std::fs::write(&path, format!("{good}\n{orphan}\n")).unwrap();
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.num_records(), 0);
        assert_eq!(db.skipped_lines(), 1);
        assert!(db.skip_notes()[0].contains("unknown workload"), "{:?}", db.skip_notes());
    }

    #[test]
    fn damaged_registry_fails_open_instead_of_lossy_recovery() {
        // Workload A's line survives, B's line is destroyed, C's line is
        // intact: C's id no longer fits the registry, which proves a
        // registration went missing. Recovering would silently drop C's
        // (and B's) records — and compaction would then erase them for
        // good — so the open must refuse instead.
        let (path, _g) = tmp("registry");
        let entry = |id: usize, shash: u64| {
            WorkloadEntry {
                id,
                name: format!("w{id}"),
                shash,
                target: "cpu".into(),
            }
            .to_json()
            .to_string()
        };
        let text = format!("{}\nB's line got vandalized\n{}\n", entry(0, 1), entry(2, 3));
        std::fs::write(&path, text).unwrap();
        let err = JsonFileDb::open(&path).unwrap_err();
        assert!(err.contains("registry damaged"), "{err}");
        assert!(err.contains(":3:"), "error should name the intact-but-unplaceable line: {err}");
    }

    #[test]
    fn foreign_file_refused_entirely() {
        // Zero recognizable lines = this is not a tuning db; appending to
        // it would vandalize an unrelated file.
        let (path, _g) = tmp("foreign");
        std::fs::write(&path, "hello\nworld\n").unwrap();
        let err = JsonFileDb::open(&path).unwrap_err();
        assert!(err.contains("no recognizable"), "{err}");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "hello\nworld\n", "open must not touch the file");
    }

    #[test]
    fn truncated_final_line_recovers_and_future_appends_stay_parseable() {
        let (path, _g) = tmp("truncated");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 9, "cpu");
            db.commit_record(rec(a, 1, Some(2.0)));
            db.commit_record(rec(a, 2, Some(1.0)));
        }
        // Simulate a crash mid-append: chop the tail of the last line.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 10]).unwrap();
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            assert_eq!(db.num_records(), 1, "intact record must survive");
            assert_eq!(db.skipped_lines(), 1);
            assert_eq!(db.best_latency(0), Some(2.0));
            // Appending after a partial tail must start a fresh line.
            db.commit_record(rec(0, 3, Some(0.5)));
        }
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.num_records(), 2);
        assert_eq!(db.skipped_lines(), 1, "partial tail lingers until compaction");
        assert_eq!(db.best_latency(0), Some(0.5));
    }

    #[test]
    fn commit_batch_matches_per_record_commits_byte_for_byte() {
        let (path_a, _ga) = tmp("batch-a");
        let (path_b, _gb) = tmp("batch-b");
        let recs: Vec<TuningRecord> =
            (0..5u64).map(|i| rec(0, i, if i == 3 { None } else { Some(i as f64 + 1.0) })).collect();
        {
            let mut a = JsonFileDb::open(&path_a).unwrap();
            a.register_workload("A", 1, "cpu");
            for r in recs.clone() {
                a.commit_record(r);
            }
            let mut b = JsonFileDb::open(&path_b).unwrap();
            b.register_workload("A", 1, "cpu");
            b.commit_batch(recs.clone());
            assert_eq!(b.commit_counter(), a.commit_counter(), "batch counts every record");
            assert_eq!(b.num_records(), 5);
            assert_eq!(b.best_latency(0), Some(1.0));
            assert!(b.has_candidate(0, 3), "failure in the batch indexed for dedup");
        }
        assert_eq!(
            std::fs::read(&path_a).unwrap(),
            std::fs::read(&path_b).unwrap(),
            "group commit must write the same bytes as per-record commits"
        );
        // Empty batch: no write, no counter movement.
        let mut b = JsonFileDb::open(&path_b).unwrap();
        let len = b.file_len();
        b.commit_batch(Vec::new());
        assert_eq!(b.commit_counter(), 0);
        assert_eq!(b.file_len(), len);
    }

    #[test]
    fn commit_batch_after_truncated_tail_starts_fresh_line() {
        let (path, _g) = tmp("batch-truncated");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 9, "cpu");
            db.commit_record(rec(a, 1, Some(2.0)));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 4]).unwrap();
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            db.commit_batch(vec![rec(0, 2, Some(1.0)), rec(0, 3, Some(0.5))]);
        }
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.num_records(), 2, "batch records parse back past the partial tail");
        assert_eq!(db.best_latency(0), Some(0.5));
    }

    #[test]
    fn commit_counter_counts_appends_and_survives_compaction() {
        let (path, _g) = tmp("counter");
        let mut db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.commit_counter(), 0);
        let a = db.register_workload("A", 1, "cpu");
        db.commit_record(rec(a, 1, Some(2.0)));
        db.commit_record(rec(a, 2, Some(1.0)));
        assert_eq!(db.commit_counter(), 3, "registration + 2 commits");
        db.compact(&CompactionPolicy::keep_top(1)).unwrap();
        db.commit_record(rec(a, 3, Some(0.5)));
        assert_eq!(db.commit_counter(), 4, "monotonic across compaction");
    }

    #[test]
    fn probe_signature_changes_on_append_only() {
        let (path, _g) = tmp("probe");
        assert_eq!(probe(&path), None, "missing file probes as None");
        let mut db = JsonFileDb::open(&path).unwrap();
        let a = db.register_workload("A", 1, "cpu");
        let s1 = probe(&path).expect("file exists");
        let again = probe(&path).unwrap();
        assert_eq!(s1, again, "no write, no change");
        db.commit_record(rec(a, 1, Some(2.0)));
        let s2 = probe(&path).unwrap();
        assert_ne!(s1, s2, "append must change the signature");
        assert!(s2.len > s1.len);
    }

    #[test]
    fn probe_detects_same_length_rewrite() {
        // A compaction rename can land a same-length rewrite in the same
        // mtime tick on coarse-mtime filesystems; the content fingerprint
        // must still change (this is the `serve --watch` staleness fix).
        let (path, _g) = tmp("probe-rewrite");
        std::fs::write(&path, "abcdefghij\n").unwrap();
        let s1 = probe(&path).unwrap();
        std::fs::write(&path, "jihgfedcba\n").unwrap();
        let s2 = probe(&path).unwrap();
        assert_eq!(s1.len, s2.len, "test premise: same length");
        assert_ne!(s1.content_fp, s2.content_fp, "fingerprint missed a same-length rewrite");
        assert_ne!(s1, s2);
        // Files larger than one probe window: a tail-only change is seen.
        let big = "x".repeat(3 * PROBE_CHUNK as usize);
        std::fs::write(&path, format!("{big}A")).unwrap();
        let s3 = probe(&path).unwrap();
        std::fs::write(&path, format!("{big}B")).unwrap();
        let s4 = probe(&path).unwrap();
        assert_eq!(s3.len, s4.len);
        assert_ne!(s3.content_fp, s4.content_fp, "tail window change missed");
        // ...and a middle-window change too.
        let mut mid = format!("{big}{big}");
        let split = mid.len() / 2;
        mid.replace_range(split..split + 1, "Y");
        std::fs::write(&path, format!("{big}{big}")).unwrap();
        let s5 = probe(&path).unwrap();
        std::fs::write(&path, &mid).unwrap();
        let s6 = probe(&path).unwrap();
        assert_eq!(s5.len, s6.len);
        assert_ne!(s5.content_fp, s6.content_fp, "middle window change missed");
        // Identical bytes fingerprint identically (mtime may differ, but
        // the fingerprint itself is a pure function of content).
        std::fs::write(&path, "abcdefghij\n").unwrap();
        let s7 = probe(&path).unwrap();
        assert_eq!(s1.content_fp, s7.content_fp);
    }

    #[test]
    fn load_readonly_never_creates_or_touches_the_file() {
        let (path, _g) = tmp("readonly");
        // Missing file: empty index, file still absent.
        let (mem, skipped) = load_readonly(&path).unwrap();
        assert_eq!(mem.num_records(), 0);
        assert_eq!(skipped, 0);
        assert!(!path.exists(), "read-only load must not create the file");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 7, "cpu");
            db.commit_record(rec(a, 1, Some(2.0)));
        }
        let before = std::fs::read(&path).unwrap();
        let (mem, skipped) = load_readonly(&path).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(mem.num_records(), 1);
        assert_eq!(mem.find_workload(7, "cpu"), Some(0));
        assert_eq!(std::fs::read(&path).unwrap(), before, "read-only load modified the file");
    }

    #[test]
    fn blank_lines_tolerated() {
        let (path, _g) = tmp("blank");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            db.register_workload("A", 9, "cpu");
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push('\n');
        std::fs::write(&path, text).unwrap();
        let db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.workload_entries().len(), 1);
        assert_eq!(db.skipped_lines(), 0, "blank lines are not corruption");
    }

    #[test]
    fn compact_drops_dominated_records_and_is_atomic_in_effect() {
        let (path, _g) = tmp("compact");
        let mut db = JsonFileDb::open(&path).unwrap();
        let a = db.register_workload("A", 1, "cpu");
        for i in 0..10u64 {
            db.commit_record(rec(a, i, Some((i + 1) as f64)));
        }
        db.commit_record(rec(a, 100, None)); // failure: must survive
        let before = db.file_len();
        let report = db.compact(&CompactionPolicy::keep_top(3)).unwrap();
        assert_eq!(report.kept, 4, "3 best + 1 failure");
        assert_eq!(report.dropped, 7);
        assert_eq!(report.kept_failures, 1);
        assert!(report.bytes_after < before);
        // The live handle and a fresh open agree.
        assert_eq!(db.num_records(), 4);
        assert_eq!(db.best_latency(a), Some(1.0));
        assert!(db.has_candidate(a, 100), "failure hash kept for dedup");
        assert!(!db.has_candidate(a, 9), "dominated record dropped");
        let reopened = JsonFileDb::open(&path).unwrap();
        assert_eq!(reopened.num_records(), 4);
        assert_eq!(reopened.best_latency(a), Some(1.0));
        // No temp file left behind.
        let mut tmp_name = path.file_name().unwrap().to_os_string();
        tmp_name.push(".compact-tmp");
        assert!(!path.with_file_name(tmp_name).exists(), "compaction temp file left behind");
    }

    #[test]
    fn compact_then_commit_then_reopen_is_consistent() {
        let (path, _g) = tmp("compact-append");
        let mut db = JsonFileDb::open(&path).unwrap();
        let a = db.register_workload("A", 1, "cpu");
        for i in 0..6u64 {
            db.commit_record(rec(a, i, Some((i + 1) as f64)));
        }
        db.compact(&CompactionPolicy::keep_top(2)).unwrap();
        db.commit_record(rec(a, 50, Some(0.25)));
        let reopened = JsonFileDb::open(&path).unwrap();
        assert_eq!(reopened.num_records(), 3);
        assert_eq!(reopened.best_latency(a), Some(0.25));
    }

    #[test]
    fn auto_gc_triggers_on_size_and_keeps_best() {
        let (path, _g) = tmp("autogc");
        let mut db = JsonFileDb::open(&path).unwrap();
        let a = db.register_workload("A", 1, "cpu");
        db.set_auto_gc(Some(AutoGc {
            max_bytes: 2048,
            policy: CompactionPolicy::keep_top(4),
        }));
        for i in 0..200u64 {
            db.commit_record(rec(a, i, Some((i + 1) as f64)));
        }
        // One record line is ~150 bytes; 200 commits without GC would be
        // ~30 KB. The GC must have kept the file bounded...
        assert!(db.file_len() < 8192, "auto-GC never triggered: {} bytes", db.file_len());
        // Between triggers the file re-accumulates up to the byte budget,
        // so the index holds top-4 plus at most a budget's worth of
        // fresh commits — far below the 200 committed.
        assert!(db.num_records() <= 24, "index not pruned: {}", db.num_records());
        // ...without ever losing the best record.
        assert_eq!(db.best_latency(a), Some(1.0));
        let reopened = JsonFileDb::open(&path).unwrap();
        assert_eq!(reopened.best_latency(a), Some(1.0));
        assert_eq!(reopened.num_records(), db.num_records());
    }

    #[test]
    fn compaction_repairs_recovered_corruption() {
        let (path, _g) = tmp("repair");
        {
            let mut db = JsonFileDb::open(&path).unwrap();
            let a = db.register_workload("A", 9, "cpu");
            db.commit_record(rec(a, 1, Some(2.0)));
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("garbage line\n");
        std::fs::write(&path, text).unwrap();
        let mut db = JsonFileDb::open(&path).unwrap();
        assert_eq!(db.skipped_lines(), 1);
        db.compact(&CompactionPolicy::default()).unwrap();
        assert_eq!(db.skipped_lines(), 0);
        let reopened = JsonFileDb::open(&path).unwrap();
        assert_eq!(reopened.skipped_lines(), 0, "compaction should have dropped the garbage");
        assert_eq!(reopened.num_records(), 1);
    }
}
