//! Sharded JSONL persistence: one database spread over
//! `shard-NN.jsonl` files in a directory, routed by structural hash.
//!
//! A single append-only file serializes every writer behind one flush
//! path and makes compaction a stop-the-world rewrite of the whole
//! history. Sharding fixes both without changing a byte of the record
//! format: each shard file is itself a complete, standalone
//! [`JsonFileDb`] (workload registrations + records, local ids starting
//! at 0), and a workload lives in exactly the shard its structural hash
//! routes to ([`shard_of`]). Shards therefore never share a workload,
//! which is what makes per-shard compaction safe to run in parallel
//! ([`ShardedDb::compact_parallel`]) and per-shard serving snapshots
//! safe to refresh independently
//! ([`crate::serve::ShardedSnapshots`]).
//!
//! # Directory layout
//!
//! ```text
//! db/
//!   MANIFEST.json      {"kind":"manifest","shards":8,"version":1}
//!   shard-00.jsonl     standalone JSONL db (workloads with shash % 8 == 0)
//!   shard-01.jsonl     ...
//! ```
//!
//! The manifest pins the shard count — routing is `shash % shards`, so
//! the count can never change silently without orphaning records (a
//! re-shard is a [`migrate_from_file`]-style rewrite, never an in-place
//! reinterpretation). See `docs/DB_FORMAT.md` for the normative spec.
//!
//! # Global ids
//!
//! [`ShardedDb`] presents the same [`Database`] trait as every other
//! backend: callers see one registry with dense global
//! [`WorkloadId`]s. Globals are assigned in discovery order — on open,
//! shard-major (every workload of shard 0 in its local order, then
//! shard 1, ...); within a session, registration order. Records inside
//! a shard file carry that shard's *local* ids (each file stays a valid
//! standalone db); the mapping is translated at the trait boundary in
//! both directions. Per-workload record order — the order every
//! determinism contract is written against — is exactly the shard
//! file's commit order, unchanged by the mapping.
//!
//! # Group commit
//!
//! [`group_commit_writer`] is the dedicated writer: producers push
//! [`TuningRecord`]s (global ids) into a
//! [`crate::search::parallel::BoundedQueue`] and one writer thread
//! drains it in opportunistic batches, paying one write+flush per shard
//! per batch ([`ShardedDb::commit_batch`]) instead of one per record.
//! Commit order within the queue is preserved, so the on-disk bytes are
//! identical to per-record commits of the same sequence.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::db::compact::{is_stale, CompactionPolicy, CompactionReport};
use crate::db::json_file::{probe, read_index, FileSignature, JsonFileDb};
use crate::db::memory::InMemoryDb;
use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry, WorkloadId};
use crate::search::parallel::{parallel_map, BoundedQueue};
use crate::telemetry::{self, Counter};
use crate::util::json::Json;

/// Shard count used when a new sharded database is created without an
/// explicit `--shards`: small enough that a fresh db is not 64 empty
/// files, large enough that parallel compaction has real work units.
pub const DEFAULT_SHARDS: usize = 8;

/// Hard cap on the manifest shard count (a typo'd `--shards 100000`
/// must not create a hundred thousand files).
pub const MAX_SHARDS: usize = 256;

/// Manifest file name inside a sharded database directory. Its presence
/// is what [`is_sharded`] keys on.
pub const MANIFEST_FILE: &str = "MANIFEST.json";

/// Route a structural hash to its shard index: `shash % num_shards`.
/// Pure and stable across sessions — the property tests pin that the
/// same record always lands in the same shard file no matter which
/// process (or how many reopens) committed it.
///
/// # Examples
///
/// ```
/// use metaschedule::db::shard_of;
///
/// assert_eq!(shard_of(13, 4), 1);
/// // Stable: the route is a pure function of (hash, shard count).
/// assert_eq!(shard_of(13, 4), shard_of(13, 4));
/// // A shard count of 0 is treated as 1 — everything routes to shard 0.
/// assert_eq!(shard_of(13, 0), 0);
/// ```
pub fn shard_of(shash: u64, num_shards: usize) -> usize {
    (shash % num_shards.max(1) as u64) as usize
}

/// File name of shard `i` (`shard-00.jsonl`, `shard-01.jsonl`, ...).
///
/// ```
/// assert_eq!(metaschedule::db::shard_file_name(3), "shard-03.jsonl");
/// ```
pub fn shard_file_name(i: usize) -> String {
    format!("shard-{i:02}.jsonl")
}

/// Whether `path` looks like a sharded database directory (a directory
/// containing a [`MANIFEST_FILE`]).
pub fn is_sharded(path: impl AsRef<Path>) -> bool {
    path.as_ref().join(MANIFEST_FILE).is_file()
}

/// The sharded database's manifest: the shard count, pinned on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Manifest {
    /// Format version (currently 1).
    pub version: u64,
    /// Number of shard files; routing is `shash % shards`.
    pub shards: usize,
}

impl Manifest {
    /// Serialize to the manifest JSON object (`kind: "manifest"`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("manifest")),
            ("version", Json::num(self.version as f64)),
            ("shards", Json::num(self.shards as f64)),
        ])
    }

    /// Parse back from the manifest JSON object.
    pub fn from_json(j: &Json) -> Result<Manifest, String> {
        if j.get("kind").and_then(Json::as_str) != Some("manifest") {
            return Err("not a manifest object".into());
        }
        let version = crate::db::record::usize_field(j, "version")? as u64;
        let shards = crate::db::record::usize_field(j, "shards")?;
        if !(1..=MAX_SHARDS).contains(&shards) {
            return Err(format!("shard count {shards} out of range 1..={MAX_SHARDS}"));
        }
        Ok(Manifest { version, shards })
    }

    /// Read the manifest of the sharded db at `dir`.
    pub fn read(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = Json::parse(text.trim())
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Manifest::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Write the manifest atomically (temp file + fsync + rename), same
    /// discipline as record compaction: a crash mid-write must never
    /// leave a half-manifest that mis-routes every later lookup.
    pub fn write(&self, dir: &Path) -> Result<(), String> {
        use std::io::Write as _;
        let path = dir.join(MANIFEST_FILE);
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        let write_all = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            writeln!(f, "{}", self.to_json().to_string())?;
            f.sync_all()
        };
        if let Err(e) = write_all() {
            let _ = std::fs::remove_file(&tmp);
            return Err(format!("write {}: {e}", tmp.display()));
        }
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
    }
}

/// File-backed tuning database sharded by structural hash (`--db dir/`).
/// Implements [`Database`] with global workload ids; see the module docs
/// for the layout and id-mapping rules.
pub struct ShardedDb {
    dir: PathBuf,
    manifest: Manifest,
    shards: Vec<JsonFileDb>,
    /// Global registry view (entries carry *global* ids).
    entries: Vec<WorkloadEntry>,
    /// Global id -> (shard index, shard-local id).
    global: Vec<(usize, usize)>,
    /// `(shash, target)` -> global id lookup accelerator.
    by_key: HashMap<(u64, String), WorkloadId>,
    /// Process-wide count of records routed to a shard by structural
    /// hash (cached [`telemetry::global`] handle — one relaxed increment
    /// per routed record, no registry lock on the commit path).
    tel_routed: Arc<Counter>,
}

/// Refuse to claim a non-empty directory that is clearly not a sharded
/// tuning db (the directory-level analog of [`JsonFileDb`]'s
/// foreign-file refusal: opening the wrong path must never scatter
/// shard files into someone's unrelated directory).
fn validate_foreign_dir(dir: &Path) -> Result<(), String> {
    let Ok(read) = std::fs::read_dir(dir) else {
        return Ok(()); // unreadable dirs fail later with a better error
    };
    for entry in read.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        let is_ours = name == MANIFEST_FILE
            || name == format!("{MANIFEST_FILE}.tmp")
            || (name.starts_with("shard-")
                && (name.ends_with(".jsonl") || name.ends_with(".compact-tmp")));
        if !is_ours {
            return Err(format!(
                "{}: directory contains {name}, which is not part of a sharded tuning db — \
                 refusing to claim it",
                dir.display()
            ));
        }
    }
    Ok(())
}

impl ShardedDb {
    /// Open (or create) a sharded database directory. A missing or empty
    /// directory is initialized with `DEFAULT_SHARDS`; an existing
    /// manifest pins the shard count. Per-shard corruption recovery is
    /// [`JsonFileDb::open`]'s: corrupt record lines are skipped and
    /// counted, registry damage in any shard fails the whole open.
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardedDb, String> {
        ShardedDb::open_with(dir, None)
    }

    /// Create a new sharded database with an explicit shard count.
    /// Errors if the directory already holds a manifest (the count is
    /// pinned at creation; re-sharding is a migration, not a reopen).
    pub fn create(dir: impl AsRef<Path>, shards: usize) -> Result<ShardedDb, String> {
        let dir = dir.as_ref();
        if is_sharded(dir) {
            return Err(format!(
                "{}: already a sharded db (manifest present); the shard count cannot be changed in place",
                dir.display()
            ));
        }
        ShardedDb::open_with(dir, Some(shards))
    }

    fn open_with(dir: impl AsRef<Path>, shards: Option<usize>) -> Result<ShardedDb, String> {
        let dir = dir.as_ref().to_path_buf();
        if dir.is_file() {
            return Err(format!(
                "{}: is a single-file db; serve it directly or convert with `db migrate --out <dir>`",
                dir.display()
            ));
        }
        std::fs::create_dir_all(&dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
        let manifest = if is_sharded(&dir) {
            let m = Manifest::read(&dir)?;
            if let Some(n) = shards {
                if n != m.shards {
                    return Err(format!(
                        "{}: manifest pins {} shard(s); requested {n} (re-shard via `db migrate`)",
                        dir.display(),
                        m.shards
                    ));
                }
            }
            m
        } else {
            validate_foreign_dir(&dir)?;
            let n = shards.unwrap_or(DEFAULT_SHARDS);
            if !(1..=MAX_SHARDS).contains(&n) {
                return Err(format!("shard count {n} out of range 1..={MAX_SHARDS}"));
            }
            let m = Manifest { version: 1, shards: n };
            m.write(&dir)?;
            m
        };
        let mut shard_dbs = Vec::with_capacity(manifest.shards);
        for i in 0..manifest.shards {
            shard_dbs.push(JsonFileDb::open(dir.join(shard_file_name(i)))?);
        }
        let mut db = ShardedDb {
            dir,
            manifest,
            shards: shard_dbs,
            entries: Vec::new(),
            global: Vec::new(),
            by_key: HashMap::new(),
            tel_routed: telemetry::global().counter(
                "db_shard_routed_total",
                "records routed to a shard file by structural hash",
            ),
        };
        // Rebuild the global registry in shard-major discovery order,
        // verifying routing as we go: an intact workload line sitting in
        // the wrong shard file proves the layout was tampered with
        // (moved files, hand-edited manifest) and every later lookup
        // would silently miss it — registry damage, so the open refuses.
        for s in 0..db.manifest.shards {
            for e in db.shards[s].workload_entries() {
                let expect = shard_of(e.shash, db.manifest.shards);
                if expect != s {
                    return Err(format!(
                        "{}: workload {:016x} found in shard {s} but routes to shard {expect}; \
                         shard layout damaged, refusing lossy recovery",
                        db.dir.display(),
                        e.shash
                    ));
                }
                let gid = db.entries.len();
                db.by_key.insert((e.shash, e.target.clone()), gid);
                db.global.push((s, e.id));
                db.entries.push(WorkloadEntry { id: gid, ..e });
            }
        }
        Ok(db)
    }

    /// The database directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Shard count pinned by the manifest.
    pub fn num_shards(&self) -> usize {
        self.manifest.shards
    }

    /// Direct (read) access to one shard's standalone [`JsonFileDb`] —
    /// the per-shard serving snapshot builds from this. Workload ids in
    /// the returned handle are shard-local.
    pub fn shard(&self, i: usize) -> &JsonFileDb {
        &self.shards[i]
    }

    /// Corrupt lines recovered over across all shards at open time.
    pub fn skipped_lines(&self) -> usize {
        self.shards.iter().map(JsonFileDb::skipped_lines).sum()
    }

    /// `file:line: error` diagnostics across all shards.
    pub fn skip_notes(&self) -> Vec<String> {
        self.shards.iter().flat_map(|s| s.skip_notes().iter().cloned()).collect()
    }

    /// Total bytes across shard files (manifest excluded).
    pub fn file_len(&self) -> u64 {
        self.shards.iter().map(JsonFileDb::file_len).sum()
    }

    /// Lines appended through this handle across all shards since open.
    pub fn commit_counter(&self) -> u64 {
        self.shards.iter().map(JsonFileDb::commit_counter).sum()
    }

    /// All records across shards in shard-major order, with global
    /// workload ids (the stale-rules refusal gate's view).
    pub(crate) fn all_records(&self) -> Vec<TuningRecord> {
        let mut out = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            for r in shard.records() {
                let mut r = r.clone();
                r.workload = self.global_id_of(s, r.workload);
                out.push(r);
            }
        }
        out
    }

    fn global_id_of(&self, shard: usize, local: usize) -> WorkloadId {
        // `global` is small (one entry per workload); a linear scan is
        // fine off the hot path (records_for translates per call, not
        // per record — see below).
        self.global
            .iter()
            .position(|&(s, l)| s == shard && l == local)
            .expect("shard-local id registered at open or registration")
    }

    /// Group commit: split the batch by shard and pay one write + one
    /// flush per shard with records ([`JsonFileDb::commit_batch`]).
    /// Record order within each shard is batch order, so the resulting
    /// bytes match committing the same sequence record-by-record.
    /// `recs` carry global workload ids, like every [`Database`] call.
    pub fn commit_batch(&mut self, recs: Vec<TuningRecord>) {
        let mut per_shard: Vec<Vec<TuningRecord>> = vec![Vec::new(); self.manifest.shards];
        for mut r in recs {
            let (s, local) = *self
                .global
                .get(r.workload)
                .unwrap_or_else(|| panic!("record for unregistered workload {}", r.workload));
            r.workload = local;
            per_shard[s].push(r);
            self.tel_routed.inc();
        }
        for (s, batch) in per_shard.into_iter().enumerate() {
            if !batch.is_empty() {
                self.shards[s].commit_batch(batch);
            }
        }
    }

    /// Compact every shard sequentially; the aggregate report sums the
    /// per-shard reports. Each shard rewrite is individually atomic
    /// (temp + fsync + rename), so a crash between shards leaves every
    /// shard either fully old or fully new — never torn.
    pub fn compact(&mut self, policy: &CompactionPolicy) -> Result<CompactionReport, String> {
        self.compact_parallel(policy, 1)
    }

    /// Compact shards on up to `threads` OS threads (0 = one per shard).
    /// Safe because shards never share a workload: each rewrite is an
    /// independent [`JsonFileDb::compact`] with the same policy, and the
    /// thread count can never change what survives — only wall-clock.
    pub fn compact_parallel(
        &mut self,
        policy: &CompactionPolicy,
        threads: usize,
    ) -> Result<CompactionReport, String> {
        let shards = std::mem::take(&mut self.shards);
        let threads = if threads == 0 { shards.len() } else { threads };
        let results = parallel_map(shards, threads, |_, mut shard| {
            let report = shard.compact(policy);
            (shard, report)
        });
        let mut total = CompactionReport {
            kept: 0,
            dropped: 0,
            kept_failures: 0,
            stale_dropped: 0,
            corrupt_dropped: 0,
            bytes_before: 0,
            bytes_after: 0,
        };
        let mut first_err = None;
        for (shard, report) in results {
            // Always restore every shard handle, even past an error —
            // dropping one would orphan its records for this process.
            self.shards.push(shard);
            match report {
                Ok(r) => {
                    total.kept += r.kept;
                    total.dropped += r.dropped;
                    total.kept_failures += r.kept_failures;
                    total.stale_dropped += r.stale_dropped;
                    total.corrupt_dropped += r.corrupt_dropped;
                    total.bytes_before += r.bytes_before;
                    total.bytes_after += r.bytes_after;
                }
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(total),
        }
    }
}

impl Database for ShardedDb {
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId {
        if let Some(&gid) = self.by_key.get(&(shash, target.to_string())) {
            return gid;
        }
        let s = shard_of(shash, self.manifest.shards);
        let local = self.shards[s].register_workload(name, shash, target);
        let gid = self.entries.len();
        self.by_key.insert((shash, target.to_string()), gid);
        self.global.push((s, local));
        self.entries.push(WorkloadEntry {
            id: gid,
            name: name.to_string(),
            shash,
            target: target.to_string(),
        });
        gid
    }

    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId> {
        self.by_key.get(&(shash, target.to_string())).copied()
    }

    fn workload_entries(&self) -> Vec<WorkloadEntry> {
        self.entries.clone()
    }

    fn commit_record(&mut self, mut rec: TuningRecord) {
        let (s, local) = *self
            .global
            .get(rec.workload)
            .unwrap_or_else(|| panic!("record for unregistered workload {}", rec.workload));
        rec.workload = local;
        self.tel_routed.inc();
        self.shards[s].commit_record(rec);
    }

    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord> {
        let Some(&(s, local)) = self.global.get(workload) else {
            return Vec::new();
        };
        let mut recs = self.shards[s].records_for(local);
        for r in &mut recs {
            r.workload = workload;
        }
        recs
    }

    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64> {
        match self.global.get(workload) {
            Some(&(s, local)) => self.shards[s].candidate_hashes(local),
            None => Vec::new(),
        }
    }

    fn num_records(&self) -> usize {
        self.shards.iter().map(|s| s.num_records()).sum()
    }

    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        match self.global.get(workload) {
            Some(&(s, local)) => self.shards[s].has_candidate(local, cand_hash),
            None => false,
        }
    }
}

/// Migrate a single-file JSONL db into a fresh sharded directory.
/// Workloads keep their registration order (so global ids match the
/// source) and every workload's records keep their commit order, which
/// is why the migrated db answers `query_top_k`/`best_latency`
/// byte-identically — the property tests pin that. The source file is
/// read-only here; corrupt lines it carried are recovered over (and
/// reported in the returned count) but never copied.
pub fn migrate_from_file(
    src: impl AsRef<Path>,
    dest_dir: impl AsRef<Path>,
    shards: usize,
) -> Result<(ShardedDb, usize), String> {
    let src = src.as_ref();
    if !src.is_file() {
        return Err(format!("no single-file database at {}", src.display()));
    }
    let loaded = read_index(src)?;
    let mut out = ShardedDb::create(dest_dir, shards)?;
    if out.num_records() > 0 || !out.workload_entries().is_empty() {
        return Err(format!(
            "{}: destination is not empty; migrate into a fresh directory",
            out.dir().display()
        ));
    }
    let mut id_map = Vec::with_capacity(loaded.mem.num_workloads());
    for e in loaded.mem.workload_entries() {
        id_map.push(out.register_workload(&e.name, e.shash, &e.target));
    }
    let recs: Vec<TuningRecord> = loaded
        .mem
        .records()
        .iter()
        .map(|r| {
            let mut r = r.clone();
            r.workload = id_map[r.workload];
            r
        })
        .collect();
    out.commit_batch(recs);
    Ok((out, loaded.skipped))
}

/// The dedicated group-commit writer loop: drain `queue` until it is
/// closed, committing opportunistic batches of up to `max_batch` records
/// through [`ShardedDb::commit_batch`]. Blocks on the first record of a
/// batch ([`BoundedQueue::pop`]), then extends without blocking
/// ([`BoundedQueue::try_pop`]) — under load the batch fills and the
/// flush amortizes; idle, every record still commits immediately.
/// Returns the number of records committed. Run it on its own (scoped)
/// thread; producers push records carrying global workload ids.
pub fn group_commit_writer(
    db: &mut ShardedDb,
    queue: &BoundedQueue<TuningRecord>,
    max_batch: usize,
) -> usize {
    let max_batch = max_batch.max(1);
    let mut committed = 0usize;
    while let Some(first) = queue.pop() {
        let mut batch = vec![first];
        while batch.len() < max_batch {
            match queue.try_pop() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        committed += batch.len();
        db.commit_batch(batch);
    }
    committed
}

/// A file-backed database of either layout, auto-detected from the path:
/// a directory (or a path whose [`MANIFEST_FILE`] exists) opens sharded,
/// anything else opens as the classic single file. This is what the CLI
/// (`--db`) constructs, so every subcommand — `tune`, `db stats`,
/// `db top`, `serve` — works on both layouts through one handle.
///
/// ```no_run
/// use metaschedule::db::{AnyDb, Database};
///
/// // A directory (with MANIFEST.json) opens sharded; a file opens as
/// // single JSONL. Both answer the same `Database` queries.
/// let mut db = AnyDb::open("tune-db")?;
/// let wid = db.register_workload("GMM", 0xfeed_beef, "cpu");
/// println!("{} record(s) across {} shard(s)", db.num_records(), db.num_shards());
/// # let _ = wid;
/// # Ok::<(), String>(())
/// ```
pub enum AnyDb {
    /// Classic single-file JSONL db.
    Single(JsonFileDb),
    /// Sharded directory db.
    Sharded(ShardedDb),
}

impl AnyDb {
    /// Open `path`, auto-detecting the layout. A missing path creates a
    /// single-file db (the backward-compatible default); pre-create a
    /// directory (or `db migrate`) to get a sharded one.
    pub fn open(path: impl AsRef<Path>) -> Result<AnyDb, String> {
        let p = path.as_ref();
        if is_sharded(p) || p.is_dir() {
            Ok(AnyDb::Sharded(ShardedDb::open(p)?))
        } else {
            Ok(AnyDb::Single(JsonFileDb::open(p)?))
        }
    }

    /// Shard count: 1 for a single-file db.
    pub fn num_shards(&self) -> usize {
        match self {
            AnyDb::Single(_) => 1,
            AnyDb::Sharded(s) => s.num_shards(),
        }
    }

    /// Corrupt lines recovered over at open time (all shards).
    pub fn skipped_lines(&self) -> usize {
        match self {
            AnyDb::Single(f) => f.skipped_lines(),
            AnyDb::Sharded(s) => s.skipped_lines(),
        }
    }

    /// `file:line: error` diagnostics for the first few skipped lines.
    pub fn skip_notes(&self) -> Vec<String> {
        match self {
            AnyDb::Single(f) => f.skip_notes().to_vec(),
            AnyDb::Sharded(s) => s.skip_notes(),
        }
    }

    /// Total record bytes on disk (shard files summed; manifest excluded).
    pub fn file_len(&self) -> u64 {
        match self {
            AnyDb::Single(f) => f.file_len(),
            AnyDb::Sharded(s) => s.file_len(),
        }
    }

    /// The sharded backend, when that is what the path held.
    pub fn as_sharded(&self) -> Option<&ShardedDb> {
        match self {
            AnyDb::Single(_) => None,
            AnyDb::Sharded(s) => Some(s),
        }
    }
}

impl Database for AnyDb {
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId {
        match self {
            AnyDb::Single(f) => f.register_workload(name, shash, target),
            AnyDb::Sharded(s) => s.register_workload(name, shash, target),
        }
    }

    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId> {
        match self {
            AnyDb::Single(f) => f.find_workload(shash, target),
            AnyDb::Sharded(s) => s.find_workload(shash, target),
        }
    }

    fn workload_entries(&self) -> Vec<WorkloadEntry> {
        match self {
            AnyDb::Single(f) => f.workload_entries(),
            AnyDb::Sharded(s) => s.workload_entries(),
        }
    }

    fn commit_record(&mut self, rec: TuningRecord) {
        match self {
            AnyDb::Single(f) => f.commit_record(rec),
            AnyDb::Sharded(s) => s.commit_record(rec),
        }
    }

    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord> {
        match self {
            AnyDb::Single(f) => f.records_for(workload),
            AnyDb::Sharded(s) => s.records_for(workload),
        }
    }

    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64> {
        match self {
            AnyDb::Single(f) => f.candidate_hashes(workload),
            AnyDb::Sharded(s) => s.candidate_hashes(workload),
        }
    }

    fn num_records(&self) -> usize {
        match self {
            AnyDb::Single(f) => f.num_records(),
            AnyDb::Sharded(s) => s.num_records(),
        }
    }

    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        match self {
            AnyDb::Single(f) => f.has_candidate(workload, cand_hash),
            AnyDb::Sharded(s) => s.has_candidate(workload, cand_hash),
        }
    }
}

/// Compact a database path of either layout, with [`crate::db::compact_file`]'s
/// refusal semantics extended db-wide: corrupt lines recovered anywhere,
/// or a stale-rules spec matching any record in any shard, refuse
/// without `repair`. Sharded dbs compact their shards on up to
/// `threads` OS threads (0 = one per shard); single files ignore
/// `threads`.
pub fn compact_any(
    path: impl AsRef<Path>,
    policy: &CompactionPolicy,
    repair: bool,
    threads: usize,
) -> Result<CompactionReport, String> {
    let path = path.as_ref();
    if !is_sharded(path) && !path.is_dir() {
        return crate::db::compact::compact_file(path, policy, repair);
    }
    let mut db = ShardedDb::open(path)?;
    if db.skipped_lines() > 0 && !repair {
        return Err(format!(
            "{}: {} corrupt line(s) would be dropped permanently:\n  {}\nre-run with --repair to drop them",
            path.display(),
            db.skipped_lines(),
            db.skip_notes().join("\n  ")
        ));
    }
    if !repair {
        let stale_matches = db.all_records().iter().filter(|r| is_stale(r, policy)).count();
        if stale_matches > 0 {
            return Err(format!(
                "{}: --stale-rules would permanently drop {stale_matches} record(s) matching {:?}\nre-run with --repair to drop them",
                path.display(),
                policy.stale_rule_sets
            ));
        }
    }
    db.compact_parallel(policy, threads)
}

/// Load a database path of either layout into a read-only in-memory
/// index with *global* ids (shard-major discovery order) — nothing is
/// created or modified, so this works off a read-only mount. Returns the
/// index plus the number of corrupt lines recovered over. The serving
/// loader ([`crate::serve::ServingCache::load`]) is built on this.
pub fn load_readonly_any(path: impl AsRef<Path>) -> Result<(InMemoryDb, usize), String> {
    let path = path.as_ref();
    if !is_sharded(path) && !path.is_dir() {
        return crate::db::json_file::load_readonly(path);
    }
    let manifest = Manifest::read(path)?;
    let mut mem = InMemoryDb::new();
    let mut skipped = 0usize;
    for i in 0..manifest.shards {
        let loaded = read_index(&path.join(shard_file_name(i)))?;
        skipped += loaded.skipped;
        let mut id_map = Vec::with_capacity(loaded.mem.num_workloads());
        for e in loaded.mem.workload_entries() {
            id_map.push(mem.register_workload(&e.name, e.shash, &e.target));
        }
        for r in loaded.mem.records() {
            let mut r = r.clone();
            r.workload = id_map[r.workload];
            mem.commit_record(r);
        }
    }
    Ok((mem, skipped))
}

/// Change signature of a whole database path: one entry per constituent
/// file. Single file: `[probe(file)]`. Sharded: the manifest's
/// signature followed by every shard file's, in shard order — so a
/// write to shard 7 changes the signature even when shard 0 is
/// untouched, and a shard file appearing or vanishing changes it too
/// (`None` holds the place of an absent file). `None` overall when the
/// path does not exist at all. This is what `serve --watch` polls
/// ([`crate::serve::DbWatcher`]).
pub fn probe_db(path: impl AsRef<Path>) -> Option<Vec<Option<FileSignature>>> {
    let path = path.as_ref();
    if is_sharded(path) {
        let mut sigs = vec![probe(path.join(MANIFEST_FILE))];
        if let Ok(m) = Manifest::read(path) {
            for i in 0..m.shards {
                sigs.push(probe(path.join(shard_file_name(i))));
            }
        }
        return Some(sigs);
    }
    if path.is_file() {
        return Some(vec![probe(path)]);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Inst, Trace};

    /// Unique temp dir per test, removed recursively on drop.
    fn tmp_dir(name: &str) -> (PathBuf, DirGuard) {
        let p = std::env::temp_dir().join(format!("ms-sharddb-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        (p.clone(), DirGuard(p))
    }

    struct DirGuard(PathBuf);
    impl Drop for DirGuard {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn rec(workload: WorkloadId, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace {
                insts: vec![Inst::GetBlock { name: format!("b{cand}"), out: 0 }],
            },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 7,
            round: cand,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        }
    }

    #[test]
    fn manifest_roundtrips_and_validates() {
        let m = Manifest { version: 1, shards: 8 };
        let back = Manifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, m);
        let bad = Json::parse("{\"kind\":\"manifest\",\"version\":1,\"shards\":0}").unwrap();
        assert!(Manifest::from_json(&bad).is_err(), "0 shards rejected");
        let huge = Json::parse("{\"kind\":\"manifest\",\"version\":1,\"shards\":100000}").unwrap();
        assert!(Manifest::from_json(&huge).is_err(), "absurd shard count rejected");
    }

    #[test]
    fn routing_is_stable_and_partitioned() {
        for n in [1usize, 2, 7, 8, 64] {
            for shash in [0u64, 1, 7, 8, u64::MAX, 0xdead_beef] {
                let s = shard_of(shash, n);
                assert!(s < n);
                assert_eq!(s, shard_of(shash, n), "routing must be a pure function");
            }
        }
    }

    #[test]
    fn register_commit_reopen_across_shards() {
        let (dir, _g) = tmp_dir("reopen");
        {
            let mut db = ShardedDb::create(&dir, 4).unwrap();
            // shash 0..6 spread over shards 0..3 (mod 4).
            let ids: Vec<_> =
                (0..6u64).map(|h| db.register_workload(&format!("w{h}"), h, "cpu")).collect();
            assert_eq!(ids, (0..6).collect::<Vec<_>>(), "global ids are dense");
            for (i, &id) in ids.iter().enumerate() {
                db.commit_record(rec(id, 100 + i as u64, Some(1.0 + i as f64)));
                db.commit_record(rec(id, 200 + i as u64, None));
            }
            assert_eq!(db.num_records(), 12);
            // Re-registration is idempotent across the shard mapping.
            assert_eq!(db.register_workload("w3-again", 3, "cpu"), ids[3]);
        }
        let db = ShardedDb::open(&dir).unwrap();
        assert_eq!(db.num_shards(), 4, "manifest pins the count");
        assert_eq!(db.workload_entries().len(), 6);
        assert_eq!(db.num_records(), 12);
        assert_eq!(db.skipped_lines(), 0);
        for h in 0..6u64 {
            let id = db.find_workload(h, "cpu").expect("registered workload found");
            assert_eq!(db.best_latency(id), Some(1.0 + h as f64));
            assert!(db.has_candidate(id, 200 + h), "failure hash survives for dedup");
            let recs = db.records_for(id);
            assert_eq!(recs.len(), 2);
            assert!(recs.iter().all(|r| r.workload == id), "records carry global ids");
        }
        // The workload actually lives in the shard its hash routes to.
        for e in db.workload_entries() {
            let s = shard_of(e.shash, db.num_shards());
            assert!(db.shard(s).find_workload(e.shash, "cpu").is_some());
        }
    }

    #[test]
    fn shard_files_are_standalone_dbs() {
        let (dir, _g) = tmp_dir("standalone");
        {
            let mut db = ShardedDb::create(&dir, 2).unwrap();
            let a = db.register_workload("A", 2, "cpu"); // shard 0
            let b = db.register_workload("B", 3, "cpu"); // shard 1
            db.commit_record(rec(a, 1, Some(2.0)));
            db.commit_record(rec(b, 2, Some(1.0)));
        }
        // Each shard file opens as a plain JsonFileDb with local ids.
        let s0 = JsonFileDb::open(dir.join(shard_file_name(0))).unwrap();
        assert_eq!(s0.workload_entries().len(), 1);
        assert_eq!(s0.find_workload(2, "cpu"), Some(0), "local ids start at 0 per shard");
        let s1 = JsonFileDb::open(dir.join(shard_file_name(1))).unwrap();
        assert_eq!(s1.find_workload(3, "cpu"), Some(0));
        assert_eq!(s1.best_latency(0), Some(1.0));
    }

    #[test]
    fn misrouted_workload_fails_open() {
        let (dir, _g) = tmp_dir("misrouted");
        {
            let mut db = ShardedDb::create(&dir, 2).unwrap();
            db.register_workload("A", 2, "cpu");
        }
        // Simulate layout damage: move shard 0's content into shard 1.
        let s0 = dir.join(shard_file_name(0));
        let s1 = dir.join(shard_file_name(1));
        std::fs::rename(&s0, &s1).unwrap();
        let err = ShardedDb::open(&dir).unwrap_err();
        assert!(err.contains("routes to shard"), "{err}");
    }

    #[test]
    fn foreign_directory_refused() {
        let (dir, _g) = tmp_dir("foreign");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("notes.txt"), "hello").unwrap();
        let err = ShardedDb::open(&dir).unwrap_err();
        assert!(err.contains("refusing to claim"), "{err}");
        assert!(!is_sharded(&dir), "refusal must not drop a manifest into the dir");
    }

    #[test]
    fn create_refuses_existing_and_open_refuses_count_change() {
        let (dir, _g) = tmp_dir("pinned");
        let _ = ShardedDb::create(&dir, 2).unwrap();
        assert!(ShardedDb::create(&dir, 2).unwrap_err().contains("already"), "create is create-only");
        let err = ShardedDb::open_with(&dir, Some(4)).unwrap_err();
        assert!(err.contains("pins 2 shard"), "{err}");
        assert!(ShardedDb::open(&dir).is_ok(), "plain open accepts the pinned count");
    }

    #[test]
    fn commit_batch_groups_by_shard_and_matches_per_record_bytes() {
        let (dir_a, _ga) = tmp_dir("batch-a");
        let (dir_b, _gb) = tmp_dir("batch-b");
        let mut a = ShardedDb::create(&dir_a, 3).unwrap();
        let mut b = ShardedDb::create(&dir_b, 3).unwrap();
        for h in 0..5u64 {
            a.register_workload(&format!("w{h}"), h, "cpu");
            b.register_workload(&format!("w{h}"), h, "cpu");
        }
        let recs: Vec<TuningRecord> = (0..20u64)
            .map(|i| rec((i % 5) as usize, i, if i % 4 == 0 { None } else { Some(i as f64) }))
            .collect();
        for r in recs.clone() {
            a.commit_record(r);
        }
        b.commit_batch(recs);
        assert_eq!(a.num_records(), b.num_records());
        for i in 0..3 {
            let fa = std::fs::read(dir_a.join(shard_file_name(i))).unwrap();
            let fb = std::fs::read(dir_b.join(shard_file_name(i))).unwrap();
            assert_eq!(fa, fb, "shard {i}: group commit bytes differ from per-record commits");
        }
    }

    #[test]
    fn group_commit_writer_drains_concurrent_producers() {
        let (dir, _g) = tmp_dir("writer");
        let mut db = ShardedDb::create(&dir, 4).unwrap();
        for h in 0..8u64 {
            db.register_workload(&format!("w{h}"), h, "cpu");
        }
        let queue: BoundedQueue<TuningRecord> = BoundedQueue::new(16);
        let committed = std::thread::scope(|s| {
            let producer = |base: u64| {
                let queue = &queue;
                move || {
                    for i in 0..50u64 {
                        assert!(queue.push(rec(
                            ((base + i) % 8) as usize,
                            base * 1000 + i,
                            Some(1.0),
                        )));
                    }
                }
            };
            let p1 = s.spawn(producer(1));
            let p2 = s.spawn(producer(2));
            let writer = s.spawn(|| group_commit_writer(&mut db, &queue, 32));
            p1.join().unwrap();
            p2.join().unwrap();
            queue.close();
            writer.join().unwrap()
        });
        assert_eq!(committed, 100);
        assert_eq!(db.num_records(), 100);
        // Every record reached the shard its workload's hash routes to.
        for h in 0..8u64 {
            let s = shard_of(h, 4);
            let local = db.shard(s).find_workload(h, "cpu").expect("routed workload");
            assert!(!db.shard(s).records_for(local).is_empty());
        }
        // A reopen sees everything the writer flushed.
        drop(db);
        let back = ShardedDb::open(&dir).unwrap();
        assert_eq!(back.num_records(), 100);
        assert_eq!(back.skipped_lines(), 0);
    }

    #[test]
    fn migrate_preserves_ids_and_answers() {
        let (dir, _g) = tmp_dir("migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let src = dir.join("single.jsonl");
        {
            let mut db = JsonFileDb::open(&src).unwrap();
            for h in 0..6u64 {
                let id = db.register_workload(&format!("w{h}"), h, "cpu");
                db.commit_record(rec(id, 10 + h, Some(2.0 + h as f64)));
                db.commit_record(rec(id, 20 + h, Some(1.0 + h as f64)));
                db.commit_record(rec(id, 30 + h, None));
            }
        }
        let out_dir = dir.join("sharded");
        let (migrated, skipped) = migrate_from_file(&src, &out_dir, 4).unwrap();
        assert_eq!(skipped, 0);
        let src_db = JsonFileDb::open(&src).unwrap();
        assert_eq!(migrated.workload_entries().len(), src_db.workload_entries().len());
        for e in src_db.workload_entries() {
            let gid = migrated.find_workload(e.shash, &e.target).expect("workload migrated");
            assert_eq!(gid, e.id, "registration order preserved => ids match");
            assert_eq!(migrated.best_latency(gid), src_db.best_latency(e.id));
            let a = src_db.query_top_k(e.id, 8);
            let b = migrated.query_top_k(gid, 8);
            assert_eq!(a.len(), b.len());
            for (ra, rb) in a.iter().zip(&b) {
                assert_eq!(ra.to_json().to_string(), rb.to_json().to_string());
            }
        }
        // Migrating into a non-empty destination refuses.
        let err = migrate_from_file(&src, &out_dir, 4).unwrap_err();
        assert!(err.contains("already") || err.contains("not empty"), "{err}");
    }

    #[test]
    fn compact_parallel_matches_sequential_and_is_idempotent() {
        let (dir_a, _ga) = tmp_dir("cpar-a");
        let (dir_b, _gb) = tmp_dir("cpar-b");
        let policy = CompactionPolicy::keep_top(2);
        let fill = |dir: &Path| {
            let mut db = ShardedDb::create(dir, 4).unwrap();
            for h in 0..8u64 {
                let id = db.register_workload(&format!("w{h}"), h, "cpu");
                for i in 0..6u64 {
                    db.commit_record(rec(id, h * 100 + i, Some((i + 1) as f64)));
                }
                db.commit_record(rec(id, h * 100 + 99, None));
            }
            db
        };
        let mut a = fill(&dir_a);
        let mut b = fill(&dir_b);
        let ra = a.compact(&policy).unwrap();
        let rb = b.compact_parallel(&policy, 0).unwrap();
        assert_eq!(ra.kept, rb.kept);
        assert_eq!(ra.dropped, rb.dropped);
        assert_eq!(ra.kept_failures, rb.kept_failures);
        for i in 0..4 {
            let fa = std::fs::read(dir_a.join(shard_file_name(i))).unwrap();
            let fb = std::fs::read(dir_b.join(shard_file_name(i))).unwrap();
            assert_eq!(fa, fb, "shard {i}: thread count changed compaction output");
        }
        // Second pass is byte-idempotent per shard.
        let before: Vec<Vec<u8>> =
            (0..4).map(|i| std::fs::read(dir_b.join(shard_file_name(i))).unwrap()).collect();
        b.compact_parallel(&policy, 2).unwrap();
        for (i, prev) in before.iter().enumerate() {
            let now = std::fs::read(dir_b.join(shard_file_name(i))).unwrap();
            assert_eq!(&now, prev, "shard {i}: compaction not idempotent");
        }
        // Queries survive: top-2 per workload plus failure hash for dedup.
        for h in 0..8u64 {
            let id = b.find_workload(h, "cpu").unwrap();
            assert_eq!(b.query_top_k(id, 8).len(), 2);
            assert!(b.has_candidate(id, h * 100 + 99));
        }
    }

    #[test]
    fn any_db_autodetects_layout() {
        let (dir, _g) = tmp_dir("anydb");
        std::fs::create_dir_all(&dir).unwrap();
        let single = dir.join("one.jsonl");
        {
            let mut db = AnyDb::open(&single).unwrap();
            assert_eq!(db.num_shards(), 1);
            let id = db.register_workload("A", 5, "cpu");
            db.commit_record(rec(id, 1, Some(1.5)));
        }
        assert!(matches!(AnyDb::open(&single).unwrap(), AnyDb::Single(_)));
        let sharded_dir = dir.join("sharded");
        std::fs::create_dir_all(&sharded_dir).unwrap();
        {
            let mut db = AnyDb::open(&sharded_dir).unwrap();
            assert!(db.as_sharded().is_some(), "existing directory opens sharded");
            assert_eq!(db.num_shards(), DEFAULT_SHARDS);
            let id = db.register_workload("A", 5, "cpu");
            db.commit_record(rec(id, 1, Some(1.5)));
        }
        let back = AnyDb::open(&sharded_dir).unwrap();
        assert_eq!(back.num_records(), 1);
        assert_eq!(back.find_workload(5, "cpu"), Some(0));
        assert!(back.file_len() > 0);
    }

    #[test]
    fn load_readonly_any_merges_shards_with_global_ids() {
        let (dir, _g) = tmp_dir("ro");
        {
            let mut db = ShardedDb::create(&dir, 3).unwrap();
            for h in 0..5u64 {
                let id = db.register_workload(&format!("w{h}"), h, "cpu");
                db.commit_record(rec(id, h, Some(1.0 + h as f64)));
            }
        }
        let (mem, skipped) = load_readonly_any(&dir).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(mem.num_workloads(), 5);
        assert_eq!(mem.num_records(), 5);
        for h in 0..5u64 {
            let id = mem.find_workload(h, "cpu").expect("merged workload");
            assert_eq!(mem.best_latency(id), Some(1.0 + h as f64));
        }
        // Single-file paths go through the classic loader unchanged.
        let single = std::env::temp_dir()
            .join(format!("ms-sharddb-{}-ro-single.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&single);
        {
            let mut db = JsonFileDb::open(&single).unwrap();
            let id = db.register_workload("A", 9, "cpu");
            db.commit_record(rec(id, 3, Some(0.5)));
        }
        let (mem, _) = load_readonly_any(&single).unwrap();
        assert_eq!(mem.num_records(), 1);
        let _ = std::fs::remove_file(&single);
    }

    #[test]
    fn probe_db_sees_writes_to_any_shard() {
        let (dir, _g) = tmp_dir("probe");
        let mut db = ShardedDb::create(&dir, 8).unwrap();
        let before = probe_db(&dir).expect("sharded db probes");
        assert_eq!(before.len(), 9, "manifest + one signature per shard");
        // Route a workload to a specific late shard and write to it.
        let id = db.register_workload("late", 7, "cpu"); // 7 % 8 == shard 7
        db.commit_record(rec(id, 1, Some(1.0)));
        let after = probe_db(&dir).expect("sharded db probes");
        assert_ne!(before, after, "a write to shard 7 must change the signature");
        assert_eq!(before[1], after[1], "shard 0 untouched");
        assert_ne!(before[8], after[8], "shard 7 changed");
        // Missing path probes as None; single file as a one-element vec.
        assert!(probe_db(dir.join("nope.jsonl")).is_none());
    }
}
