//! Record GC / compaction: rewrite a grown database down to the records
//! that still earn their bytes — the top-k successful records per
//! workload (what [`crate::db::Database::query_top_k`] and the serving
//! layer actually read) plus **every** failed record (their candidate
//! hashes are the cross-session dedup set; dropping one would let a
//! warm-started search re-measure a known-invalid schedule).
//!
//! The plan is a pure function of the record list ([`keep_mask`]), so the
//! same logic backs three entry points: the `db compact` CLI
//! ([`compact_file`]), the size-triggered auto-GC inside
//! [`crate::db::JsonFileDb`]'s commit path, and the property tests that
//! pin the contract. Rewrites are atomic (temp file in the same
//! directory, fsync, rename) and canonicalizing (records re-serialize
//! through [`crate::db::TuningRecord::to_json`]), which is what makes
//! compaction idempotent byte-for-byte: the first pass canonicalizes,
//! the second is the identity.
//!
//! What compaction deliberately loses: the candidate hashes of dropped
//! *successful* records. A later warm start may re-measure a dominated
//! candidate it had already seen — a bounded cost, unlike losing a best
//! record (never dropped) or a failure hash (never dropped).

use crate::db::record::TuningRecord;

/// What to keep when compacting.
#[derive(Debug, Clone)]
pub struct CompactionPolicy {
    /// Successful records kept per workload (best-first). Failed records
    /// are always kept for dedup.
    pub top_k: usize,
    /// Rule-set specs whose records are dropped outright — successes
    /// *and* failures — because the space they were drawn from no longer
    /// exists (ROADMAP "registry-driven space invalidation"). Each spec
    /// matches per [`rule_set_matches`]: a full canonical label, its
    /// name-list part, its `#digest` part, or the empty string for
    /// pre-provenance records. Destructive, so [`compact_file`] refuses
    /// a non-empty match set without `repair`.
    pub stale_rule_sets: Vec<String>,
}

/// Default `top_k`: comfortably above the search's warm-start replay
/// depth (8) so compaction never degrades a warm start, while still
/// bounding the file.
pub const DEFAULT_TOP_K: usize = 32;

impl CompactionPolicy {
    /// The plain size-bounding policy: keep the best `top_k` per
    /// workload, drop nothing for provenance reasons.
    pub fn keep_top(top_k: usize) -> CompactionPolicy {
        CompactionPolicy { top_k, stale_rule_sets: Vec::new() }
    }
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        CompactionPolicy::keep_top(DEFAULT_TOP_K)
    }
}

/// Whether a stale-rules `spec` matches a record's canonical rule-set
/// `label` (`"name1,name2 #digest"`). Accepted spellings, so the CLI
/// value can be copied from `db stats` without shell-quoting the space:
/// the full label, the name-list part alone, or the `#digest` part
/// alone. The empty spec matches only pre-provenance records (empty
/// label) — `db compact --stale-rules -` spells it.
pub fn rule_set_matches(spec: &str, label: &str) -> bool {
    if spec == label {
        return true;
    }
    match label.split_once(" #") {
        Some((names, digest)) => match spec.strip_prefix('#') {
            Some(d) => d == digest,
            None => spec == names,
        },
        None => false,
    }
}

/// Whether `policy` marks a record's rule set stale.
pub(crate) fn is_stale(rec: &TuningRecord, policy: &CompactionPolicy) -> bool {
    policy.stale_rule_sets.iter().any(|s| rule_set_matches(s, &rec.rule_set))
}

/// Outcome of one compaction pass.
#[derive(Debug, Clone)]
pub struct CompactionReport {
    /// Records surviving (successful top-k + all failures).
    pub kept: usize,
    /// Records dropped (dominated successful records).
    pub dropped: usize,
    /// Failed records kept for cross-session dedup.
    pub kept_failures: usize,
    /// Records dropped because their rule set matched
    /// [`CompactionPolicy::stale_rule_sets`] (included in `dropped`).
    pub stale_dropped: usize,
    /// Corrupt lines the open had recovered over, now gone for good (the
    /// canonical rewrite does not carry unparseable bytes forward).
    pub corrupt_dropped: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

impl CompactionReport {
    /// One-line human rendering (the `db compact` CLI output).
    pub fn render(&self, path: &str) -> String {
        let mut out = format!(
            "compacted {path}: kept {} records ({} failures for dedup), dropped {}; {} -> {} bytes",
            self.kept, self.kept_failures, self.dropped, self.bytes_before, self.bytes_after
        );
        if self.stale_dropped > 0 {
            out.push_str(&format!(
                "\nstale_dropped: {} record(s) from retired rule set(s)",
                self.stale_dropped
            ));
        }
        if self.corrupt_dropped > 0 {
            out.push_str(&format!(
                "\nwarning: {} corrupt line(s) were dropped permanently",
                self.corrupt_dropped
            ));
        }
        out
    }
}

/// The compaction plan: `mask[i]` says whether `records[i]` survives.
/// Pure and order-preserving — survivors keep their relative commit
/// order, so `query_top_k` (stable sort, commit-order ties) answers
/// identically on the compacted set for any `k <= policy.top_k`.
pub fn keep_mask(records: &[TuningRecord], policy: &CompactionPolicy) -> Vec<bool> {
    let mut mask = vec![false; records.len()];
    // Group successful record indices per workload, in commit order.
    let mut by_workload: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, r) in records.iter().enumerate() {
        if is_stale(r, policy) {
            // Stale-space records drop outright — failures included:
            // "always keep failures" protects the dedup set, but a dedup
            // set for a space that no longer exists protects nothing.
            continue;
        }
        if r.is_failed() {
            mask[i] = true; // failures always survive (dedup set)
            continue;
        }
        match by_workload.iter_mut().find(|(w, _)| *w == r.workload) {
            Some((_, v)) => v.push(i),
            None => by_workload.push((r.workload, vec![i])),
        }
    }
    for (_, mut idxs) in by_workload {
        // Same criterion as `query_top_k`: ascending best latency, stable
        // sort so commit order breaks ties.
        idxs.sort_by(|&a, &b| {
            let la = records[a].best_latency().expect("failures filtered above");
            let lb = records[b].best_latency().expect("failures filtered above");
            la.total_cmp(&lb)
        });
        for &i in idxs.iter().take(policy.top_k) {
            mask[i] = true;
        }
    }
    mask
}

/// Compact a JSONL database file in place (atomically): open, rewrite
/// with the [`keep_mask`] survivors, rename over the original. Returns
/// the report; the file is untouched on error.
///
/// When the open recovered over corrupt lines, or when
/// `policy.stale_rule_sets` actually matches records, the rewrite would
/// drop data *permanently* — that destruction is refused unless `repair`
/// is set (the CLI's `--repair` switch), so a user always sees what they
/// are about to lose before losing it. A stale-rules spec that matches
/// nothing (e.g. a second pass over an already-cleaned file) needs no
/// confirmation, which keeps stale-rules compaction idempotent.
pub fn compact_file(
    path: impl AsRef<std::path::Path>,
    policy: &CompactionPolicy,
    repair: bool,
) -> Result<CompactionReport, String> {
    let path = path.as_ref();
    if !path.exists() {
        return Err(format!("no database at {}", path.display()));
    }
    let mut db = crate::db::JsonFileDb::open(path)?;
    if db.skipped_lines() > 0 && !repair {
        return Err(format!(
            "{}: {} corrupt line(s) would be dropped permanently:\n  {}\nre-run with --repair to drop them",
            path.display(),
            db.skipped_lines(),
            db.skip_notes().join("\n  ")
        ));
    }
    if !repair {
        let stale_matches = db.records().iter().filter(|r| is_stale(r, policy)).count();
        if stale_matches > 0 {
            return Err(format!(
                "{}: --stale-rules would permanently drop {stale_matches} record(s) matching {:?}\nre-run with --repair to drop them",
                path.display(),
                policy.stale_rule_sets
            ));
        }
    }
    db.compact(policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Database;
    use crate::trace::Trace;

    fn rec(workload: usize, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace { insts: vec![] },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 0,
            round: cand,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        }
    }

    #[test]
    fn keep_mask_keeps_top_k_and_all_failures() {
        let records = vec![
            rec(0, 1, Some(3.0)),
            rec(0, 2, None), // failure: always kept
            rec(0, 3, Some(1.0)),
            rec(0, 4, Some(2.0)),
            rec(1, 5, Some(9.0)),
        ];
        let mask = keep_mask(&records, &CompactionPolicy::keep_top(2));
        // Workload 0 keeps its two best (1.0, 2.0) + the failure; the 3.0
        // record is dominated and dropped. Workload 1 keeps its only record.
        assert_eq!(mask, vec![false, true, true, true, true]);
    }

    #[test]
    fn keep_mask_breaks_latency_ties_by_commit_order() {
        let records = vec![rec(0, 1, Some(2.0)), rec(0, 2, Some(2.0)), rec(0, 3, Some(2.0))];
        let mask = keep_mask(&records, &CompactionPolicy::keep_top(2));
        assert_eq!(mask, vec![true, true, false], "earliest committed ties must win");
    }

    #[test]
    fn keep_mask_on_survivors_is_identity() {
        let records = vec![
            rec(0, 1, Some(3.0)),
            rec(0, 2, None),
            rec(0, 3, Some(1.0)),
            rec(1, 4, Some(5.0)),
            rec(1, 5, Some(4.0)),
        ];
        let policy = CompactionPolicy::keep_top(1);
        let mask = keep_mask(&records, &policy);
        let survivors: Vec<TuningRecord> =
            records.into_iter().zip(&mask).filter(|(_, k)| **k).map(|(r, _)| r).collect();
        let mask2 = keep_mask(&survivors, &policy);
        assert!(mask2.iter().all(|&k| k), "compaction must be idempotent");
    }

    #[test]
    fn rule_set_matches_accepts_label_names_and_digest_spellings() {
        let label = "auto-inline,multi-level-tiling #1a2b3c4d";
        assert!(rule_set_matches(label, label));
        assert!(rule_set_matches("auto-inline,multi-level-tiling", label));
        assert!(rule_set_matches("#1a2b3c4d", label));
        assert!(!rule_set_matches("auto-inline", label));
        assert!(!rule_set_matches("#ffffffff", label));
        // Empty spec matches only pre-provenance (empty) labels.
        assert!(rule_set_matches("", ""));
        assert!(!rule_set_matches("", label));
        assert!(!rule_set_matches("auto-inline,multi-level-tiling", ""));
    }

    #[test]
    fn keep_mask_drops_stale_rule_sets_including_failures() {
        let with_rules = |mut r: TuningRecord, rules: &str| {
            r.rule_set = rules.to_string();
            r
        };
        let records = vec![
            with_rules(rec(0, 1, Some(1.0)), "live-rule #aaaaaaaa"),
            with_rules(rec(0, 2, Some(0.5)), "ghost-rule #bbbbbbbb"), // stale best
            with_rules(rec(0, 3, None), "ghost-rule #bbbbbbbb"),      // stale failure
            with_rules(rec(0, 4, None), "live-rule #aaaaaaaa"),       // live failure
        ];
        let policy = CompactionPolicy {
            top_k: 8,
            stale_rule_sets: vec!["ghost-rule".to_string()],
        };
        let mask = keep_mask(&records, &policy);
        assert_eq!(mask, vec![true, false, false, true]);
        // Idempotent: the survivors contain no stale records.
        let survivors: Vec<TuningRecord> =
            records.into_iter().zip(&mask).filter(|(_, k)| **k).map(|(r, _)| r).collect();
        assert!(keep_mask(&survivors, &policy).iter().all(|&k| k));
        // Default policy (no stale sets) keeps everything here.
        assert_eq!(
            keep_mask(&survivors, &CompactionPolicy::keep_top(8)),
            vec![true, true]
        );
    }

    #[test]
    fn compact_file_refuses_stale_drop_without_repair() {
        let path = std::env::temp_dir()
            .join(format!("ms-stale-compact-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut db = crate::db::JsonFileDb::open(&path).unwrap();
            let w = db.register_workload("w", 1, "cpu");
            let mut live = rec(w, 1, Some(1.0));
            live.rule_set = "live-rule #aaaaaaaa".into();
            let mut ghost = rec(w, 2, Some(0.5));
            ghost.rule_set = "ghost-rule #bbbbbbbb".into();
            db.commit_record(live);
            db.commit_record(ghost);
        }
        let policy = CompactionPolicy {
            top_k: 8,
            stale_rule_sets: vec!["ghost-rule".to_string()],
        };
        let err = compact_file(&path, &policy, false).unwrap_err();
        assert!(err.contains("--repair") && err.contains("1 record"), "{err}");
        // Refusal left the file untouched.
        assert_eq!(crate::db::JsonFileDb::open(&path).unwrap().num_records(), 2);
        let report = compact_file(&path, &policy, true).unwrap();
        assert_eq!(report.stale_dropped, 1);
        assert_eq!(report.kept, 1);
        assert!(report.render("x").contains("stale_dropped: 1"), "{}", report.render("x"));
        let bytes_once = std::fs::read(&path).unwrap();
        // Second pass: nothing matches any more, so no --repair is
        // needed and the file is byte-identical (idempotence).
        let again = compact_file(&path, &policy, false).unwrap();
        assert_eq!(again.stale_dropped, 0);
        assert_eq!(std::fs::read(&path).unwrap(), bytes_once);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compact_file_errors_on_missing_path() {
        let err = compact_file("/nonexistent/db.jsonl", &CompactionPolicy::default(), false).unwrap_err();
        assert!(err.contains("no database"), "{err}");
    }
}
