//! Process-local database backend. Also the index that file-backed
//! backends rebuild on open, so everything here is deterministic by
//! construction: entries and records live in `Vec`s in arrival order and
//! the hash maps are lookup accelerators only — never iterated.

use std::collections::{HashMap, HashSet};

use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry, WorkloadId};

/// In-memory tuning database (the default when no `--db` file is given:
/// every run starts cold and the records die with the process).
#[derive(Debug, Clone, Default)]
pub struct InMemoryDb {
    entries: Vec<WorkloadEntry>,
    /// (shash, target) -> id lookup accelerator.
    by_key: HashMap<(u64, String), WorkloadId>,
    records: Vec<TuningRecord>,
    /// (workload, cand_hash) membership accelerator for dedup queries.
    cand_index: HashSet<(WorkloadId, u64)>,
}

impl InMemoryDb {
    pub fn new() -> InMemoryDb {
        InMemoryDb::default()
    }

    /// Registered workload count (cheaper than `workload_entries().len()`,
    /// which clones the registry).
    pub fn num_workloads(&self) -> usize {
        self.entries.len()
    }

    /// All records across workloads, in commit order — the compaction
    /// planner's input ([`crate::db::compact::keep_mask`]).
    pub(crate) fn records(&self) -> &[TuningRecord] {
        &self.records
    }

    /// Replace the record log wholesale (post-compaction prune), keeping
    /// the registry and rebuilding the dedup accelerator to match.
    pub(crate) fn replace_records(&mut self, records: Vec<TuningRecord>) {
        self.cand_index = records.iter().map(|r| (r.workload, r.cand_hash)).collect();
        self.records = records;
    }

    /// Rebuild-path insert of an already-numbered entry (file load). The
    /// id must match registration order; duplicate keys are rejected.
    pub(crate) fn insert_entry(&mut self, e: WorkloadEntry) -> Result<(), String> {
        if e.id != self.entries.len() {
            return Err(format!("workload id {} out of order (expected {})", e.id, self.entries.len()));
        }
        let key = (e.shash, e.target.clone());
        if self.by_key.contains_key(&key) {
            return Err(format!("duplicate workload ({:016x}, {})", e.shash, e.target));
        }
        self.by_key.insert(key, e.id);
        self.entries.push(e);
        Ok(())
    }
}

impl Database for InMemoryDb {
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId {
        if let Some(&id) = self.by_key.get(&(shash, target.to_string())) {
            return id;
        }
        let id = self.entries.len();
        let entry = WorkloadEntry {
            id,
            name: name.to_string(),
            shash,
            target: target.to_string(),
        };
        self.by_key.insert((shash, target.to_string()), id);
        self.entries.push(entry);
        id
    }

    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId> {
        self.by_key.get(&(shash, target.to_string())).copied()
    }

    fn workload_entries(&self) -> Vec<WorkloadEntry> {
        self.entries.clone()
    }

    fn commit_record(&mut self, rec: TuningRecord) {
        assert!(rec.workload < self.entries.len(), "record for unregistered workload {}", rec.workload);
        self.cand_index.insert((rec.workload, rec.cand_hash));
        self.records.push(rec);
    }

    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord> {
        self.records.iter().filter(|r| r.workload == workload).cloned().collect()
    }

    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64> {
        self.records.iter().filter(|r| r.workload == workload).map(|r| r.cand_hash).collect()
    }

    fn num_records(&self) -> usize {
        self.records.len()
    }

    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        self.cand_index.contains(&(workload, cand_hash))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Trace;

    fn rec(workload: WorkloadId, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace { insts: vec![] },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 0,
            round: 0,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        }
    }

    #[test]
    fn registration_is_idempotent_and_ordered() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 10, "cpu");
        let b = db.register_workload("B", 20, "cpu");
        let a2 = db.register_workload("A-renamed", 10, "cpu");
        // Same hash, different target = a distinct workload.
        let a_gpu = db.register_workload("A", 10, "gpu");
        assert_eq!((a, b, a2), (0, 1, 0));
        assert_eq!(a_gpu, 2);
        let entries = db.workload_entries();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].name, "A", "first registration keeps its name");
        assert_eq!(db.find_workload(10, "cpu"), Some(0));
        assert_eq!(db.find_workload(10, "tpu"), None);
    }

    #[test]
    fn records_partition_by_workload_in_commit_order() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 1, "cpu");
        let b = db.register_workload("B", 2, "cpu");
        db.commit_record(rec(a, 100, Some(2.0)));
        db.commit_record(rec(b, 200, Some(1.0)));
        db.commit_record(rec(a, 101, None));
        assert_eq!(db.num_records(), 3);
        assert_eq!(db.candidate_hashes(a), vec![100, 101]);
        assert_eq!(db.candidate_hashes(b), vec![200]);
        assert!(db.has_candidate(a, 101));
        assert!(!db.has_candidate(b, 101));
        assert_eq!(db.records_for(a).len(), 2);
        assert_eq!(db.best_latency(a), Some(2.0));
    }

    #[test]
    #[should_panic(expected = "unregistered workload")]
    fn committing_to_unregistered_workload_panics() {
        let mut db = InMemoryDb::new();
        db.commit_record(rec(0, 1, Some(1.0)));
    }

    #[test]
    fn insert_entry_validates_order_and_duplicates() {
        let mut db = InMemoryDb::new();
        let e = |id: usize, shash: u64| WorkloadEntry {
            id,
            name: "w".into(),
            shash,
            target: "cpu".into(),
        };
        db.insert_entry(e(0, 1)).unwrap();
        assert!(db.insert_entry(e(2, 2)).is_err(), "gap in ids");
        assert!(db.insert_entry(e(1, 1)).is_err(), "duplicate key");
        db.insert_entry(e(1, 2)).unwrap();
        assert_eq!(db.workload_entries().len(), 2);
    }
}
