//! The unit of persistence: one measured candidate — its trace, measured
//! latencies, and provenance — serialized as a single JSON object (one
//! JSONL line in [`crate::db::JsonFileDb`]).
//!
//! Field conventions: 64-bit hashes are hex strings and seeds are decimal
//! strings, because the zero-dep JSON value models numbers as `f64` and
//! a `u64` does not round-trip through one.

use crate::db::WorkloadId;
use crate::trace::serde::{text_to_trace, trace_to_text};
use crate::trace::Trace;
use crate::util::json::Json;

/// One tuning record: a candidate schedule (as its trace) measured for a
/// registered workload. `latencies` is empty when the candidate was
/// rejected by the hardware validator — failed candidates are kept so
/// warm-started runs do not re-measure known-invalid schedules.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningRecord {
    /// Id of the workload this record belongs to (see
    /// [`crate::db::Database::register_workload`]).
    pub workload: WorkloadId,
    /// The candidate's execution trace (replays against the workload's
    /// base program to reconstruct the scheduled program).
    pub trace: Trace,
    /// Measured latencies in seconds; empty = invalid on the target.
    pub latencies: Vec<f64>,
    /// Target the measurement ran on (e.g. `cpu-avx512`).
    pub target: String,
    /// Search seed that produced the candidate.
    pub seed: u64,
    /// Search round within that run.
    pub round: u64,
    /// Structural hash of the scheduled candidate program — the
    /// cross-session deduplication key.
    pub cand_hash: u64,
    /// Simulator/toolchain version the latencies were measured under
    /// ([`crate::sim::SIM_VERSION`] at commit time). Records written
    /// before provenance stamping parse back as `"v0"`, so a stats pass
    /// (or a future invalidation policy) can tell stale generations
    /// apart from current ones.
    pub sim_version: String,
    /// Canonical rule-set label of the space the candidate was drawn
    /// from ([`crate::ctx::TuneContext::rule_set`]). Empty for
    /// pre-provenance records.
    pub rule_set: String,
    /// Cost-model objective label the producing search ran under (e.g.
    /// `"rank"`). Empty means the historical default (squared-error
    /// regression); the field is then omitted from the JSONL line, so
    /// default-configuration databases stay byte-identical to
    /// pre-objective ones.
    pub objective: String,
}

impl TuningRecord {
    /// Best (minimum) measured latency; `None` for failed candidates.
    pub fn best_latency(&self) -> Option<f64> {
        self.latencies.iter().copied().reduce(f64::min)
    }

    /// Whether the candidate was rejected by the hardware validator.
    pub fn is_failed(&self) -> bool {
        self.latencies.is_empty()
    }

    /// Serialize to the JSONL object (`kind: "record"`). Non-finite
    /// latencies are dropped here: the JSON writer would emit them as
    /// `null`, and one such value must not make the whole file
    /// unreadable (a record whose latencies all vanish reads back as a
    /// failed candidate, which is the honest interpretation).
    pub fn to_json(&self) -> Json {
        let finite = self.latencies.iter().filter(|l| l.is_finite());
        let mut fields = vec![
            ("kind", Json::str("record")),
            ("workload", Json::num(self.workload as f64)),
            ("trace", Json::str(trace_to_text(&self.trace))),
            ("latencies", Json::arr(finite.map(|l| Json::num(*l)))),
            ("target", Json::str(self.target.clone())),
            ("seed", Json::str(self.seed.to_string())),
            ("round", Json::num(self.round as f64)),
            ("cand", Json::str(format!("{:016x}", self.cand_hash))),
            ("sim", Json::str(self.sim_version.clone())),
            ("rules", Json::str(self.rule_set.clone())),
        ];
        // Omitted (not written as "") for the default objective: the
        // absent field is what keeps default-config databases
        // byte-identical to pre-objective ones.
        if !self.objective.is_empty() {
            fields.push(("obj", Json::str(self.objective.clone())));
        }
        Json::obj(fields)
    }

    /// Parse back from a JSONL object.
    pub fn from_json(j: &Json) -> Result<TuningRecord, String> {
        if j.get("kind").and_then(Json::as_str) != Some("record") {
            return Err("not a record object".into());
        }
        let workload = usize_field(j, "workload")?;
        let trace_text = str_field(j, "trace")?;
        let trace = text_to_trace(trace_text).map_err(|e| format!("trace: {e}"))?;
        // Tolerate non-numeric entries (e.g. a `null` written by an old
        // build) by skipping them — refusing to open the whole file over
        // one unusable sample would break resumability.
        let latencies: Vec<f64> = j
            .get("latencies")
            .and_then(Json::as_arr)
            .ok_or("missing latencies")?
            .iter()
            .filter_map(Json::as_f64)
            .filter(|l| l.is_finite())
            .collect();
        let target = str_field(j, "target")?.to_string();
        let seed = str_field(j, "seed")?.parse::<u64>().map_err(|e| format!("seed: {e}"))?;
        let round = usize_field(j, "round")? as u64;
        let cand_hash =
            u64::from_str_radix(str_field(j, "cand")?, 16).map_err(|e| format!("cand: {e}"))?;
        // Provenance stamps are backward-compatible: absent fields mean
        // the record predates stamping ("v0" simulator, unknown rules).
        let sim_version = j
            .get("sim")
            .and_then(Json::as_str)
            .unwrap_or("v0")
            .to_string();
        let rule_set = j
            .get("rules")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let objective = j
            .get("obj")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        Ok(TuningRecord {
            workload,
            trace,
            latencies,
            target,
            seed,
            round,
            cand_hash,
            sim_version,
            rule_set,
            objective,
        })
    }
}

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    j.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing string field {key}"))
}

fn num_field(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing numeric field {key}"))
}

/// A non-negative integer field. Validated rather than `as`-cast: a
/// corrupt `-3` must fail the line (an unchecked cast saturates it to 0
/// and silently misfiles the record into workload 0).
pub(crate) fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    let v = num_field(j, key)?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > usize::MAX as f64 {
        return Err(format!("{key}: {v} is not a non-negative integer"));
    }
    Ok(v as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Inst;

    fn sample_record() -> TuningRecord {
        TuningRecord {
            workload: 3,
            trace: Trace {
                insts: vec![
                    Inst::GetBlock {
                        name: "mat mul\nx".into(),
                        out: 0,
                    },
                    Inst::Parallel { loop_rv: 1 },
                ],
            },
            latencies: vec![1.25e-5, 1.5e-5],
            target: "cpu-avx512".into(),
            seed: u64::MAX - 7,
            round: 12,
            cand_hash: 0xdead_beef_cafe_f00d,
            sim_version: crate::sim::SIM_VERSION.to_string(),
            rule_set: "auto-inline,multi-level-tiling".to_string(),
            objective: String::new(),
        }
    }

    #[test]
    fn record_roundtrips_through_json_line() {
        let r = sample_record();
        let line = r.to_json().to_string();
        assert!(!line.contains('\n'), "JSONL line must be newline-free");
        let back = TuningRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn failed_record_roundtrips_and_reports() {
        let mut r = sample_record();
        r.latencies.clear();
        assert!(r.is_failed());
        assert_eq!(r.best_latency(), None);
        let back = TuningRecord::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn best_latency_is_minimum() {
        let r = sample_record();
        assert_eq!(r.best_latency(), Some(1.25e-5));
    }

    #[test]
    fn non_finite_latencies_never_brick_the_line() {
        let mut r = sample_record();
        r.latencies = vec![f64::INFINITY, 1.0, f64::NAN];
        let line = r.to_json().to_string();
        assert!(!line.contains("null"), "non-finite latency leaked into JSONL: {line}");
        let back = TuningRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.latencies, vec![1.0]);
        // Even a hand-written null entry parses (skipped), rather than
        // failing the whole file.
        let hostile = line.replace("[1]", "[null,1]");
        let back2 = TuningRecord::from_json(&Json::parse(&hostile).unwrap()).unwrap();
        assert_eq!(back2.latencies, vec![1.0]);
    }

    #[test]
    fn pre_provenance_lines_parse_with_v0_defaults() {
        // A line written before the provenance stamps (no "sim"/"rules"
        // fields) must still parse — absent = v0 / unknown rules.
        let mut j = sample_record().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("sim");
            m.remove("rules");
        }
        let back = TuningRecord::from_json(&j).unwrap();
        assert_eq!(back.sim_version, "v0");
        assert_eq!(back.rule_set, "");
        assert_eq!(back.objective, "");
        // And re-serializing writes the defaults explicitly.
        let line = back.to_json().to_string();
        assert!(line.contains("\"sim\""), "{line}");
    }

    #[test]
    fn objective_stamp_round_trips_and_default_is_omitted() {
        // Default (mse) records must serialize WITHOUT an "obj" field —
        // byte-compat with pre-objective databases.
        let r = sample_record();
        assert_eq!(r.objective, "");
        let line = r.to_json().to_string();
        assert!(!line.contains("\"obj\""), "default objective leaked into JSONL: {line}");
        // A non-default objective round-trips.
        let mut ranked = sample_record();
        ranked.objective = "rank".to_string();
        let rline = ranked.to_json().to_string();
        assert!(rline.contains("\"obj\""), "{rline}");
        let back = TuningRecord::from_json(&Json::parse(&rline).unwrap()).unwrap();
        assert_eq!(back, ranked);
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(TuningRecord::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = sample_record().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("trace".into(), Json::str("frobnicate x=1"));
        }
        assert!(TuningRecord::from_json(&j).is_err());
        // Negative / fractional ids must error, not saturate to a valid
        // workload and misfile the record.
        for bad in [-3.0, 1.5, f64::NAN] {
            let mut j = sample_record().to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("workload".into(), Json::Num(bad));
            }
            assert!(TuningRecord::from_json(&j).is_err(), "workload {bad} accepted");
        }
    }
}
