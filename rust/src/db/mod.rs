//! Persistent tuning-record database (the paper's §5 "database" box).
//!
//! MetaSchedule's learning-driven search is anchored by a record store
//! that registers workloads, persists measured `(trace, latency)` pairs,
//! and serves top-k queries back to the search and the cost model — the
//! same role the record store plays in Ansor and the training-data
//! pipeline of "Learning to Optimize Tensor Programs". This module is
//! that store:
//!
//! - [`Database`] — the backend-agnostic API ([`register_workload`],
//!   [`commit_record`], [`query_top_k`], [`best_latency`]).
//! - [`InMemoryDb`] — process-local store (also the index every other
//!   backend builds on).
//! - [`JsonFileDb`] — append-only JSONL persistence via the zero-dep
//!   [`crate::util::json`] value and the [`crate::trace::serde`] line
//!   format; re-opening the file warm-starts the next run.
//! - [`SharedDb`] — mutex adapter so task-parallel scheduler rounds can
//!   commit through one handle.
//! - [`ShardedDb`] — the same JSONL format spread over per-shard files
//!   routed by structural hash ([`sharded::shard_of`]): parallel
//!   compaction, batched group commit ([`group_commit_writer`]), and
//!   per-shard serving snapshots. [`AnyDb`] auto-detects which layout a
//!   `--db` path holds.
//! - [`compact`] — record GC: atomic top-k-per-workload rewrite of the
//!   JSONL file (plus the size-triggered auto-GC hook inside
//!   [`JsonFileDb`]); failures always survive for cross-session dedup.
//! - [`pretrain_cost_model`] — replays committed records into training
//!   samples so [`crate::cost_model::GbtCostModel`] starts round 1 fit.
//!
//! Iteration order everywhere is registration/commit order, never hash
//! order, so warm-started runs stay bit-reproducible.
//!
//! The on-disk format is specified normatively in `docs/DB_FORMAT.md`.
//!
//! # Example
//!
//! ```
//! use metaschedule::db::{Database, InMemoryDb, TuningRecord};
//! use metaschedule::trace::Trace;
//!
//! let mut db = InMemoryDb::new();
//! let wid = db.register_workload("GMM", 0x42, "cpu");
//! db.commit_record(TuningRecord {
//!     workload: wid,
//!     trace: Trace { insts: vec![] },
//!     latencies: vec![2.0e-5, 1.0e-5],
//!     target: "cpu".into(),
//!     seed: 7,
//!     round: 0,
//!     cand_hash: 1,
//!     sim_version: "sim".into(),
//!     rule_set: String::new(),
//!     objective: String::new(),
//! });
//! assert_eq!(db.best_latency(wid), Some(1.0e-5));
//! assert!(db.has_candidate(wid, 1), "failed or not, a commit dedups");
//! ```
//!
//! [`register_workload`]: Database::register_workload
//! [`commit_record`]: Database::commit_record
//! [`query_top_k`]: Database::query_top_k
//! [`best_latency`]: Database::best_latency

pub mod compact;
pub mod json_file;
pub mod memory;
pub mod record;
pub mod shared;
pub mod sharded;
pub mod stats;

pub use compact::{compact_file, keep_mask, rule_set_matches, CompactionPolicy, CompactionReport};
pub use json_file::{load_readonly, probe, AutoGc, FileSignature, JsonFileDb};
pub use memory::InMemoryDb;
pub use record::TuningRecord;
pub use shared::SharedDb;
pub use sharded::{
    compact_any, group_commit_writer, is_sharded, load_readonly_any, migrate_from_file, probe_db,
    shard_file_name, shard_of, AnyDb, Manifest, ShardedDb, DEFAULT_SHARDS,
};
pub use stats::{DbStats, WorkloadStats};

use crate::cost_model::CostModel;
use crate::tir::Program;
use crate::util::json::Json;

/// Index of a registered workload within a database (registration order).
pub type WorkloadId = usize;

/// One registry entry: a workload is identified by the structural hash of
/// its base (unscheduled) program plus the target it is tuned for —
/// records never transfer across targets implicitly. Explicit transfer
/// goes through [`Database::query_transfer_candidates`] and the
/// [`crate::transfer`] module, which injects another target's records as
/// *priors* (re-measured on the destination before anything is
/// committed), never as truth.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadEntry {
    pub id: WorkloadId,
    /// Human-readable name (task/program name at first registration).
    pub name: String,
    /// Structural hash of the base program.
    pub shash: u64,
    /// Target name the records were measured on.
    pub target: String,
}

impl WorkloadEntry {
    /// Serialize to the JSONL object (`kind: "workload"`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("workload")),
            ("id", Json::num(self.id as f64)),
            ("name", Json::str(self.name.clone())),
            ("shash", Json::str(format!("{:016x}", self.shash))),
            ("target", Json::str(self.target.clone())),
        ])
    }

    /// Parse back from a JSONL object.
    pub fn from_json(j: &Json) -> Result<WorkloadEntry, String> {
        if j.get("kind").and_then(Json::as_str) != Some("workload") {
            return Err("not a workload object".into());
        }
        let get_str = |k: &str| {
            j.get(k).and_then(Json::as_str).ok_or_else(|| format!("missing string field {k}"))
        };
        let id = record::usize_field(j, "id")?;
        let shash =
            u64::from_str_radix(get_str("shash")?, 16).map_err(|e| format!("shash: {e}"))?;
        Ok(WorkloadEntry {
            id,
            name: get_str("name")?.to_string(),
            shash,
            target: get_str("target")?.to_string(),
        })
    }
}

/// A tuning-record database. `Send` (not `Sync`): concurrent access goes
/// through [`SharedDb`], mirroring how [`crate::search::parallel::SharedMeasurer`]
/// shares the measurement oracle.
///
/// Query methods return owned values rather than borrows so the trait
/// stays implementable by lock-guarded adapters (a `&[TuningRecord]`
/// cannot escape a mutex guard); record counts here are small enough
/// that the clones never show up in profiles.
pub trait Database: Send {
    /// Register (or find) the workload `(shash, target)`. Idempotent:
    /// re-registration returns the existing id and keeps the first name.
    fn register_workload(&mut self, name: &str, shash: u64, target: &str) -> WorkloadId;

    /// Look up a workload id without registering.
    fn find_workload(&self, shash: u64, target: &str) -> Option<WorkloadId>;

    /// All registry entries, in registration order.
    fn workload_entries(&self) -> Vec<WorkloadEntry>;

    /// Append one record. Backends persist synchronously (a crashed run
    /// must be resumable from everything it measured).
    fn commit_record(&mut self, rec: TuningRecord);

    /// All records for one workload, in commit order.
    fn records_for(&self, workload: WorkloadId) -> Vec<TuningRecord>;

    /// Structural hashes of every candidate ever committed (measured OR
    /// failed) for the workload, in commit order — the search seeds its
    /// dedup set from this.
    fn candidate_hashes(&self, workload: WorkloadId) -> Vec<u64>;

    /// Total committed records across all workloads.
    fn num_records(&self) -> usize;

    /// The `k` best successful records for a workload, ordered by
    /// ascending best latency with commit order breaking ties (stable
    /// sort), so the result is deterministic for a given file content.
    fn query_top_k(&self, workload: WorkloadId, k: usize) -> Vec<TuningRecord> {
        let mut recs: Vec<TuningRecord> =
            self.records_for(workload).into_iter().filter(|r| !r.is_failed()).collect();
        recs.sort_by(|a, b| {
            let (Some(la), Some(lb)) = (a.best_latency(), b.best_latency()) else {
                unreachable!("failed records filtered above");
            };
            la.total_cmp(&lb)
        });
        recs.truncate(k);
        recs
    }

    /// Best latency on record for a workload (`None` = no successful
    /// measurement yet).
    fn best_latency(&self, workload: WorkloadId) -> Option<f64> {
        self.query_top_k(workload, 1).first().and_then(TuningRecord::best_latency)
    }

    /// Whether a candidate (by structural hash) was already committed for
    /// the workload.
    fn has_candidate(&self, workload: WorkloadId, cand_hash: u64) -> bool {
        self.candidate_hashes(workload).contains(&cand_hash)
    }

    /// Every registry entry whose structural hash matches, regardless of
    /// target, in registration order — the cross-target view of one
    /// workload (the same program registered per target it was tuned on).
    fn find_workload_any_target(&self, shash: u64) -> Vec<WorkloadEntry> {
        self.workload_entries().into_iter().filter(|e| e.shash == shash).collect()
    }

    /// Cross-target transfer candidates for the workload `shash` tuned
    /// for `dest_target`: the `k` best successful records of every
    /// *other* target's registration of the same program (optionally
    /// restricted to `source_target`), grouped by donor registration
    /// order and best-first within each donor. Latencies from different
    /// sources are not comparable with each other — callers rank within
    /// a source, never across. Provenance compatibility
    /// ([`crate::ctx::TuneContext::transfer_compatible`], `sim_version`)
    /// is the [`crate::transfer`] layer's job, not the database's.
    fn query_transfer_candidates(
        &self,
        shash: u64,
        dest_target: &str,
        source_target: Option<&str>,
        k: usize,
    ) -> Vec<TuningRecord> {
        let mut out = Vec::new();
        for e in self.find_workload_any_target(shash) {
            if e.target == dest_target {
                continue;
            }
            if let Some(src) = source_target {
                if e.target != src {
                    continue;
                }
            }
            out.extend(self.query_top_k(e.id, k));
        }
        out
    }
}

/// What [`pretrain_cost_model`] did: samples fed vs records it refused.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PretrainStats {
    /// `(program, latency)` samples fed to the model.
    pub fed: usize,
    /// Successful records skipped because their `sim_version` does not
    /// match [`crate::sim::SIM_VERSION`] — latencies measured under an
    /// older simulator model would silently poison the fit.
    pub stale_skipped: usize,
}

/// Replay up to `limit` of a workload's best records against its base
/// program and feed the `(program, latency)` pairs to the cost model as
/// one training batch — so the model is fit *before* round 1 of a
/// warm-started search instead of starting cold. Records whose traces no
/// longer replay (e.g. after a schedule-primitive change) are skipped,
/// and so are records measured under a different `sim_version` (their
/// latencies are not commensurable with the current simulator model;
/// they are counted in [`PretrainStats::stale_skipped`] instead of fed).
pub fn pretrain_cost_model(
    model: &mut dyn CostModel,
    db: &dyn Database,
    workload: WorkloadId,
    prog: &Program,
    limit: usize,
) -> PretrainStats {
    let mut progs: Vec<Program> = Vec::new();
    let mut lats: Vec<f64> = Vec::new();
    let mut stale_skipped = 0usize;
    // Fetch everything and filter *before* truncating to `limit`: a
    // stale record in the top-k must not crowd a current one out.
    for rec in db.query_top_k(workload, usize::MAX) {
        if rec.sim_version != crate::sim::SIM_VERSION {
            stale_skipped += 1;
            continue;
        }
        if progs.len() >= limit {
            continue;
        }
        let Some(lat) = rec.best_latency() else {
            continue;
        };
        if let Ok(sch) = crate::trace::replay(&rec.trace, prog, 0) {
            progs.push(sch.prog);
            lats.push(lat);
        }
    }
    if progs.is_empty() {
        return PretrainStats { fed: 0, stale_skipped };
    }
    let refs: Vec<&Program> = progs.iter().collect();
    model.update(&refs, &lats);
    PretrainStats { fed: progs.len(), stale_skipped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost_model::GbtCostModel;
    use crate::search::{Measurer, SimMeasurer};
    use crate::sim::Target;
    use crate::ctx::TuneContext;
    use crate::tir::structural_hash;
    use crate::workloads;

    #[test]
    fn workload_entry_roundtrips_through_json() {
        let e = WorkloadEntry {
            id: 7,
            name: "GMM odd name\n".into(),
            shash: 0x0123_4567_89ab_cdef,
            target: "gpu".into(),
        };
        let back = WorkloadEntry::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn workload_entry_rejects_wrong_kind() {
        let r = Json::parse("{\"kind\":\"record\"}").unwrap();
        assert!(WorkloadEntry::from_json(&r).is_err());
    }

    /// Populate a db with a couple of real measured schedules for GMM.
    fn seeded_db(prog: &crate::tir::Program, target: &Target, n: usize) -> (InMemoryDb, WorkloadId) {
        let mut db = InMemoryDb::new();
        let wid = db.register_workload(&prog.name, structural_hash(prog), target.name);
        let ctx = TuneContext::generic(target.clone());
        let designs = ctx.generate(prog, 1);
        let mut measurer = SimMeasurer::new(target.clone());
        let mut committed = 0;
        for (i, d) in designs.iter().cycle().take(n * 20).enumerate() {
            if committed >= n {
                break;
            }
            let Ok(sch) = crate::trace::replay::replay_fresh(&d.trace, prog, 1000 + i as u64) else {
                continue;
            };
            let lat = measurer.measure(&sch.prog);
            db.commit_record(TuningRecord {
                workload: wid,
                trace: sch.trace.clone(),
                latencies: lat.into_iter().collect(),
                target: target.name.to_string(),
                seed: 1,
                round: i as u64,
                cand_hash: structural_hash(&sch.prog),
                sim_version: crate::sim::SIM_VERSION.to_string(),
                rule_set: String::new(),
                objective: String::new(),
            });
            committed += 1;
        }
        (db, wid)
    }

    #[test]
    fn pretrain_fits_model_from_records() {
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 64, 64, 64);
        let (db, wid) = seeded_db(&prog, &target, 8);
        assert!(db.best_latency(wid).is_some());
        let mut model = GbtCostModel::new();
        let stats = pretrain_cost_model(&mut model, &db, wid, &prog, 64);
        assert!(stats.fed > 0, "no samples fed");
        assert_eq!(stats.stale_skipped, 0);
        assert_eq!(model.n_samples(), stats.fed);
        // A fit model no longer returns the cold neutral score for every
        // input (scores are -ln(latency), strictly positive here).
        let preds = model.predict(&[&prog]);
        assert!(preds[0] != 0.0, "model still cold after pretraining");
    }

    #[test]
    fn pretrain_on_empty_workload_is_noop() {
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 32, 32, 32);
        let mut db = InMemoryDb::new();
        let wid = db.register_workload(&prog.name, structural_hash(&prog), target.name);
        let mut model = GbtCostModel::new();
        assert_eq!(pretrain_cost_model(&mut model, &db, wid, &prog, 64), PretrainStats::default());
        assert_eq!(model.n_samples(), 0);
    }

    #[test]
    fn pretrain_skips_and_counts_stale_sim_versions() {
        // A record measured under an older simulator model must not feed
        // the fit — even when it is the best record on file.
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 64, 64, 64);
        let (mut db, wid) = seeded_db(&prog, &target, 4);
        let mut stale = db.query_top_k(wid, 1).remove(0);
        stale.sim_version = "sim-v0-retired".into();
        stale.latencies = vec![1e-15]; // absurdly good: would dominate the fit
        stale.cand_hash = stale.cand_hash.wrapping_add(1);
        db.commit_record(stale);
        let mut model = GbtCostModel::new();
        let stats = pretrain_cost_model(&mut model, &db, wid, &prog, 64);
        assert_eq!(stats.stale_skipped, 1);
        assert!(stats.fed > 0, "compatible records must still feed the fit");
        assert_eq!(model.n_samples(), stats.fed, "stale sample leaked into the model");
    }

    #[test]
    fn cross_target_queries_see_other_targets_only() {
        let mut db = InMemoryDb::new();
        let cpu = db.register_workload("w", 42, "cpu");
        let gpu = db.register_workload("w", 42, "gpu");
        let other = db.register_workload("x", 43, "cpu");
        let mk = |w: usize, lat: f64, cand: u64| TuningRecord {
            workload: w,
            trace: crate::trace::Trace { insts: vec![] },
            latencies: vec![lat],
            target: "?".into(),
            seed: 0,
            round: 0,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        };
        db.commit_record(mk(cpu, 2.0, 1));
        db.commit_record(mk(cpu, 1.0, 2));
        db.commit_record(mk(gpu, 5.0, 3));
        db.commit_record(mk(other, 9.0, 4));
        let entries = db.find_workload_any_target(42);
        assert_eq!(entries.len(), 2);
        assert_eq!((entries[0].target.as_str(), entries[1].target.as_str()), ("cpu", "gpu"));
        // Tuning for gpu: donors are the cpu records, best-first.
        let donors = db.query_transfer_candidates(42, "gpu", None, 8);
        assert_eq!(donors.iter().map(|r| r.cand_hash).collect::<Vec<_>>(), vec![2, 1]);
        // Source restriction and self-exclusion.
        assert!(db.query_transfer_candidates(42, "gpu", Some("tpu"), 8).is_empty());
        let donors_cpu = db.query_transfer_candidates(42, "cpu", None, 8);
        assert_eq!(donors_cpu.iter().map(|r| r.cand_hash).collect::<Vec<_>>(), vec![3]);
        // Unrelated shash never leaks in.
        assert!(db.query_transfer_candidates(999, "gpu", None, 8).is_empty());
    }

    #[test]
    fn query_top_k_orders_by_latency_and_skips_failures() {
        let mut db = InMemoryDb::new();
        let wid = db.register_workload("w", 1, "cpu");
        let mk = |lats: Vec<f64>, round: u64| TuningRecord {
            workload: wid,
            trace: crate::trace::Trace { insts: vec![] },
            latencies: lats,
            target: "cpu".into(),
            seed: 0,
            round,
            cand_hash: round,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        };
        db.commit_record(mk(vec![3.0], 0));
        db.commit_record(mk(vec![], 1)); // failed
        db.commit_record(mk(vec![1.0, 9.0], 2));
        db.commit_record(mk(vec![2.0], 3));
        let top = db.query_top_k(wid, 2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].round, 2);
        assert_eq!(top[1].round, 3);
        assert_eq!(db.best_latency(wid), Some(1.0));
        assert!(db.has_candidate(wid, 1), "failed candidates still dedup");
        assert!(!db.has_candidate(wid, 99));
    }
}
