//! Aggregate statistics over a database — the `metaschedule db stats`
//! view and the numbers the CI smoke step asserts on.

use crate::db::{Database, TuningRecord, WorkloadEntry};

/// Per-workload aggregate.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub entry: WorkloadEntry,
    /// Total committed records (including failed candidates).
    pub records: usize,
    /// Records with no successful measurement.
    pub failed: usize,
    pub best_latency_s: Option<f64>,
}

/// Whole-database aggregate, in registration order.
#[derive(Debug, Clone)]
pub struct DbStats {
    pub workloads: Vec<WorkloadStats>,
    pub records: usize,
    pub failed: usize,
    /// Provenance mix: `(sim_version, rule_set) -> record count`, in
    /// first-seen (commit) order. Pre-provenance records group under
    /// `("v0", "")` — a non-empty mix after a simulator bump tells the
    /// operator which records predate the current model.
    pub versions: Vec<((String, String), usize)>,
}

impl DbStats {
    pub fn compute(db: &dyn Database) -> DbStats {
        // One records_for() fetch per workload: the provenance tally
        // shares the record set the per-workload stats already hold
        // (records_for deep-clones traces, so a second pass would double
        // the cost on large databases).
        let mut versions: Vec<((String, String), usize)> = Vec::new();
        let workloads: Vec<WorkloadStats> = db
            .workload_entries()
            .into_iter()
            .map(|entry| {
                let recs = db.records_for(entry.id);
                for rec in &recs {
                    let key = (rec.sim_version.clone(), rec.rule_set.clone());
                    match versions.iter_mut().find(|(k, _)| *k == key) {
                        Some((_, n)) => *n += 1,
                        None => versions.push((key, 1)),
                    }
                }
                let failed = recs.iter().filter(|r| r.is_failed()).count();
                // Minimum over the records already in hand — a
                // best_latency() call would re-fetch and re-sort them.
                let best_latency_s = recs.iter().filter_map(TuningRecord::best_latency).reduce(f64::min);
                WorkloadStats {
                    best_latency_s,
                    records: recs.len(),
                    failed,
                    entry,
                }
            })
            .collect();
        let records = workloads.iter().map(|w| w.records).sum();
        let failed = workloads.iter().map(|w| w.failed).sum();
        DbStats {
            workloads,
            records,
            failed,
            versions,
        }
    }

    /// Human-readable rendering (one line per workload).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("workloads: {}\n", self.workloads.len()));
        out.push_str(&format!("records:   {} ({} failed)\n", self.records, self.failed));
        for w in &self.workloads {
            let best = match w.best_latency_s {
                Some(l) => format!("best {:.2} us", l * 1e6),
                None => "no successful measurement".to_string(),
            };
            out.push_str(&format!(
                "  [{}] {} on {} (shash {:016x}): {} records ({} failed), {}\n",
                w.entry.id, w.entry.name, w.entry.target, w.entry.shash, w.records, w.failed, best
            ));
        }
        for ((sim, rules), n) in &self.versions {
            let rules = if rules.is_empty() { "-" } else { rules.as_str() };
            out.push_str(&format!("  version {sim} rules={rules}: {n} records\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::{InMemoryDb, TuningRecord};
    use crate::trace::Trace;

    #[test]
    fn stats_count_per_workload_and_render() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("GMM", 0xabc, "cpu");
        let b = db.register_workload("C1D", 0xdef, "gpu");
        let mk = |w: usize, lat: Option<f64>| TuningRecord {
            workload: w,
            trace: Trace { insts: vec![] },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 0,
            round: 0,
            cand_hash: 0,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        };
        db.commit_record(mk(a, Some(2e-6)));
        db.commit_record(mk(a, None));
        db.commit_record(mk(b, Some(5e-6)));
        let stats = DbStats::compute(&db);
        assert_eq!(stats.records, 3);
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.workloads.len(), 2);
        assert_eq!(stats.workloads[0].records, 2);
        assert_eq!(stats.workloads[0].failed, 1);
        assert_eq!(stats.workloads[0].best_latency_s, Some(2e-6));
        assert_eq!(stats.workloads[1].best_latency_s, Some(5e-6));
        let text = stats.render();
        assert!(text.contains("workloads: 2"));
        assert!(text.contains("GMM"));
        assert!(text.contains("2.00 us"));
        // Provenance mix: the helper stamps every record identically.
        assert_eq!(stats.versions.len(), 1);
        assert_eq!(stats.versions[0].1, 3);
        assert!(text.contains("version simtest rules=-: 3 records"), "{text}");
    }

    #[test]
    fn empty_db_renders() {
        let stats = DbStats::compute(&InMemoryDb::new());
        assert_eq!(stats.records, 0);
        assert!(stats.render().contains("workloads: 0"));
    }
}
