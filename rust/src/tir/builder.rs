//! Convenience builder for constructing workload programs.
//!
//! Workloads declare computations einsum-style: a named block with typed
//! iteration axes; the builder materializes one loop per axis (identity
//! bindings) at the program root, which is the canonical starting point
//! `e_0` for scheduling.

use crate::tir::block::{BlockBody, BlockData, IterKind, IterVar};
use crate::tir::buffer::{Buffer, DType, Region};
use crate::tir::expr::{AExpr, VarId};
use crate::tir::program::{ItemId, LoopData, Program};

/// Declared iteration axis of a compute block.
#[derive(Debug, Clone)]
pub struct Axis {
    pub hint: &'static str,
    pub extent: i64,
    pub kind: IterKind,
}

/// Spatial axis shorthand.
pub fn sp(hint: &'static str, extent: i64) -> Axis {
    Axis {
        hint,
        extent,
        kind: IterKind::Spatial,
    }
}

/// Reduction axis shorthand.
pub fn rd(hint: &'static str, extent: i64) -> Axis {
    Axis {
        hint,
        extent,
        kind: IterKind::Reduce,
    }
}

impl Program {
    /// Add an input/output parameter buffer.
    pub fn param(&mut self, name: &str, shape: Vec<i64>, dtype: DType) -> usize {
        let id = self.add_buffer(Buffer::new(name, shape, dtype));
        self.params.push(id);
        id
    }

    /// Add an intermediate (non-parameter) buffer.
    pub fn temp(&mut self, name: &str, shape: Vec<i64>, dtype: DType) -> usize {
        self.add_buffer(Buffer::new(name, shape, dtype))
    }

    /// Emit a compute block wrapped in one fresh loop per axis, attached at
    /// the program root (after any existing roots). The closure receives the
    /// block iteration vars in axis order and returns the regions + body.
    pub fn emit(
        &mut self,
        name: &str,
        axes: &[Axis],
        f: impl FnOnce(&[VarId]) -> (Vec<Region>, Vec<Region>, BlockBody),
    ) -> ItemId {
        let mut loop_ids = Vec::with_capacity(axes.len());
        let mut iter_vars = Vec::with_capacity(axes.len());
        let mut iters = Vec::with_capacity(axes.len());
        for ax in axes {
            let lv = self.fresh_var(ax.hint);
            let bv = self.fresh_var(&format!("{}_", ax.hint));
            loop_ids.push(self.alloc_loop(LoopData::new(lv, ax.extent)));
            iter_vars.push(bv);
            iters.push(IterVar {
                var: bv,
                extent: ax.extent,
                kind: ax.kind,
                binding: AExpr::Var(lv),
            });
        }
        let (reads, writes, body) = f(&iter_vars);
        let mut block = BlockData::new(name);
        block.iters = iters;
        block.reads = reads;
        block.writes = writes;
        block.body = body;
        let block_id = self.alloc_block(block);
        // Chain the loops and hang the block at the innermost.
        let mut parent: Option<ItemId> = None;
        for &l in &loop_ids {
            self.attach(l, parent);
            parent = Some(l);
        }
        self.attach(block_id, parent);
        block_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;
    use crate::tir::expr::{BinOp, CExpr};

    #[test]
    fn emit_builds_loop_nest_and_block() {
        let mut p = Program::new("vecadd");
        let a = p.param("A", vec![256], DType::F32);
        let b = p.param("B", vec![256], DType::F32);
        let c = p.param("C", vec![256], DType::F32);
        let blk = p.emit("add", &[sp("i", 256)], |iv| {
            let i = iv[0];
            (
                vec![
                    Region::point(a, vec![AExpr::Var(i)]),
                    Region::point(b, vec![AExpr::Var(i)]),
                ],
                vec![Region::point(c, vec![AExpr::Var(i)])],
                BlockBody::Assign {
                    expr: CExpr::bin(
                        BinOp::Add,
                        CExpr::load(a, vec![AExpr::Var(i)]),
                        CExpr::load(b, vec![AExpr::Var(i)]),
                    ),
                },
            )
        });
        p.check_integrity().unwrap();
        assert_eq!(p.loops_above(blk).len(), 1);
        assert_eq!(program_flops(&p), 256.0);
    }

    #[test]
    fn emit_multiple_blocks_sequence_at_root() {
        let mut p = Program::new("two");
        let a = p.param("A", vec![8], DType::F32);
        let t = p.temp("T", vec![8], DType::F32);
        let o = p.param("O", vec![8], DType::F32);
        let b1 = p.emit("first", &[sp("i", 8)], |iv| {
            (
                vec![Region::point(a, vec![AExpr::Var(iv[0])])],
                vec![Region::point(t, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::load(a, vec![AExpr::Var(iv[0])]),
                },
            )
        });
        let b2 = p.emit("second", &[sp("i", 8)], |iv| {
            (
                vec![Region::point(t, vec![AExpr::Var(iv[0])])],
                vec![Region::point(o, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::load(t, vec![AExpr::Var(iv[0])]),
                },
            )
        });
        assert_eq!(p.roots.len(), 2);
        assert_eq!(p.producers_of(b2), vec![b1]);
        assert_eq!(p.consumers_of(b1), vec![b2]);
    }
}
