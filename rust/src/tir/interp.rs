//! Reference interpreter: execute a tensor program on concrete f32 data.
//!
//! This is the ground-truth semantics of the IR. Its purpose is *deep
//! validation*: a schedule primitive is only correct if the transformed
//! program computes bit-identical results to `e_0` on arbitrary inputs,
//! which is a much stronger invariant than the structural checks the
//! trace validator applies on the search hot path. The property suite
//! (rust/tests/prop_invariants.rs) runs randomly-scheduled programs
//! through this interpreter against their initial programs.
//!
//! Execution model: walk the loop forest in order (parallel / vectorized
//! / unrolled / thread-bound loops run serially — scheduling annotations
//! must not change semantics); at each block instance, bind the block
//! iteration variables by evaluating their loop-var bindings, then apply
//! the body. A `Reduce` body stores its init value on the instance where
//! every reduction iter evaluates to 0 (the "first reduction step", which
//! split/reordered/fused reduction loops still visit exactly once per
//! output element), then folds the update.

use std::collections::HashMap;

use crate::tir::block::{BlockBody, IterKind};
use crate::tir::buffer::Region;
use crate::tir::expr::{AExpr, BinOp, CExpr, UnOp, VarId};
use crate::tir::program::{ItemId, ItemKind, Program};

/// Why a program cannot be interpreted.
#[derive(Debug, Clone, PartialEq)]
pub enum InterpError {
    /// Blockized/tensorized blocks are opaque — no scalar body to run.
    OpaqueBlock(String),
    /// A write region with extent != 1 (not a point store).
    NonPointWrite(String),
    OutOfBounds { buffer: String, index: i64 },
}

impl std::fmt::Display for InterpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InterpError::OpaqueBlock(b) => write!(f, "cannot interpret opaque block {b}"),
            InterpError::NonPointWrite(b) => write!(f, "non-point write in block {b}"),
            InterpError::OutOfBounds { buffer, index } => {
                write!(f, "index {index} out of bounds for buffer {buffer}")
            }
        }
    }
}

/// Concrete buffer contents, indexed like `Program::buffers`.
#[derive(Debug, Clone)]
pub struct Memory {
    pub bufs: Vec<Vec<f32>>,
}

impl Memory {
    /// Allocate every buffer; parameters filled with a deterministic
    /// pseudorandom pattern from `seed`, intermediates zeroed.
    pub fn seeded(prog: &Program, seed: u64) -> Memory {
        let mut state = seed ^ 0x9e3779b97f4a7c15;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // Small-magnitude values keep f32 reductions well-conditioned.
            ((state >> 40) as f32 / (1u32 << 24) as f32) - 0.5
        };
        let bufs = prog
            .buffers
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let n = b.numel().max(0) as usize;
                if prog.params.contains(&i) {
                    (0..n).map(|_| next()).collect()
                } else {
                    vec![0.0; n]
                }
            })
            .collect();
        Memory { bufs }
    }

    fn flat_index(prog: &Program, buffer: usize, idx: &[i64]) -> i64 {
        let shape = &prog.buffers[buffer].shape;
        let mut flat = 0i64;
        for (d, &i) in idx.iter().enumerate() {
            flat = flat * shape.get(d).copied().unwrap_or(1) + i;
        }
        flat
    }
}

fn eval_cexpr(
    prog: &Program,
    mem: &Memory,
    env: &HashMap<VarId, i64>,
    e: &CExpr,
) -> Result<f32, InterpError> {
    Ok(match e {
        CExpr::ConstF(c) => *c as f32,
        CExpr::Load(buf, idx) => {
            let concrete: Vec<i64> = idx.iter().map(|a| a.eval(env)).collect();
            let flat = Memory::flat_index(prog, *buf, &concrete);
            let data = &mem.bufs[*buf];
            if flat < 0 || flat as usize >= data.len() {
                return Err(InterpError::OutOfBounds {
                    buffer: prog.buffers[*buf].name.clone(),
                    index: flat,
                });
            }
            data[flat as usize]
        }
        CExpr::Bin(op, a, b) => {
            let (x, y) = (
                eval_cexpr(prog, mem, env, a)?,
                eval_cexpr(prog, mem, env, b)?,
            );
            apply_bin(*op, x, y)
        }
        CExpr::Un(op, a) => {
            let x = eval_cexpr(prog, mem, env, a)?;
            match op {
                UnOp::Neg => -x,
                UnOp::Exp => x.exp(),
                UnOp::Sqrt => x.sqrt(),
                UnOp::Rsqrt => 1.0 / x.sqrt(),
                UnOp::Relu => x.max(0.0),
                UnOp::Tanh => x.tanh(),
                UnOp::Erf => {
                    // Abramowitz-Stegun 7.1.26 approximation.
                    let sign = if x < 0.0 { -1.0 } else { 1.0 };
                    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
                    let y = 1.0
                        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                            - 0.284496736)
                            * t
                            + 0.254829592)
                            * t
                            * (-x * x).exp();
                    sign * y
                }
                UnOp::CastF32 | UnOp::CastBF16 => x,
            }
        }
    })
}

fn apply_bin(op: BinOp, x: f32, y: f32) -> f32 {
    match op {
        BinOp::Add => x + y,
        BinOp::Sub => x - y,
        BinOp::Mul => x * y,
        BinOp::Div => x / y,
        BinOp::Max => x.max(y),
        BinOp::Min => x.min(y),
    }
}

fn store(
    prog: &Program,
    mem: &mut Memory,
    block_name: &str,
    region: &Region,
    env: &HashMap<VarId, i64>,
    value: f32,
) -> Result<i64, InterpError> {
    if region.ranges.iter().any(|(_, e)| *e != 1) {
        return Err(InterpError::NonPointWrite(block_name.to_string()));
    }
    let idx: Vec<i64> = region.ranges.iter().map(|(s, _)| s.eval(env)).collect();
    let flat = Memory::flat_index(prog, region.buffer, &idx);
    let data = &mut mem.bufs[region.buffer];
    if flat < 0 || flat as usize >= data.len() {
        return Err(InterpError::OutOfBounds {
            buffer: prog.buffers[region.buffer].name.clone(),
            index: flat,
        });
    }
    data[flat as usize] = value;
    Ok(flat)
}

/// Execute `prog` over `mem` in place.
pub fn execute(prog: &Program, mem: &mut Memory) -> Result<(), InterpError> {
    let mut env: HashMap<VarId, i64> = HashMap::new();
    for &root in &prog.roots {
        exec_item(prog, mem, root, &mut env)?;
    }
    Ok(())
}

fn exec_item(
    prog: &Program,
    mem: &mut Memory,
    item: ItemId,
    env: &mut HashMap<VarId, i64>,
) -> Result<(), InterpError> {
    if !prog.items[item].alive {
        return Ok(());
    }
    match &prog.items[item].kind {
        ItemKind::Loop(l) => {
            for v in 0..l.extent {
                env.insert(l.var, v);
                for &c in &prog.items[item].children {
                    exec_item(prog, mem, c, env)?;
                }
            }
            env.remove(&l.var);
            Ok(())
        }
        ItemKind::Block(bd) => {
            // Bind block iter vars from their loop-var bindings.
            let mut benv = env.clone();
            for iv in &bd.iters {
                let val = iv.binding.eval(env);
                benv.insert(iv.var, val);
            }
            match &bd.body {
                BlockBody::Assign { expr } => {
                    let v = eval_cexpr(prog, mem, &benv, expr)?;
                    store(prog, mem, &bd.name, &bd.writes[0], &benv, v)?;
                    Ok(())
                }
                BlockBody::Reduce { init, op, rhs } => {
                    // First reduction step for this output element: every
                    // reduce iter evaluates to 0.
                    let first = bd
                        .iters
                        .iter()
                        .filter(|iv| iv.kind == IterKind::Reduce)
                        .all(|iv| benv[&iv.var] == 0);
                    if first && !bd.init_decomposed {
                        let v = eval_cexpr(prog, mem, &benv, init)?;
                        store(prog, mem, &bd.name, &bd.writes[0], &benv, v)?;
                    }
                    let update = eval_cexpr(prog, mem, &benv, rhs)?;
                    // Load-modify-store on the accumulator.
                    let region = &bd.writes[0];
                    let idx: Vec<AExpr> = region.ranges.iter().map(|(s, _)| s.clone()).collect();
                    let cur = eval_cexpr(prog, mem, &benv, &CExpr::Load(region.buffer, idx))?;
                    store(prog, mem, &bd.name, region, &benv, apply_bin(*op, cur, update))?;
                    Ok(())
                }
                BlockBody::Opaque { .. } => Err(InterpError::OpaqueBlock(bd.name.clone())),
            }
        }
    }
}

/// Execute `prog` from a seeded memory and return the final state.
pub fn run_seeded(prog: &Program, seed: u64) -> Result<Memory, InterpError> {
    let mut mem = Memory::seeded(prog, seed);
    execute(prog, &mut mem)?;
    Ok(mem)
}

/// Compare two programs' *parameter* buffers (inputs are identical by
/// seeding; outputs must agree) after executing both from the same seed.
/// Returns the max absolute difference over all parameter buffers.
pub fn semantic_distance(a: &Program, b: &Program, seed: u64) -> Result<f64, InterpError> {
    let ma = run_seeded(a, seed)?;
    let mb = run_seeded(b, seed)?;
    let mut max = 0.0f64;
    for (&pa, &pb) in a.params.iter().zip(b.params.iter()) {
        for (x, y) in ma.bufs[pa].iter().zip(mb.bufs[pb].iter()) {
            max = max.max((x - y).abs() as f64);
        }
    }
    Ok(max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::trace::FactorArg;
    use crate::workloads;

    #[test]
    fn matmul_matches_host_reference() {
        let prog = workloads::matmul(1, 8, 8, 8);
        let mem = run_seeded(&prog, 1).unwrap();
        // Host-side reference from the same inputs.
        let (a, b, c) = (&mem.bufs[0], &mem.bufs[1], &mem.bufs[2]);
        for i in 0..8 {
            for j in 0..8 {
                let mut acc = 0.0f32;
                for k in 0..8 {
                    acc += a[i * 8 + k] * b[k * 8 + j];
                }
                assert!((acc - c[i * 8 + j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fused_dense_relu_nonnegative_and_consistent() {
        let prog = workloads::fused_dense(8, 16, 8);
        let mem = run_seeded(&prog, 2).unwrap();
        let out = &mem.bufs[prog.params[4]]; // Out
        assert!(out.iter().all(|&x| x >= 0.0));
        assert!(out.iter().any(|&x| x > 0.0));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let prog = workloads::softmax(1, 8, 8);
        let mem = run_seeded(&prog, 3).unwrap();
        let out = &mem.bufs[prog.params[1]];
        for i in 0..8 {
            let s: f32 = out[i * 8..(i + 1) * 8].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {i} sums to {s}");
        }
    }

    #[test]
    fn split_reorder_parallel_preserve_semantics() {
        let prog = workloads::matmul(1, 16, 16, 16);
        let mut s = Schedule::new(prog.clone(), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let i = s.split(loops[1], &[FactorArg::Lit(4), FactorArg::Lit(4)]).unwrap();
        let k = s.split(loops[3], &[FactorArg::Lit(2), FactorArg::Lit(8)]).unwrap();
        s.reorder(&[k[0], i[1]]).unwrap();
        s.parallel(i[0]).unwrap();
        let loops2 = s.get_loops(b).unwrap();
        s.vectorize(*loops2.last().unwrap()).unwrap_or(());
        let d = semantic_distance(&prog, &s.prog, 7).unwrap();
        assert_eq!(d, 0.0, "schedule changed program values");
    }

    #[test]
    fn compute_inline_preserves_semantics() {
        let prog = workloads::fused_dense(8, 8, 8);
        let mut s = Schedule::new(prog.clone(), 0);
        let bias = s.get_block("bias_add").unwrap();
        s.compute_inline(bias).unwrap();
        let d = semantic_distance(&prog, &s.prog, 11).unwrap();
        assert!(d < 1e-5, "inline changed values by {d}");
    }

    #[test]
    fn rfactor_preserves_semantics() {
        let prog = workloads::norm(1, 8, 32);
        let mut s = Schedule::new(prog.clone(), 0);
        let b = s.get_block("sq_sum").unwrap();
        let loops = s.get_loops(b).unwrap();
        let parts = s.split(loops[1], &[FactorArg::Lit(4), FactorArg::Lit(8)]).unwrap();
        s.rfactor(b, parts[0]).unwrap();
        let d = semantic_distance(&prog, &s.prog, 13).unwrap();
        assert!(d < 1e-4, "rfactor changed values by {d}");
    }

    #[test]
    fn opaque_blocks_rejected() {
        let prog = workloads::matmul(1, 16, 16, 16);
        let mut s = Schedule::new(prog, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.blockize(loops[3]).unwrap();
        assert!(matches!(
            run_seeded(&s.prog, 0),
            Err(InterpError::OpaqueBlock(_))
        ));
    }
}
