//! Blocks: the unit of computation in the IR (a TensorIR-style "block").
//!
//! A block owns its iteration variables (spatial or reduction), declares the
//! buffer regions it reads and writes, and carries a scalar body. Bindings
//! map each block iteration variable to an index expression over the
//! *enclosing loop variables*; loop transformations (split/fuse/reorder)
//! only ever rewrite bindings, never the body.

use std::collections::BTreeMap;

use crate::tir::buffer::Region;
use crate::tir::expr::{AExpr, BinOp, CExpr, VarId};

/// Kind of a block iteration variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IterKind {
    /// Data-parallel (output) axis.
    Spatial,
    /// Reduction axis.
    Reduce,
}

/// A block iteration variable with its domain and loop binding.
#[derive(Debug, Clone, PartialEq)]
pub struct IterVar {
    pub var: VarId,
    pub extent: i64,
    pub kind: IterKind,
    /// Value of this iter var in terms of enclosing loop variables.
    pub binding: AExpr,
}

/// Scalar body of a block.
#[derive(Debug, Clone, PartialEq)]
pub enum BlockBody {
    /// `writes[0][...] = expr`
    Assign { expr: CExpr },
    /// `writes[0][...] = init` on the first reduction step, then
    /// `writes[0][...] = op(writes[0][...], rhs)`.
    Reduce { init: CExpr, op: BinOp, rhs: CExpr },
    /// Structurally opaque block produced by blockize/tensorize; carries
    /// aggregate statistics of the computation it encloses.
    Opaque { flops_per_instance: f64 },
}

impl BlockBody {
    /// Weighted scalar ops per block instance.
    pub fn flops(&self) -> f64 {
        match self {
            BlockBody::Assign { expr } => expr.flops(),
            // One combiner op per step plus the rhs expression.
            BlockBody::Reduce { rhs, .. } => 1.0 + rhs.flops(),
            BlockBody::Opaque { flops_per_instance } => *flops_per_instance,
        }
    }

    pub fn is_reduction(&self) -> bool {
        matches!(self, BlockBody::Reduce { .. })
    }
}

/// A computation block.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockData {
    pub name: String,
    pub iters: Vec<IterVar>,
    pub reads: Vec<Region>,
    pub writes: Vec<Region>,
    pub body: BlockBody,
    /// Set by `decompose-reduction`: the init assignment has been hoisted
    /// into a separate block, this block only performs updates.
    pub init_decomposed: bool,
    pub annotations: BTreeMap<String, String>,
}

impl BlockData {
    pub fn new(name: impl Into<String>) -> BlockData {
        BlockData {
            name: name.into(),
            iters: Vec::new(),
            reads: Vec::new(),
            writes: Vec::new(),
            body: BlockBody::Assign {
                expr: CExpr::ConstF(0.0),
            },
            init_decomposed: false,
            annotations: BTreeMap::new(),
        }
    }

    /// Spatial iteration variables in declaration order.
    pub fn spatial_iters(&self) -> impl Iterator<Item = &IterVar> {
        self.iters.iter().filter(|iv| iv.kind == IterKind::Spatial)
    }

    /// Reduction iteration variables in declaration order.
    pub fn reduce_iters(&self) -> impl Iterator<Item = &IterVar> {
        self.iters.iter().filter(|iv| iv.kind == IterKind::Reduce)
    }

    pub fn is_reduction(&self) -> bool {
        self.iters.iter().any(|iv| iv.kind == IterKind::Reduce)
    }

    /// Whether the first write region is an identity over the spatial iter
    /// vars: dimension `d` is exactly `Var(spatial_d)` with extent 1. Such
    /// blocks can be inlined into consumers.
    pub fn write_is_trivial(&self) -> bool {
        let w = match self.writes.first() {
            Some(w) => w,
            None => return false,
        };
        let spatial: Vec<VarId> = self.spatial_iters().map(|iv| iv.var).collect();
        if w.ranges.len() != spatial.len() {
            return false;
        }
        w.ranges
            .iter()
            .zip(&spatial)
            .all(|((start, extent), v)| *extent == 1 && *start == AExpr::Var(*v))
    }

    /// Total block instances = product of iter extents.
    pub fn domain_size(&self) -> i64 {
        self.iters.iter().map(|iv| iv.extent).product()
    }

    pub fn annotate(&mut self, key: &str, value: &str) {
        self.annotations.insert(key.to_string(), value.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iter(var: VarId, extent: i64, kind: IterKind) -> IterVar {
        IterVar {
            var,
            extent,
            kind,
            binding: AExpr::Var(var + 100),
        }
    }

    #[test]
    fn spatial_and_reduce_partition() {
        let mut b = BlockData::new("matmul");
        b.iters = vec![
            iter(0, 64, IterKind::Spatial),
            iter(1, 64, IterKind::Spatial),
            iter(2, 32, IterKind::Reduce),
        ];
        assert_eq!(b.spatial_iters().count(), 2);
        assert_eq!(b.reduce_iters().count(), 1);
        assert!(b.is_reduction());
        assert_eq!(b.domain_size(), 64 * 64 * 32);
    }

    #[test]
    fn trivial_write_detection() {
        let mut b = BlockData::new("relu");
        b.iters = vec![iter(0, 8, IterKind::Spatial), iter(1, 8, IterKind::Spatial)];
        b.writes = vec![Region::point(0, vec![AExpr::Var(0), AExpr::Var(1)])];
        assert!(b.write_is_trivial());
        // Swapped indices are not an identity binding.
        b.writes = vec![Region::point(0, vec![AExpr::Var(1), AExpr::Var(0)])];
        assert!(!b.write_is_trivial());
    }

    #[test]
    fn reduce_body_flops() {
        let body = BlockBody::Reduce {
            init: CExpr::ConstF(0.0),
            op: BinOp::Add,
            rhs: CExpr::bin(
                BinOp::Mul,
                CExpr::load(0, vec![]),
                CExpr::load(1, vec![]),
            ),
        };
        assert_eq!(body.flops(), 2.0); // mul + add
    }
}
