//! Index and compute expressions for the tensor-program IR.
//!
//! Index expressions (`AExpr`) are affine-with-div/mod over interned loop /
//! block-iter variables — rich enough for strided, padded, dilated access
//! patterns (`i*stride + r*dilation - pad`) while keeping interval analysis
//! and substitution exact and fast. Compute expressions (`CExpr`) describe
//! the scalar computation of a block body.

use std::collections::HashMap;

/// Interned variable id. The owning [`crate::tir::Program`] maps ids to names.
pub type VarId = u32;

/// Index expression: affine combinations plus floordiv/mod by constants.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum AExpr {
    Var(VarId),
    Const(i64),
    Add(Box<AExpr>, Box<AExpr>),
    Sub(Box<AExpr>, Box<AExpr>),
    /// Multiply by an integer constant.
    Mul(Box<AExpr>, i64),
    /// Floor division by a positive constant.
    FloorDiv(Box<AExpr>, i64),
    /// Euclidean remainder by a positive constant.
    Mod(Box<AExpr>, i64),
}

impl AExpr {
    pub fn var(v: VarId) -> AExpr {
        AExpr::Var(v)
    }

    pub fn add(self, rhs: AExpr) -> AExpr {
        match (&self, &rhs) {
            (AExpr::Const(0), _) => rhs,
            (_, AExpr::Const(0)) => self,
            (AExpr::Const(a), AExpr::Const(b)) => AExpr::Const(a + b),
            _ => AExpr::Add(Box::new(self), Box::new(rhs)),
        }
    }

    pub fn sub(self, rhs: AExpr) -> AExpr {
        match (&self, &rhs) {
            (_, AExpr::Const(0)) => self,
            (AExpr::Const(a), AExpr::Const(b)) => AExpr::Const(a - b),
            _ => AExpr::Sub(Box::new(self), Box::new(rhs)),
        }
    }

    pub fn mul(self, c: i64) -> AExpr {
        match (&self, c) {
            (_, 1) => self,
            (_, 0) => AExpr::Const(0),
            (AExpr::Const(a), c) => AExpr::Const(a * c),
            _ => AExpr::Mul(Box::new(self), c),
        }
    }

    pub fn floordiv(self, c: i64) -> AExpr {
        debug_assert!(c > 0);
        match (&self, c) {
            (_, 1) => self,
            (AExpr::Const(a), c) => AExpr::Const(a.div_euclid(c)),
            _ => AExpr::FloorDiv(Box::new(self), c),
        }
    }

    pub fn modulo(self, c: i64) -> AExpr {
        debug_assert!(c > 0);
        match (&self, c) {
            (AExpr::Const(a), c) => AExpr::Const(a.rem_euclid(c)),
            _ => AExpr::Mod(Box::new(self), c),
        }
    }

    /// Substitute variables according to `map` (vars absent stay untouched).
    pub fn subst(&self, map: &HashMap<VarId, AExpr>) -> AExpr {
        match self {
            AExpr::Var(v) => map.get(v).cloned().unwrap_or(AExpr::Var(*v)),
            AExpr::Const(c) => AExpr::Const(*c),
            AExpr::Add(a, b) => a.subst(map).add(b.subst(map)),
            AExpr::Sub(a, b) => a.subst(map).sub(b.subst(map)),
            AExpr::Mul(a, c) => a.subst(map).mul(*c),
            AExpr::FloorDiv(a, c) => a.subst(map).floordiv(*c),
            AExpr::Mod(a, c) => a.subst(map).modulo(*c),
        }
    }

    /// Evaluate with a concrete assignment. Panics on unbound variable in
    /// debug builds; treats unbound as 0 in release (used only in tests).
    pub fn eval(&self, env: &HashMap<VarId, i64>) -> i64 {
        match self {
            AExpr::Var(v) => *env.get(v).unwrap_or(&0),
            AExpr::Const(c) => *c,
            AExpr::Add(a, b) => a.eval(env) + b.eval(env),
            AExpr::Sub(a, b) => a.eval(env) - b.eval(env),
            AExpr::Mul(a, c) => a.eval(env) * c,
            AExpr::FloorDiv(a, c) => a.eval(env).div_euclid(*c),
            AExpr::Mod(a, c) => a.eval(env).rem_euclid(*c),
        }
    }

    /// Collect the set of variables referenced.
    pub fn collect_vars(&self, out: &mut Vec<VarId>) {
        match self {
            AExpr::Var(v) => {
                if !out.contains(v) {
                    out.push(*v);
                }
            }
            AExpr::Const(_) => {}
            AExpr::Add(a, b) | AExpr::Sub(a, b) => {
                a.collect_vars(out);
                b.collect_vars(out);
            }
            AExpr::Mul(a, _) | AExpr::FloorDiv(a, _) | AExpr::Mod(a, _) => a.collect_vars(out),
        }
    }

    pub fn uses_var(&self, v: VarId) -> bool {
        match self {
            AExpr::Var(x) => *x == v,
            AExpr::Const(_) => false,
            AExpr::Add(a, b) | AExpr::Sub(a, b) => a.uses_var(v) || b.uses_var(v),
            AExpr::Mul(a, _) | AExpr::FloorDiv(a, _) | AExpr::Mod(a, _) => a.uses_var(v),
        }
    }

    /// Interval (min/max inclusive) of the expression when each variable
    /// ranges over the interval given in `env`. Exact for affine parts;
    /// conservative (but tight for the patterns we generate) for div/mod.
    pub fn interval(&self, env: &HashMap<VarId, (i64, i64)>) -> (i64, i64) {
        match self {
            AExpr::Var(v) => *env.get(v).unwrap_or(&(0, 0)),
            AExpr::Const(c) => (*c, *c),
            AExpr::Add(a, b) => {
                let (al, ah) = a.interval(env);
                let (bl, bh) = b.interval(env);
                (al + bl, ah + bh)
            }
            AExpr::Sub(a, b) => {
                let (al, ah) = a.interval(env);
                let (bl, bh) = b.interval(env);
                (al - bh, ah - bl)
            }
            AExpr::Mul(a, c) => {
                let (al, ah) = a.interval(env);
                if *c >= 0 {
                    (al * c, ah * c)
                } else {
                    (ah * c, al * c)
                }
            }
            AExpr::FloorDiv(a, c) => {
                let (al, ah) = a.interval(env);
                (al.div_euclid(*c), ah.div_euclid(*c))
            }
            AExpr::Mod(a, c) => {
                let (al, ah) = a.interval(env);
                // If the whole range lies in one "period" the mod is exact.
                if al.div_euclid(*c) == ah.div_euclid(*c) {
                    (al.rem_euclid(*c), ah.rem_euclid(*c))
                } else {
                    (0, c - 1)
                }
            }
        }
    }

    /// Width (number of distinct values, max-min+1) over the given ranges.
    pub fn width(&self, env: &HashMap<VarId, (i64, i64)>) -> i64 {
        let (lo, hi) = self.interval(env);
        hi - lo + 1
    }
}

/// Binary scalar ops appearing in block bodies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
}

impl BinOp {
    pub fn name(self) -> &'static str {
        match self {
            BinOp::Add => "add",
            BinOp::Sub => "sub",
            BinOp::Mul => "mul",
            BinOp::Div => "div",
            BinOp::Max => "max",
            BinOp::Min => "min",
        }
    }
}

/// Unary scalar ops / intrinsics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Exp,
    Sqrt,
    Rsqrt,
    Relu,
    Tanh,
    Erf,
    CastF32,
    CastBF16,
}

impl UnOp {
    pub fn name(self) -> &'static str {
        match self {
            UnOp::Neg => "neg",
            UnOp::Exp => "exp",
            UnOp::Sqrt => "sqrt",
            UnOp::Rsqrt => "rsqrt",
            UnOp::Relu => "relu",
            UnOp::Tanh => "tanh",
            UnOp::Erf => "erf",
            UnOp::CastF32 => "f32",
            UnOp::CastBF16 => "bf16",
        }
    }

    /// Approximate scalar-op cost relative to an FMA (used by the simulator).
    pub fn flop_cost(self) -> f64 {
        match self {
            UnOp::Neg | UnOp::Relu | UnOp::CastF32 | UnOp::CastBF16 => 1.0,
            UnOp::Sqrt | UnOp::Rsqrt => 4.0,
            UnOp::Exp | UnOp::Tanh | UnOp::Erf => 8.0,
        }
    }
}

/// Scalar compute expression of a block body. Buffer loads are indexed by
/// `AExpr`s over the *block iteration variables*.
#[derive(Debug, Clone, PartialEq)]
pub enum CExpr {
    /// Load `buffers[id][indices...]`.
    Load(usize, Vec<AExpr>),
    ConstF(f64),
    Bin(BinOp, Box<CExpr>, Box<CExpr>),
    Un(UnOp, Box<CExpr>),
}

impl CExpr {
    pub fn load(buffer: usize, indices: Vec<AExpr>) -> CExpr {
        CExpr::Load(buffer, indices)
    }

    pub fn bin(op: BinOp, a: CExpr, b: CExpr) -> CExpr {
        CExpr::Bin(op, Box::new(a), Box::new(b))
    }

    pub fn un(op: UnOp, a: CExpr) -> CExpr {
        CExpr::Un(op, Box::new(a))
    }

    /// Count of scalar operations per evaluation (weighted by op cost).
    pub fn flops(&self) -> f64 {
        match self {
            CExpr::Load(_, _) | CExpr::ConstF(_) => 0.0,
            CExpr::Bin(_, a, b) => 1.0 + a.flops() + b.flops(),
            CExpr::Un(op, a) => op.flop_cost() + a.flops(),
        }
    }

    /// Substitute index variables inside all loads.
    pub fn subst_indices(&self, map: &HashMap<VarId, AExpr>) -> CExpr {
        match self {
            CExpr::Load(b, idx) => {
                CExpr::Load(*b, idx.iter().map(|e| e.subst(map)).collect())
            }
            CExpr::ConstF(c) => CExpr::ConstF(*c),
            CExpr::Bin(op, a, b) => CExpr::bin(*op, a.subst_indices(map), b.subst_indices(map)),
            CExpr::Un(op, a) => CExpr::un(*op, a.subst_indices(map)),
        }
    }

    /// Replace every `Load(buffer, idx)` via `f` (used by inlining and
    /// cache-read redirection).
    pub fn map_loads(&self, f: &mut impl FnMut(usize, &[AExpr]) -> CExpr) -> CExpr {
        match self {
            CExpr::Load(b, idx) => f(*b, idx),
            CExpr::ConstF(c) => CExpr::ConstF(*c),
            CExpr::Bin(op, a, b) => CExpr::bin(*op, a.map_loads(f), b.map_loads(f)),
            CExpr::Un(op, a) => CExpr::un(*op, a.map_loads(f)),
        }
    }

    /// All buffers loaded, with multiplicity.
    pub fn loaded_buffers(&self, out: &mut Vec<usize>) {
        match self {
            CExpr::Load(b, _) => out.push(*b),
            CExpr::ConstF(_) => {}
            CExpr::Bin(_, a, b) => {
                a.loaded_buffers(out);
                b.loaded_buffers(out);
            }
            CExpr::Un(_, a) => a.loaded_buffers(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(VarId, (i64, i64))]) -> HashMap<VarId, (i64, i64)> {
        pairs.iter().cloned().collect()
    }

    #[test]
    fn constant_folding_in_builders() {
        let e = AExpr::Const(3).add(AExpr::Const(4)).mul(2);
        assert_eq!(e, AExpr::Const(14));
        assert_eq!(AExpr::Var(0).mul(1), AExpr::Var(0));
        assert_eq!(AExpr::Var(0).add(AExpr::Const(0)), AExpr::Var(0));
    }

    #[test]
    fn interval_of_strided_padded_access() {
        // i*2 + r - 3 with i in [0,111], r in [0,6]  (conv-style index)
        let e = AExpr::Var(0).mul(2).add(AExpr::Var(1)).sub(AExpr::Const(3));
        let (lo, hi) = e.interval(&env(&[(0, (0, 111)), (1, (0, 6))]));
        assert_eq!((lo, hi), (-3, 225));
    }

    #[test]
    fn interval_mod_single_period_exact() {
        let e = AExpr::Var(0).modulo(8);
        assert_eq!(e.interval(&env(&[(0, (2, 5))])), (2, 5));
        assert_eq!(e.interval(&env(&[(0, (2, 11))])), (0, 7));
    }

    #[test]
    fn subst_split_pattern_preserves_value() {
        // i -> i0*8 + i1, evaluate both sides.
        let orig = AExpr::Var(0).mul(3).add(AExpr::Const(1));
        let mut m = HashMap::new();
        m.insert(0, AExpr::Var(1).mul(8).add(AExpr::Var(2)));
        let sub = orig.subst(&m);
        let mut env_val = HashMap::new();
        env_val.insert(1, 5i64);
        env_val.insert(2, 3i64);
        let i = 5 * 8 + 3;
        let mut env_orig = HashMap::new();
        env_orig.insert(0, i);
        assert_eq!(sub.eval(&env_val), orig.eval(&env_orig));
    }

    #[test]
    fn fuse_pattern_roundtrip() {
        // outer = f / 4, inner = f % 4; f = outer*4+inner must round-trip.
        let outer = AExpr::Var(9).floordiv(4);
        let inner = AExpr::Var(9).modulo(4);
        for f in 0..16 {
            let mut env_val = HashMap::new();
            env_val.insert(9, f);
            assert_eq!(outer.eval(&env_val) * 4 + inner.eval(&env_val), f);
        }
    }

    #[test]
    fn cexpr_flops_counts_weighted_ops() {
        // relu(a*b + c) = 1 mul + 1 add + 1 relu = 3 weighted flops
        let e = CExpr::un(
            UnOp::Relu,
            CExpr::bin(
                BinOp::Add,
                CExpr::bin(
                    BinOp::Mul,
                    CExpr::load(0, vec![AExpr::Var(0)]),
                    CExpr::load(1, vec![AExpr::Var(0)]),
                ),
                CExpr::ConstF(1.0),
            ),
        );
        assert_eq!(e.flops(), 3.0);
    }

    #[test]
    fn map_loads_rewrites_buffers() {
        let e = CExpr::bin(
            BinOp::Add,
            CExpr::load(0, vec![AExpr::Var(0)]),
            CExpr::load(1, vec![AExpr::Var(1)]),
        );
        let r = e.map_loads(&mut |b, idx| {
            if b == 0 {
                CExpr::load(7, idx.to_vec())
            } else {
                CExpr::Load(b, idx.to_vec())
            }
        });
        let mut bufs = vec![];
        r.loaded_buffers(&mut bufs);
        assert_eq!(bufs, vec![7, 1]);
    }

    #[test]
    fn collect_vars_dedups() {
        let e = AExpr::Var(2).add(AExpr::Var(2).mul(3)).add(AExpr::Var(5));
        let mut vs = vec![];
        e.collect_vars(&mut vs);
        assert_eq!(vs, vec![2, 5]);
    }
}
