//! Structural analyses over tensor programs: loop classification, FLOP
//! counting, region footprints. These feed the transformation modules
//! (which must identify spatial vs. reduction loops, per Figure 4 of the
//! paper) and the hardware simulator.

use std::collections::HashMap;

use crate::tir::block::IterKind;
use crate::tir::expr::VarId;
use crate::tir::program::{ItemId, ItemKind, Program};

/// Classification of a loop with respect to the blocks beneath it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopClass {
    /// Feeds only spatial block iters — safe to parallelize / vectorize.
    Spatial,
    /// Feeds only reduction block iters.
    Reduce,
    /// Feeds both (e.g. after fusing a spatial with a reduce loop).
    Mixed,
    /// Feeds no block iter (unit/dead loop).
    Unused,
}

/// Classify `loop_id` by scanning iter bindings of all blocks beneath it.
pub fn classify_loop(p: &Program, loop_id: ItemId) -> LoopClass {
    let var = p.loop_data(loop_id).var;
    let mut spatial = false;
    let mut reduce = false;
    for b in p.blocks_under(loop_id) {
        for iv in &p.block_data(b).iters {
            if iv.binding.uses_var(var) {
                match iv.kind {
                    IterKind::Spatial => spatial = true,
                    IterKind::Reduce => reduce = true,
                }
            }
        }
    }
    match (spatial, reduce) {
        (true, false) => LoopClass::Spatial,
        (false, true) => LoopClass::Reduce,
        (true, true) => LoopClass::Mixed,
        (false, false) => LoopClass::Unused,
    }
}

/// Number of times a block executes = product of enclosing loop extents.
pub fn block_trip_count(p: &Program, block: ItemId) -> i64 {
    p.loops_above(block)
        .iter()
        .map(|&l| p.loop_data(l).extent)
        .product()
}

/// Total weighted floating-point operations of the program.
pub fn program_flops(p: &Program) -> f64 {
    p.blocks()
        .iter()
        .map(|&b| block_trip_count(p, b) as f64 * p.block_data(b).body.flops())
        .sum()
}

/// Footprint in *elements* of one region access when the variables in
/// `free_vars` sweep their full ranges and all other variables are fixed.
///
/// This is the core quantity behind the cache model: fixing the loops
/// outside level L and sweeping the loops inside gives the working set at
/// level L.
pub fn region_footprint_elems(
    region_ranges: &[(crate::tir::expr::AExpr, i64)],
    sweep_env: &HashMap<VarId, (i64, i64)>,
) -> i64 {
    region_ranges
        .iter()
        .map(|(start, extent)| {
            let width = start.width(sweep_env);
            width + extent - 1
        })
        .product()
}

/// Environment where the given loops sweep fully and all other vars are
/// pinned (range (0,0)).
pub fn sweep_env(p: &Program, sweeping: &[ItemId]) -> HashMap<VarId, (i64, i64)> {
    let mut env = HashMap::new();
    for &l in sweeping {
        let d = p.loop_data(l);
        env.insert(d.var, (0, d.extent - 1));
    }
    env
}

/// For a block, resolve each iter var to its binding interval under `env`
/// (loop vars -> ranges), yielding an env over *block iter vars*.
pub fn iter_env(
    p: &Program,
    block: ItemId,
    loop_env: &HashMap<VarId, (i64, i64)>,
) -> HashMap<VarId, (i64, i64)> {
    p.block_data(block)
        .iters
        .iter()
        .map(|iv| (iv.var, iv.binding.interval(loop_env)))
        .collect()
}

/// Innermost loop above a block, if any.
pub fn innermost_loop(p: &Program, block: ItemId) -> Option<ItemId> {
    p.loops_above(block).last().copied()
}

/// Whether `maybe_ancestor` is an ancestor of `item` (or equal).
pub fn is_ancestor(p: &Program, maybe_ancestor: ItemId, item: ItemId) -> bool {
    let mut cur = Some(item);
    while let Some(i) = cur {
        if i == maybe_ancestor {
            return true;
        }
        cur = p.items[i].parent;
    }
    false
}

/// Row-major linear address stride of one region access per unit step of
/// `loop_var`: substitute iter-var bindings, take the coefficient of
/// `loop_var` in each index, and weight by the buffer's row-major dim
/// strides. |stride| <= 1 means the access is vector-friendly (stride-1
/// contiguous or stride-0 broadcast) when that loop is vectorized.
pub fn linear_stride(
    p: &Program,
    region: &crate::tir::buffer::Region,
    iter_bindings: &HashMap<VarId, crate::tir::expr::AExpr>,
    loop_var: VarId,
) -> i64 {
    let shape = &p.buffers[region.buffer].shape;
    let mut stride = 1i64;
    let mut total = 0i64;
    for (d, (start, _)) in region.ranges.iter().enumerate().rev() {
        let e = start.subst(iter_bindings);
        let mut env: HashMap<VarId, i64> = HashMap::new();
        let mut vars = Vec::new();
        e.collect_vars(&mut vars);
        for v in vars {
            env.insert(v, 0);
        }
        let base = e.eval(&env);
        env.insert(loop_var, 1);
        let coef = e.eval(&env) - base;
        total += coef.saturating_mul(stride);
        stride = stride.saturating_mul(shape.get(d).copied().unwrap_or(1).max(1));
    }
    total
}

/// Count of live loops in the program.
pub fn loop_count(p: &Program) -> usize {
    p.preorder()
        .into_iter()
        .filter(|&i| matches!(p.items[i].kind, ItemKind::Loop(_)))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::block::{BlockBody, BlockData, IterVar};
    use crate::tir::buffer::{Buffer, DType, Region};
    use crate::tir::expr::{AExpr, BinOp, CExpr};
    use crate::tir::program::LoopData;

    /// C[i,j] += A[i,k] * B[k,j] over 16x16x8.
    fn matmul() -> (Program, ItemId) {
        let mut p = Program::new("mm");
        let a = p.add_buffer(Buffer::new("A", vec![16, 8], DType::F32));
        let b = p.add_buffer(Buffer::new("B", vec![8, 16], DType::F32));
        let c = p.add_buffer(Buffer::new("C", vec![16, 16], DType::F32));
        p.params = vec![a, b, c];
        let li_v = p.fresh_var("i");
        let lj_v = p.fresh_var("j");
        let lk_v = p.fresh_var("k");
        let bi = p.fresh_var("bi");
        let bj = p.fresh_var("bj");
        let bk = p.fresh_var("bk");
        let li = p.alloc_loop(LoopData::new(li_v, 16));
        let lj = p.alloc_loop(LoopData::new(lj_v, 16));
        let lk = p.alloc_loop(LoopData::new(lk_v, 8));
        let mut blk = BlockData::new("matmul");
        blk.iters = vec![
            IterVar {
                var: bi,
                extent: 16,
                kind: IterKind::Spatial,
                binding: AExpr::Var(li_v),
            },
            IterVar {
                var: bj,
                extent: 16,
                kind: IterKind::Spatial,
                binding: AExpr::Var(lj_v),
            },
            IterVar {
                var: bk,
                extent: 8,
                kind: IterKind::Reduce,
                binding: AExpr::Var(lk_v),
            },
        ];
        blk.reads = vec![
            Region::point(a, vec![AExpr::Var(bi), AExpr::Var(bk)]),
            Region::point(b, vec![AExpr::Var(bk), AExpr::Var(bj)]),
        ];
        blk.writes = vec![Region::point(c, vec![AExpr::Var(bi), AExpr::Var(bj)])];
        blk.body = BlockBody::Reduce {
            init: CExpr::ConstF(0.0),
            op: BinOp::Add,
            rhs: CExpr::bin(
                BinOp::Mul,
                CExpr::load(a, vec![AExpr::Var(bi), AExpr::Var(bk)]),
                CExpr::load(b, vec![AExpr::Var(bk), AExpr::Var(bj)]),
            ),
        };
        let blk = p.alloc_block(blk);
        p.attach(li, None);
        p.attach(lj, Some(li));
        p.attach(lk, Some(lj));
        p.attach(blk, Some(lk));
        (p, blk)
    }

    #[test]
    fn classifies_loops() {
        let (p, blk) = matmul();
        let loops = p.loops_above(blk);
        assert_eq!(classify_loop(&p, loops[0]), LoopClass::Spatial);
        assert_eq!(classify_loop(&p, loops[1]), LoopClass::Spatial);
        assert_eq!(classify_loop(&p, loops[2]), LoopClass::Reduce);
    }

    #[test]
    fn flops_of_matmul() {
        let (p, _) = matmul();
        // 16*16*8 instances * (mul + add) = 4096
        assert_eq!(program_flops(&p), 16.0 * 16.0 * 8.0 * 2.0);
    }

    #[test]
    fn footprint_under_sweep() {
        let (p, blk) = matmul();
        let loops = p.loops_above(blk);
        // Sweep only k (innermost): A touches 1x8, B touches 8x1, C 1x1.
        let le = sweep_env(&p, &loops[2..]);
        let ie = iter_env(&p, blk, &le);
        let bd = p.block_data(blk);
        assert_eq!(region_footprint_elems(&bd.reads[0].ranges, &ie), 8);
        assert_eq!(region_footprint_elems(&bd.reads[1].ranges, &ie), 8);
        assert_eq!(region_footprint_elems(&bd.writes[0].ranges, &ie), 1);
        // Sweep j and k: A row of 8, B 8x16, C row of 16.
        let le = sweep_env(&p, &loops[1..]);
        let ie = iter_env(&p, blk, &le);
        assert_eq!(region_footprint_elems(&bd.reads[1].ranges, &ie), 128);
        assert_eq!(region_footprint_elems(&bd.writes[0].ranges, &ie), 16);
    }

    #[test]
    fn ancestor_relation() {
        let (p, blk) = matmul();
        let loops = p.loops_above(blk);
        assert!(is_ancestor(&p, loops[0], blk));
        assert!(!is_ancestor(&p, blk, loops[0]));
    }
}
