//! Pretty-printer for tensor programs (TVMScript-flavoured text) and the
//! normalized form used for structural hashing / task deduplication.

use std::collections::HashMap;

use crate::tir::block::{BlockBody, IterKind};
use crate::tir::expr::{AExpr, CExpr, VarId};
use crate::tir::program::{ItemKind, Program};

/// Options controlling printing.
#[derive(Debug, Clone, Copy)]
pub struct PrintOptions {
    /// Rename variables by order of first appearance (`v0`, `v1`, …) so two
    /// structurally-identical programs print identically.
    pub normalize_vars: bool,
}

impl Default for PrintOptions {
    fn default() -> Self {
        PrintOptions {
            normalize_vars: false,
        }
    }
}

struct Printer<'a> {
    p: &'a Program,
    opts: PrintOptions,
    rename: HashMap<VarId, String>,
    out: String,
}

impl<'a> Printer<'a> {
    fn var(&mut self, v: VarId) -> String {
        if self.opts.normalize_vars {
            if let Some(n) = self.rename.get(&v) {
                return n.clone();
            }
            let n = format!("v{}", self.rename.len());
            self.rename.insert(v, n.clone());
            n
        } else {
            self.p.var_name(v).to_string()
        }
    }

    fn aexpr(&mut self, e: &AExpr) -> String {
        match e {
            AExpr::Var(v) => self.var(*v),
            AExpr::Const(c) => c.to_string(),
            AExpr::Add(a, b) => format!("({} + {})", self.aexpr(a), self.aexpr(b)),
            AExpr::Sub(a, b) => format!("({} - {})", self.aexpr(a), self.aexpr(b)),
            AExpr::Mul(a, c) => format!("({}*{})", self.aexpr(a), c),
            AExpr::FloorDiv(a, c) => format!("({} // {})", self.aexpr(a), c),
            AExpr::Mod(a, c) => format!("({} % {})", self.aexpr(a), c),
        }
    }

    fn cexpr(&mut self, e: &CExpr) -> String {
        match e {
            CExpr::Load(b, idx) => {
                let name = self.p.buffers[*b].name.clone();
                let idx: Vec<String> = idx.iter().map(|i| self.aexpr(i)).collect();
                format!("{}[{}]", name, idx.join(", "))
            }
            CExpr::ConstF(c) => format!("{c}"),
            CExpr::Bin(op, a, b) => {
                format!("{}({}, {})", op.name(), self.cexpr(a), self.cexpr(b))
            }
            CExpr::Un(op, a) => format!("{}({})", op.name(), self.cexpr(a)),
        }
    }

    fn indent(&mut self, depth: usize) {
        for _ in 0..depth {
            self.out.push_str("  ");
        }
    }

    fn item(&mut self, id: usize, depth: usize) {
        match &self.p.items[id].kind {
            ItemKind::Loop(l) => {
                let l = l.clone();
                self.indent(depth);
                let var = self.var(l.var);
                let kind = match l.kind {
                    crate::tir::program::LoopKind::Serial => String::new(),
                    k => format!(" ({})", k.name()),
                };
                let ann = if l.annotations.is_empty() {
                    String::new()
                } else {
                    format!(
                        " @[{}]",
                        l.annotations
                            .iter()
                            .map(|(k, v)| format!("{k}={v}"))
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                };
                self.out
                    .push_str(&format!("for {} in {}{}{} {{\n", var, l.extent, kind, ann));
                for c in self.p.items[id].children.clone() {
                    self.item(c, depth + 1);
                }
                self.indent(depth);
                self.out.push_str("}\n");
            }
            ItemKind::Block(b) => {
                let b = b.clone();
                self.indent(depth);
                let iters: Vec<String> = b
                    .iters
                    .iter()
                    .map(|iv| {
                        let tag = match iv.kind {
                            IterKind::Spatial => "",
                            IterKind::Reduce => "[reduce]",
                        };
                        let name = self.var(iv.var);
                        let bind = self.aexpr(&iv.binding);
                        format!("{}{}:{} = {}", name, tag, iv.extent, bind)
                    })
                    .collect();
                self.out
                    .push_str(&format!("block {}({}) {{\n", b.name, iters.join(", ")));
                for (label, regions) in [("reads", &b.reads), ("writes", &b.writes)] {
                    self.indent(depth + 1);
                    let rs: Vec<String> = regions
                        .iter()
                        .map(|r| {
                            let name = self.p.buffers[r.buffer].name.clone();
                            let dims: Vec<String> = r
                                .ranges
                                .iter()
                                .map(|(start, extent)| {
                                    if *extent == 1 {
                                        self.aexpr(start)
                                    } else {
                                        format!("{}+:{}", self.aexpr(start), extent)
                                    }
                                })
                                .collect();
                            format!("{}[{}]", name, dims.join(", "))
                        })
                        .collect();
                    self.out.push_str(&format!("{}: {}\n", label, rs.join(", ")));
                }
                self.indent(depth + 1);
                match &b.body {
                    BlockBody::Assign { expr } => {
                        let e = self.cexpr(expr);
                        self.out.push_str(&format!("out = {e}\n"));
                    }
                    BlockBody::Reduce { init, op, rhs } => {
                        let i = self.cexpr(init);
                        let r = self.cexpr(rhs);
                        self.out
                            .push_str(&format!("out = {}(out, {r}) [init = {i}]\n", op.name()));
                    }
                    BlockBody::Opaque { flops_per_instance } => {
                        self.out
                            .push_str(&format!("opaque [flops={flops_per_instance}]\n"));
                    }
                }
                if !b.annotations.is_empty() {
                    self.indent(depth + 1);
                    let ann: Vec<String> = b
                        .annotations
                        .iter()
                        .map(|(k, v)| format!("{k}={v}"))
                        .collect();
                    self.out.push_str(&format!("@[{}]\n", ann.join(", ")));
                }
                self.indent(depth);
                self.out.push_str("}\n");
            }
        }
    }
}

/// Render a program to text.
pub fn print_program(p: &Program, opts: PrintOptions) -> String {
    let mut pr = Printer {
        p,
        opts,
        rename: HashMap::new(),
        out: String::new(),
    };
    let sig: Vec<String> = p
        .params
        .iter()
        .map(|&b| {
            let buf = &p.buffers[b];
            let dims: Vec<String> = buf.shape.iter().map(|d| d.to_string()).collect();
            format!("{}: {}[{}]", buf.name, buf.dtype.name(), dims.join(","))
        })
        .collect();
    pr.out
        .push_str(&format!("func {}({}) {{\n", p.name, sig.join(", ")));
    for r in p.roots.clone() {
        pr.item(r, 1);
    }
    pr.out.push_str("}\n");
    pr.out
}

/// FNV-1a over the normalized print — the structural hash used for task
/// deduplication in graph-level tuning.
pub fn structural_hash(p: &Program) -> u64 {
    let text = print_program(
        p,
        PrintOptions {
            normalize_vars: true,
        },
    );
    let mut h: u64 = 0xcbf29ce484222325;
    for byte in text.bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl std::fmt::Display for Program {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", print_program(self, PrintOptions::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::block::BlockData;
    use crate::tir::buffer::{Buffer, DType};
    use crate::tir::program::LoopData;

    fn prog(name_hint: &str) -> Program {
        let mut p = Program::new("t");
        let a = p.add_buffer(Buffer::new("A", vec![8], DType::F32));
        p.params = vec![a];
        let v = p.fresh_var(name_hint);
        let l = p.alloc_loop(LoopData::new(v, 8));
        let b = p.alloc_block(BlockData::new("B"));
        p.attach(l, None);
        p.attach(b, Some(l));
        p
    }

    #[test]
    fn prints_signature_and_structure() {
        let p = prog("i");
        let text = print_program(&p, PrintOptions::default());
        assert!(text.contains("func t(A: f32[8])"));
        assert!(text.contains("for i0 in 8 {"));
        assert!(text.contains("block B("));
    }

    #[test]
    fn structural_hash_ignores_var_names() {
        let p1 = prog("i");
        let p2 = prog("zzz");
        assert_eq!(structural_hash(&p1), structural_hash(&p2));
    }

    #[test]
    fn structural_hash_sees_extent_change() {
        let p1 = prog("i");
        let mut p2 = prog("i");
        // change loop extent
        let l = p2.roots[0];
        p2.loop_data_mut(l).extent = 16;
        assert_ne!(structural_hash(&p1), structural_hash(&p2));
    }
}
