//! Tensor-program IR (a TensorIR-style substrate built from scratch).
//!
//! The paper schedules TVM TensorIR programs; this module provides the
//! equivalent substrate: buffers with storage scopes, blocks with
//! spatial/reduction iteration variables bound to an enclosing loop tree,
//! affine index expressions amenable to exact interval analysis, a
//! pretty-printer, and the structural analyses the transformation modules
//! and the hardware simulator rely on.

pub mod analysis;
pub mod block;
pub mod buffer;
pub mod builder;
pub mod expr;
pub mod interp;
pub mod printer;
pub mod program;

pub use block::{BlockBody, BlockData, IterKind, IterVar};
pub use buffer::{Buffer, DType, Region, Scope};
pub use builder::{rd, sp, Axis};
pub use expr::{AExpr, BinOp, CExpr, UnOp, VarId};
pub use printer::{print_program, structural_hash, PrintOptions};
pub use program::{Item, ItemId, ItemKind, LoopData, LoopKind, Program};
