//! Buffers, storage scopes, and accessed regions.

use crate::tir::expr::AExpr;

/// Element datatype of a buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    BF16,
    F16,
    I32,
}

impl DType {
    pub fn bytes(self) -> i64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::I32 => "i32",
        }
    }
}

/// Storage scope of a buffer in the memory hierarchy.
///
/// `Shared`/`Local` follow the CUDA naming the paper uses; on the TPU
/// adaptation `Shared` models VMEM staging and `Wmma*` model the MXU input /
/// accumulator registers (see DESIGN.md §Hardware-Adaptation).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Scope {
    /// Off-chip memory (DRAM / HBM).
    Global,
    /// On-chip scratchpad shared by a thread block (shared mem / VMEM).
    Shared,
    /// Per-thread registers / local cache.
    Local,
    /// Tensor-intrinsic staging fragment, e.g. "wmma.matrix_a".
    Wmma(String),
}

impl Scope {
    pub fn parse(s: &str) -> Scope {
        match s {
            "global" => Scope::Global,
            "shared" | "shared.dyn" => Scope::Shared,
            "local" => Scope::Local,
            other => Scope::Wmma(other.to_string()),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Scope::Global => "global".into(),
            Scope::Shared => "shared".into(),
            Scope::Local => "local".into(),
            Scope::Wmma(s) => s.clone(),
        }
    }
}

/// A tensor buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct Buffer {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: DType,
    pub scope: Scope,
    /// Storage alignment requirement in bytes (set by `storage-align`).
    pub align: i64,
    /// True once the buffer has been eliminated by compute-inline.
    pub inlined: bool,
}

impl Buffer {
    pub fn new(name: impl Into<String>, shape: Vec<i64>, dtype: DType) -> Buffer {
        Buffer {
            name: name.into(),
            shape,
            dtype,
            scope: Scope::Global,
            align: dtype.bytes(),
            inlined: false,
        }
    }

    /// Total elements.
    pub fn numel(&self) -> i64 {
        self.shape.iter().product()
    }

    /// Total bytes.
    pub fn bytes(&self) -> i64 {
        self.numel() * self.dtype.bytes()
    }
}

/// A rectangular region of a buffer: per-dimension `(start, extent)` where
/// `start` is an index expression over block iteration variables and
/// `extent` a constant. A point access has extent 1 in every dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct Region {
    pub buffer: usize,
    pub ranges: Vec<(AExpr, i64)>,
}

impl Region {
    /// A single-element access at the given indices.
    pub fn point(buffer: usize, indices: Vec<AExpr>) -> Region {
        Region {
            buffer,
            ranges: indices.into_iter().map(|e| (e, 1)).collect(),
        }
    }

    /// Elements covered by one access of this region.
    pub fn extent_numel(&self) -> i64 {
        self.ranges.iter().map(|(_, e)| *e).product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::BF16.bytes(), 2);
    }

    #[test]
    fn buffer_bytes() {
        let b = Buffer::new("A", vec![128, 128], DType::F32);
        assert_eq!(b.numel(), 128 * 128);
        assert_eq!(b.bytes(), 128 * 128 * 4);
    }

    #[test]
    fn scope_roundtrip() {
        for s in ["global", "shared", "local", "wmma.accumulator"] {
            let sc = Scope::parse(s);
            if s == "shared.dyn" {
                assert_eq!(sc.name(), "shared");
            } else {
                assert_eq!(sc.name(), s);
            }
        }
    }

    #[test]
    fn region_extent() {
        let r = Region {
            buffer: 0,
            ranges: vec![(AExpr::Const(0), 16), (AExpr::Const(0), 16)],
        };
        assert_eq!(r.extent_numel(), 256);
        let p = Region::point(0, vec![AExpr::Var(0), AExpr::Var(1)]);
        assert_eq!(p.extent_numel(), 1);
    }
}
