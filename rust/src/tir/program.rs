//! The tensor program: an arena of loops and blocks forming a forest.
//!
//! Items (loops and blocks) live in a flat arena with stable ids, so
//! schedule primitives can hold handles across transformations. Structure
//! is parent/children links; removal tombstones the item (`alive = false`).

use std::collections::{BTreeMap, HashMap};

use crate::tir::block::BlockData;
use crate::tir::buffer::Buffer;
use crate::tir::expr::{AExpr, VarId};

/// Index into [`Program::items`].
pub type ItemId = usize;

/// Execution kind of a loop.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum LoopKind {
    Serial,
    Parallel,
    Vectorized,
    Unrolled,
    /// Bound to a hardware thread axis, e.g. "blockIdx.x", "threadIdx.y".
    ThreadBinding(String),
}

impl LoopKind {
    pub fn name(&self) -> String {
        match self {
            LoopKind::Serial => "serial".into(),
            LoopKind::Parallel => "parallel".into(),
            LoopKind::Vectorized => "vectorized".into(),
            LoopKind::Unrolled => "unrolled".into(),
            LoopKind::ThreadBinding(t) => format!("thread<{t}>"),
        }
    }
}

/// A loop node.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopData {
    pub var: VarId,
    pub extent: i64,
    pub kind: LoopKind,
    pub annotations: BTreeMap<String, String>,
}

impl LoopData {
    pub fn new(var: VarId, extent: i64) -> LoopData {
        LoopData {
            var,
            extent,
            kind: LoopKind::Serial,
            annotations: BTreeMap::new(),
        }
    }
}

/// Arena item payload.
#[derive(Debug, Clone, PartialEq)]
pub enum ItemKind {
    Loop(LoopData),
    Block(BlockData),
}

/// Arena item: payload plus tree links.
#[derive(Debug, Clone)]
pub struct Item {
    pub parent: Option<ItemId>,
    pub children: Vec<ItemId>,
    pub kind: ItemKind,
    pub alive: bool,
}

/// A complete tensor program.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    /// Variable names, indexed by `VarId`.
    pub vars: Vec<String>,
    pub buffers: Vec<Buffer>,
    pub items: Vec<Item>,
    /// Top-level items in execution order.
    pub roots: Vec<ItemId>,
    /// Ids of buffers that are kernel parameters (inputs + outputs).
    pub params: Vec<usize>,
}

impl Program {
    pub fn new(name: impl Into<String>) -> Program {
        Program {
            name: name.into(),
            vars: Vec::new(),
            buffers: Vec::new(),
            items: Vec::new(),
            roots: Vec::new(),
            params: Vec::new(),
        }
    }

    // ---- construction -----------------------------------------------------

    /// Intern a fresh variable with the given name hint.
    pub fn fresh_var(&mut self, hint: &str) -> VarId {
        let id = self.vars.len() as VarId;
        self.vars.push(format!("{hint}{id}"));
        id
    }

    pub fn var_name(&self, v: VarId) -> &str {
        &self.vars[v as usize]
    }

    pub fn add_buffer(&mut self, buffer: Buffer) -> usize {
        self.buffers.push(buffer);
        self.buffers.len() - 1
    }

    /// Allocate a loop item (not yet linked into the tree).
    pub fn alloc_loop(&mut self, data: LoopData) -> ItemId {
        self.items.push(Item {
            parent: None,
            children: Vec::new(),
            kind: ItemKind::Loop(data),
            alive: true,
        });
        self.items.len() - 1
    }

    /// Allocate a block item (not yet linked into the tree).
    pub fn alloc_block(&mut self, data: BlockData) -> ItemId {
        self.items.push(Item {
            parent: None,
            children: Vec::new(),
            kind: ItemKind::Block(data),
            alive: true,
        });
        self.items.len() - 1
    }

    /// Append `child` as the last child of `parent` (or as a root).
    pub fn attach(&mut self, child: ItemId, parent: Option<ItemId>) {
        self.items[child].parent = parent;
        match parent {
            Some(p) => self.items[p].children.push(child),
            None => self.roots.push(child),
        }
    }

    /// Insert `child` under `parent` at position `pos`.
    pub fn attach_at(&mut self, child: ItemId, parent: Option<ItemId>, pos: usize) {
        self.items[child].parent = parent;
        match parent {
            Some(p) => self.items[p].children.insert(pos, child),
            None => self.roots.insert(pos, child),
        }
    }

    /// Unlink `item` from its parent (does not tombstone).
    pub fn detach(&mut self, item: ItemId) {
        let parent = self.items[item].parent;
        match parent {
            Some(p) => self.items[p].children.retain(|&c| c != item),
            None => self.roots.retain(|&c| c != item),
        }
        self.items[item].parent = None;
    }

    /// Remove an item and its whole subtree from the tree (tombstoned).
    pub fn remove_subtree(&mut self, item: ItemId) {
        self.detach(item);
        let mut stack = vec![item];
        while let Some(i) = stack.pop() {
            self.items[i].alive = false;
            stack.extend(self.items[i].children.iter().copied());
        }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn is_loop(&self, item: ItemId) -> bool {
        matches!(self.items[item].kind, ItemKind::Loop(_))
    }

    pub fn loop_data(&self, item: ItemId) -> &LoopData {
        match &self.items[item].kind {
            ItemKind::Loop(l) => l,
            _ => panic!("item {item} is not a loop"),
        }
    }

    pub fn loop_data_mut(&mut self, item: ItemId) -> &mut LoopData {
        match &mut self.items[item].kind {
            ItemKind::Loop(l) => l,
            _ => panic!("item {item} is not a loop"),
        }
    }

    pub fn block_data(&self, item: ItemId) -> &BlockData {
        match &self.items[item].kind {
            ItemKind::Block(b) => b,
            _ => panic!("item {item} is not a block"),
        }
    }

    pub fn block_data_mut(&mut self, item: ItemId) -> &mut BlockData {
        match &mut self.items[item].kind {
            ItemKind::Block(b) => b,
            _ => panic!("item {item} is not a block"),
        }
    }

    // ---- navigation ---------------------------------------------------------

    /// Pre-order traversal of live items.
    pub fn preorder(&self) -> Vec<ItemId> {
        let mut out = Vec::with_capacity(self.items.len());
        let mut stack: Vec<ItemId> = self.roots.iter().rev().copied().collect();
        while let Some(i) = stack.pop() {
            if !self.items[i].alive {
                continue;
            }
            out.push(i);
            stack.extend(self.items[i].children.iter().rev().copied());
        }
        out
    }

    /// All live blocks, in pre-order.
    pub fn blocks(&self) -> Vec<ItemId> {
        self.preorder()
            .into_iter()
            .filter(|&i| matches!(self.items[i].kind, ItemKind::Block(_)))
            .collect()
    }

    /// Find a live block by name. Returns the first match in pre-order.
    pub fn find_block(&self, name: &str) -> Option<ItemId> {
        self.blocks()
            .into_iter()
            .find(|&i| self.block_data(i).name == name)
    }

    /// Loops on the path from root to `item` (outermost first), excluding
    /// `item` itself.
    pub fn loops_above(&self, item: ItemId) -> Vec<ItemId> {
        let mut out = Vec::new();
        let mut cur = self.items[item].parent;
        while let Some(p) = cur {
            if self.is_loop(p) {
                out.push(p);
            }
            cur = self.items[p].parent;
        }
        out.reverse();
        out
    }

    /// All live blocks in the subtree rooted at `item` (pre-order).
    pub fn blocks_under(&self, item: ItemId) -> Vec<ItemId> {
        let mut out = Vec::new();
        let mut stack = vec![item];
        while let Some(i) = stack.pop() {
            if !self.items[i].alive {
                continue;
            }
            if matches!(self.items[i].kind, ItemKind::Block(_)) {
                out.push(i);
            }
            stack.extend(self.items[i].children.iter().rev().copied());
        }
        out.reverse();
        out.reverse();
        out
    }

    /// The outermost ancestor (root item) containing `item`.
    pub fn root_of(&self, item: ItemId) -> ItemId {
        let mut cur = item;
        while let Some(p) = self.items[cur].parent {
            cur = p;
        }
        cur
    }

    /// Extents of loop variables as an environment for interval analysis:
    /// every live loop var maps to `(0, extent-1)`.
    pub fn loop_var_ranges(&self) -> HashMap<VarId, (i64, i64)> {
        let mut env = HashMap::new();
        for i in self.preorder() {
            if let ItemKind::Loop(l) = &self.items[i].kind {
                env.insert(l.var, (0, l.extent - 1));
            }
        }
        env
    }

    /// Substitute a loop variable in every block-iter binding within the
    /// subtree rooted at `item`.
    pub fn subst_loop_var_under(&mut self, item: ItemId, var: VarId, replacement: &AExpr) {
        let mut map = HashMap::new();
        map.insert(var, replacement.clone());
        let mut stack = vec![item];
        while let Some(i) = stack.pop() {
            if !self.items[i].alive {
                continue;
            }
            let children = self.items[i].children.clone();
            if let ItemKind::Block(b) = &mut self.items[i].kind {
                for iv in &mut b.iters {
                    if iv.binding.uses_var(var) {
                        iv.binding = iv.binding.subst(&map);
                    }
                }
            }
            stack.extend(children);
        }
    }

    /// Blocks writing / reading each buffer (live blocks only).
    pub fn writers_of(&self, buffer: usize) -> Vec<ItemId> {
        self.blocks()
            .into_iter()
            .filter(|&b| self.block_data(b).writes.iter().any(|r| r.buffer == buffer))
            .collect()
    }

    pub fn readers_of(&self, buffer: usize) -> Vec<ItemId> {
        self.blocks()
            .into_iter()
            .filter(|&b| self.block_data(b).reads.iter().any(|r| r.buffer == buffer))
            .collect()
    }

    /// Consumer blocks of `block`: blocks reading any buffer it writes.
    pub fn consumers_of(&self, block: ItemId) -> Vec<ItemId> {
        let written: Vec<usize> = self
            .block_data(block)
            .writes
            .iter()
            .map(|r| r.buffer)
            .collect();
        self.blocks()
            .into_iter()
            .filter(|&b| {
                b != block
                    && self
                        .block_data(b)
                        .reads
                        .iter()
                        .any(|r| written.contains(&r.buffer))
            })
            .collect()
    }

    /// Producer blocks of `block`: blocks writing any buffer it reads.
    pub fn producers_of(&self, block: ItemId) -> Vec<ItemId> {
        let read: Vec<usize> = self
            .block_data(block)
            .reads
            .iter()
            .map(|r| r.buffer)
            .collect();
        self.blocks()
            .into_iter()
            .filter(|&b| {
                b != block
                    && self
                        .block_data(b)
                        .writes
                        .iter()
                        .any(|r| read.contains(&r.buffer))
            })
            .collect()
    }

    /// Sanity-check tree links; used by tests and the trace validator.
    pub fn check_integrity(&self) -> Result<(), String> {
        for (id, item) in self.items.iter().enumerate() {
            if !item.alive {
                continue;
            }
            for &c in &item.children {
                if !self.items[c].alive {
                    return Err(format!("live item {id} has dead child {c}"));
                }
                if self.items[c].parent != Some(id) {
                    return Err(format!("child {c} of {id} has wrong parent link"));
                }
            }
            match item.parent {
                Some(p) => {
                    if !self.items[p].children.contains(&id) {
                        return Err(format!("item {id} not in parent {p}'s children"));
                    }
                }
                None => {
                    if !self.roots.contains(&id) {
                        return Err(format!("parentless live item {id} not a root"));
                    }
                }
            }
            // Blocks must be leaves unless opaque wrappers; loops must have children.
            match &item.kind {
                ItemKind::Loop(l) => {
                    if l.extent <= 0 {
                        return Err(format!("loop {id} has non-positive extent"));
                    }
                    if item.children.is_empty() {
                        return Err(format!("loop {id} has no children"));
                    }
                }
                ItemKind::Block(_) => {
                    if !item.children.is_empty() {
                        return Err(format!("block {id} has children"));
                    }
                }
            }
        }
        for &r in &self.roots {
            if self.items[r].parent.is_some() {
                return Err(format!("root {r} has a parent"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::block::BlockData;

    /// Build: for i in 64 { for j in 32 { block B } }
    fn tiny() -> (Program, ItemId, ItemId, ItemId) {
        let mut p = Program::new("tiny");
        let vi = p.fresh_var("i");
        let vj = p.fresh_var("j");
        let li = p.alloc_loop(LoopData::new(vi, 64));
        let lj = p.alloc_loop(LoopData::new(vj, 32));
        let b = p.alloc_block(BlockData::new("B"));
        p.attach(li, None);
        p.attach(lj, Some(li));
        p.attach(b, Some(lj));
        (p, li, lj, b)
    }

    #[test]
    fn preorder_and_loops_above() {
        let (p, li, lj, b) = tiny();
        assert_eq!(p.preorder(), vec![li, lj, b]);
        assert_eq!(p.loops_above(b), vec![li, lj]);
        assert_eq!(p.blocks(), vec![b]);
        p.check_integrity().unwrap();
    }

    #[test]
    fn detach_and_reattach() {
        let (mut p, li, lj, b) = tiny();
        p.detach(b);
        assert!(p.blocks_under(li).is_empty());
        p.attach(b, Some(lj));
        assert_eq!(p.blocks_under(li), vec![b]);
        p.check_integrity().unwrap();
    }

    #[test]
    fn remove_subtree_tombstones() {
        let (mut p, li, _lj, b) = tiny();
        p.remove_subtree(li);
        assert!(!p.items[li].alive);
        assert!(!p.items[b].alive);
        assert!(p.roots.is_empty());
        assert!(p.blocks().is_empty());
    }

    #[test]
    fn loop_var_ranges_cover_loops() {
        let (p, li, lj, _) = tiny();
        let env = p.loop_var_ranges();
        assert_eq!(env[&p.loop_data(li).var], (0, 63));
        assert_eq!(env[&p.loop_data(lj).var], (0, 31));
    }

    #[test]
    fn integrity_detects_bad_parent() {
        let (mut p, _li, lj, b) = tiny();
        p.items[b].parent = None; // corrupt: not in roots
        assert!(p.check_integrity().is_err());
        p.items[b].parent = Some(lj);
        p.check_integrity().unwrap();
    }
}
