//! Per-canonical-trace feature cache: `extract` is a pure function of
//! the scheduled program, and the program is a pure function of
//! `(workload base program, trace)` — so once a trace has an interned
//! canonical id chain ([`crate::trace::InternedTrace`]), its feature
//! vector can be cached under `(workload hash, id chain)` and reused
//! every time the search re-scores an unchanged candidate (elite
//! replays across rounds, re-proposed mutations, re-measured members).
//!
//! Invalidation rules: there are none. Both key components are content-
//! addressed — a different base program hashes differently, a different
//! trace interns to a different chain — and `extract` has no other
//! inputs, so an entry can never go stale within a process. Nothing is
//! persisted; the cache dies with the [`crate::ctx::TuneContext`].
//!
//! Correctness contract (pinned by `rust/tests/intern_invariants.rs` and
//! the determinism suite): a cached vector is element-exact equal to a
//! fresh `extract`, so cached and uncached searches produce byte-
//! identical results and database files. Hit/miss counts land both in
//! the context's local registry (exact `--explain-space` numbers) and
//! the process-global registry (`/metrics`).

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::cost_model::features::extract;
use crate::telemetry::{self, Counter, Metrics};
use crate::tir::Program;
use crate::trace::InternedTrace;

/// Cache key: the workload's base-program structural hash plus the
/// candidate trace's canonical id chain. The workload hash matters
/// because one `TuneContext` (and so one cache) is reused across the
/// task scheduler's workloads — the same trace replayed onto different
/// base programs yields different features.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FeatKey {
    pub workload: u64,
    pub trace: InternedTrace,
}

/// The cache itself: a read-mostly map from [`FeatKey`] to the shared
/// feature vector. Thread-safe; worker chains share it through
/// `&TuneContext`.
pub struct FeatureCache {
    map: RwLock<HashMap<FeatKey, Arc<Vec<f64>>>>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    hits_global: Arc<Counter>,
    misses_global: Arc<Counter>,
}

const HITS_HELP: &str = "cost-model feature vectors served from the canonical-trace cache";
const MISSES_HELP: &str = "cost-model feature vectors extracted fresh and inserted into the cache";

impl FeatureCache {
    /// A cache whose hit/miss counters register in `local` (the owning
    /// context's registry) and mirror into the process-global registry.
    pub fn new(local: &Metrics) -> FeatureCache {
        let g = telemetry::global();
        FeatureCache {
            map: RwLock::new(HashMap::new()),
            hits: local.counter("feature_cache_hits_total", HITS_HELP),
            misses: local.counter("feature_cache_misses_total", MISSES_HELP),
            hits_global: g.counter("feature_cache_hits_total", HITS_HELP),
            misses_global: g.counter("feature_cache_misses_total", MISSES_HELP),
        }
    }

    /// The feature vector for `prog` under `key`: served from the cache
    /// when present, extracted and inserted otherwise. The caller
    /// guarantees `prog` is the replay of `key` (the search derives both
    /// from the same population member); since `extract` is pure, a hit
    /// is element-exact equal to the fresh extraction it replaces.
    pub fn get_or_extract(&self, key: &FeatKey, prog: &Program) -> Arc<Vec<f64>> {
        if let Some(hit) = self.map.read().unwrap().get(key) {
            let out = Arc::clone(hit);
            self.hits.inc();
            self.hits_global.inc();
            return out;
        }
        let feats = Arc::new(extract(prog));
        let mut g = self.map.write().unwrap();
        // A racing extractor may have inserted meanwhile; keep the first
        // entry (the values are identical — extract is pure).
        let entry = g.entry(key.clone()).or_insert_with(|| Arc::clone(&feats));
        let out = Arc::clone(entry);
        drop(g);
        self.misses.inc();
        self.misses_global.inc();
        out
    }

    /// Cached entries.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hits recorded by this cache (local registry view).
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Misses (= extractions) recorded by this cache.
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::structural_hash;
    use crate::trace::InternArena;
    use crate::workloads;

    #[test]
    fn hit_returns_the_exact_extracted_vector() {
        let metrics = Metrics::new();
        let cache = FeatureCache::new(&metrics);
        let arena = InternArena::new();
        let prog = workloads::matmul(1, 32, 32, 32);
        let key = FeatKey {
            workload: structural_hash(&prog),
            trace: arena.intern(&crate::trace::Trace::default()),
        };
        let fresh = extract(&prog);
        let first = cache.get_or_extract(&key, &prog);
        let second = cache.get_or_extract(&key, &prog);
        assert_eq!(*first, fresh);
        assert_eq!(*second, fresh);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        assert_eq!(metrics.counter_value("feature_cache_hits_total"), Some(1));
    }

    #[test]
    fn distinct_workloads_do_not_collide() {
        // Same (empty) trace, different base programs: separate entries
        // — the workload hash keeps task-scheduler reuse safe.
        let metrics = Metrics::new();
        let cache = FeatureCache::new(&metrics);
        let arena = InternArena::new();
        let it = arena.intern(&crate::trace::Trace::default());
        let a = workloads::matmul(1, 32, 32, 32);
        let b = workloads::softmax(1, 32, 32);
        let fa = cache.get_or_extract(
            &FeatKey { workload: structural_hash(&a), trace: it.clone() },
            &a,
        );
        let fb = cache.get_or_extract(
            &FeatKey { workload: structural_hash(&b), trace: it },
            &b,
        );
        assert_eq!(cache.len(), 2);
        assert_ne!(*fa, *fb);
    }
}
