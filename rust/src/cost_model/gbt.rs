//! Gradient-boosted regression trees, from scratch (the image vendors no
//! ML crates). Squared loss, greedy depth-limited trees over quantile
//! candidate thresholds — the same model class as the tree-boosting cost
//! models of [10, 43]. A pairwise ranking objective ([`Gbt::fit_ranked`])
//! sits on top of the same weighted-tree machinery: search only needs
//! candidate *order*, so the loss compares sampled pairs instead of
//! fitting absolute scores.

use crate::util::rng::Rng;

/// Training objective for the boosted ensemble.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Squared-error regression on absolute scores — the historical path;
    /// bit-identical to pre-objective code and the compat default.
    Regression,
    /// Pairwise logistic ranking loss (LambdaRank-style) over sampled
    /// same-workload pairs: predictions only promise *order* consistency
    /// with the labels, which is all the evolutionary search consumes.
    PairwiseRank,
}

impl Default for Objective {
    fn default() -> Objective {
        Objective::Regression
    }
}

impl Objective {
    /// Parse a CLI spelling (`mse` / `rank`). Returns `None` on unknown
    /// names so callers can print their own usage error.
    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "mse" | "regression" | "reg" => Some(Objective::Regression),
            "rank" | "pairwise" | "pairwise-rank" => Some(Objective::PairwiseRank),
            _ => None,
        }
    }

    /// Canonical short label (`mse` / `rank`) used by CLI output and
    /// record provenance.
    pub fn label(self) -> &'static str {
        match self {
            Objective::Regression => "mse",
            Objective::PairwiseRank => "rank",
        }
    }
}

/// Dedicated RNG stream for rank-loss pair sampling, disjoint from the
/// search's per-worker streams.
const RANK_PAIR_STREAM: u64 = 0x9e37_79b9_7f4a_7c15;

/// Sampled pairs per training sample in [`Gbt::fit_ranked`].
const PAIRS_PER_SAMPLE: usize = 8;

/// One node of a regression tree (flattened arena).
#[derive(Debug, Clone)]
enum Node {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// A depth-limited regression tree.
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Fit a tree to (x, residual) by greedy variance-reduction splits.
    fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &[usize],
        depth: usize,
        min_leaf: usize,
        n_thresholds: usize,
    ) -> Tree {
        let mut nodes = Vec::new();
        Self::fit_node(xs, ys, idx, depth, min_leaf, n_thresholds, &mut nodes);
        Tree { nodes }
    }

    /// Weighted-sample variant of [`Tree::fit`] (cross-target transfer
    /// priors fit with a mismatch discount `w < 1`). Kept as a separate
    /// code path so the uniform-weight fit stays bit-identical to the
    /// historical one — determinism suites pin its exact float sequence.
    fn fit_w(xs: &[Vec<f64>], ys: &[f64], ws: &[f64], idx: &[usize], depth: usize, min_leaf: usize) -> Tree {
        let mut nodes = Vec::new();
        Self::fit_node_w(xs, ys, ws, idx, depth, min_leaf, &mut nodes);
        Tree { nodes }
    }

    /// Weighted greedy split search: weighted mean leaves, weighted SSE
    /// `Σw·y² − (Σw·y)²/Σw` via prefix sums over the per-feature sorted
    /// scan; `min_leaf` still counts *samples* (a heavily-discounted leaf
    /// is still a leaf of real observations).
    fn fit_node_w(
        xs: &[Vec<f64>],
        ys: &[f64],
        ws: &[f64],
        idx: &[usize],
        depth: usize,
        min_leaf: usize,
        nodes: &mut Vec<Node>,
    ) -> usize {
        let n = idx.len();
        let total_w: f64 = idx.iter().map(|&i| ws[i]).sum();
        let total_wy: f64 = idx.iter().map(|&i| ws[i] * ys[i]).sum();
        let mean = if total_w > 0.0 { total_wy / total_w } else { 0.0 };
        if depth == 0 || n < 2 * min_leaf || total_w <= 0.0 {
            nodes.push(Node::Leaf { value: mean });
            return nodes.len() - 1;
        }
        let n_feat = xs[0].len();
        let total_wy2: f64 = idx.iter().map(|&i| ws[i] * ys[i] * ys[i]).sum();
        let base_sse = total_wy2 - total_wy * total_wy / total_w;
        let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, sse)
        let mut triples: Vec<(f64, f64, f64)> = Vec::with_capacity(n); // (x, y, w)
        for f in 0..n_feat {
            triples.clear();
            triples.extend(idx.iter().map(|&i| (xs[i][f], ys[i], ws[i])));
            triples.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            if triples[0].0 == triples[n - 1].0 {
                continue; // constant feature
            }
            let mut lw = 0.0f64;
            let mut lwy = 0.0f64;
            let mut lwy2 = 0.0f64;
            for (k, &(v, y, w)) in triples.iter().enumerate().take(n - 1) {
                lw += w;
                lwy += w * y;
                lwy2 += w * y * y;
                // Only cut between distinct values; respect min_leaf.
                let nl = k + 1;
                let nr = n - nl;
                if v == triples[k + 1].0 || nl < min_leaf || nr < min_leaf {
                    continue;
                }
                let rw = total_w - lw;
                if lw <= 0.0 || rw <= 0.0 {
                    continue; // a side of all-zero weight fits nothing
                }
                let rwy = total_wy - lwy;
                let rwy2 = total_wy2 - lwy2;
                let sse = (lwy2 - lwy * lwy / lw) + (rwy2 - rwy * rwy / rw);
                if sse < base_sse - 1e-12 && best.map(|(_, _, b)| sse < b).unwrap_or(true) {
                    best = Some((f, 0.5 * (v + triples[k + 1].0), sse));
                }
            }
        }
        match best {
            None => {
                nodes.push(Node::Leaf { value: mean });
                nodes.len() - 1
            }
            Some((f, thr, _)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.iter().partition(|&&i| xs[i][f] <= thr);
                let me = nodes.len();
                nodes.push(Node::Leaf { value: mean }); // placeholder
                let left = Self::fit_node_w(xs, ys, ws, &li, depth - 1, min_leaf, nodes);
                let right = Self::fit_node_w(xs, ys, ws, &ri, depth - 1, min_leaf, nodes);
                nodes[me] = Node::Split { feature: f, threshold: thr, left, right };
                me
            }
        }
    }

    fn fit_node(
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &[usize],
        depth: usize,
        min_leaf: usize,
        n_thresholds: usize,
        nodes: &mut Vec<Node>,
    ) -> usize {
        let _ = n_thresholds; // superseded: the sorted scan tries all splits
        let n = idx.len();
        let mean = idx.iter().map(|&i| ys[i]).sum::<f64>() / n.max(1) as f64;
        if depth == 0 || n < 2 * min_leaf {
            nodes.push(Node::Leaf { value: mean });
            return nodes.len() - 1;
        }
        let n_feat = xs[0].len();
        // Best split by exhaustive sorted scan with prefix sums:
        // SSE(split) = (Σy²_L - (Σy_L)²/n_L) + (Σy²_R - (Σy_R)²/n_R),
        // O(n log n + n) per feature instead of O(thresholds * n) passes.
        let total_y: f64 = idx.iter().map(|&i| ys[i]).sum();
        let total_y2: f64 = idx.iter().map(|&i| ys[i] * ys[i]).sum();
        let base_sse = total_y2 - total_y * total_y / n as f64;
        let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, sse)
        let mut pairs: Vec<(f64, f64)> = Vec::with_capacity(n);
        for f in 0..n_feat {
            pairs.clear();
            pairs.extend(idx.iter().map(|&i| (xs[i][f], ys[i])));
            pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            if pairs[0].0 == pairs[n - 1].0 {
                continue; // constant feature
            }
            let mut ly = 0.0f64;
            let mut ly2 = 0.0f64;
            for (k, &(v, y)) in pairs.iter().enumerate().take(n - 1) {
                ly += y;
                ly2 += y * y;
                // Only cut between distinct values; respect min_leaf.
                let nl = k + 1;
                let nr = n - nl;
                if v == pairs[k + 1].0 || nl < min_leaf || nr < min_leaf {
                    continue;
                }
                let ry = total_y - ly;
                let ry2 = total_y2 - ly2;
                let sse = (ly2 - ly * ly / nl as f64) + (ry2 - ry * ry / nr as f64);
                if sse < base_sse - 1e-12 && best.map(|(_, _, b)| sse < b).unwrap_or(true) {
                    best = Some((f, 0.5 * (v + pairs[k + 1].0), sse));
                }
            }
        }
        match best {
            None => {
                nodes.push(Node::Leaf { value: mean });
                nodes.len() - 1
            }
            Some((f, thr, _)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.iter().partition(|&&i| xs[i][f] <= thr);
                let me = nodes.len();
                nodes.push(Node::Leaf { value: mean }); // placeholder
                let left = Self::fit_node(xs, ys, &li, depth - 1, min_leaf, n_thresholds, nodes);
                let right = Self::fit_node(xs, ys, &ri, depth - 1, min_leaf, n_thresholds, nodes);
                nodes[me] = Node::Split { feature: f, threshold: thr, left, right };
                me
            }
        }
    }
}

/// Gradient-boosted tree ensemble with squared loss.
#[derive(Debug, Clone)]
pub struct Gbt {
    pub n_trees: usize,
    pub depth: usize,
    pub learning_rate: f64,
    pub min_leaf: usize,
    pub n_thresholds: usize,
    base: f64,
    trees: Vec<Tree>,
}

impl Gbt {
    pub fn new(n_trees: usize, depth: usize, learning_rate: f64) -> Gbt {
        Gbt {
            n_trees,
            depth,
            learning_rate,
            min_leaf: 2,
            n_thresholds: 16,
            base: 0.0,
            trees: Vec::new(),
        }
    }

    pub fn is_fit(&self) -> bool {
        !self.trees.is_empty()
    }

    /// Fit from scratch on the dataset.
    pub fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        self.trees.clear();
        if xs.is_empty() {
            self.base = 0.0;
            return;
        }
        self.base = ys.iter().sum::<f64>() / ys.len() as f64;
        let idx: Vec<usize> = (0..xs.len()).collect();
        let mut pred: Vec<f64> = vec![self.base; xs.len()];
        for _ in 0..self.n_trees {
            let resid: Vec<f64> = ys.iter().zip(&pred).map(|(y, p)| y - p).collect();
            let tree = Tree::fit(xs, &resid, &idx, self.depth, self.min_leaf, self.n_thresholds);
            for (p, x) in pred.iter_mut().zip(xs.iter()) {
                *p += self.learning_rate * tree.predict(x);
            }
            self.trees.push(tree);
        }
    }

    /// Fit with per-sample weights (the cross-target transfer discount).
    /// Uniform all-1 weights delegate to the plain [`Gbt::fit`] so the
    /// native path's float sequence is untouched; any other weighting
    /// runs the weighted tree fit, where a sample's pull on leaf means
    /// and split scores scales with its weight.
    pub fn fit_weighted(&mut self, xs: &[Vec<f64>], ys: &[f64], ws: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        assert_eq!(xs.len(), ws.len());
        if ws.iter().all(|&w| w == 1.0) {
            return self.fit(xs, ys);
        }
        self.trees.clear();
        let total_w: f64 = ws.iter().sum();
        if xs.is_empty() || total_w <= 0.0 {
            self.base = 0.0;
            return;
        }
        self.base = ys.iter().zip(ws).map(|(y, w)| y * w).sum::<f64>() / total_w;
        let idx: Vec<usize> = (0..xs.len()).collect();
        let mut pred: Vec<f64> = vec![self.base; xs.len()];
        for _ in 0..self.n_trees {
            let resid: Vec<f64> = ys.iter().zip(&pred).map(|(y, p)| y - p).collect();
            let tree = Tree::fit_w(xs, &resid, ws, &idx, self.depth, self.min_leaf);
            for (p, x) in pred.iter_mut().zip(xs.iter()) {
                *p += self.learning_rate * tree.predict(x);
            }
            self.trees.push(tree);
        }
    }

    /// Fit with the pairwise ranking objective. Labels are scores
    /// (higher = better); the fit only consumes their *order*.
    ///
    /// Pairs `(i, j)` are drawn uniformly with a fixed-stream RNG and
    /// filtered (self-pairs, label ties) *after* the draw, so the RNG
    /// consumption depends only on `n` and `seed` — never on label
    /// values. Together with orientation-by-comparison this makes the
    /// fit bit-identical under any strictly monotone relabeling, the
    /// property the objective-layer tests pin. Each boosting round
    /// accumulates lambda gradients `w / (1 + exp(s_hi − s_lo))` per
    /// sample and fits a tree to the weighted mean gradient via the same
    /// [`Tree::fit_w`] the transfer discount uses: a pair's weight is
    /// `min(w_hi, w_lo)`, so discounted transfer priors enter as
    /// discounted pairs.
    ///
    /// Degenerate inputs (fewer than two samples, or no untied pairs)
    /// fall back to [`Gbt::fit_weighted`].
    pub fn fit_ranked(&mut self, xs: &[Vec<f64>], ys: &[f64], ws: &[f64], seed: u64) {
        assert_eq!(xs.len(), ys.len());
        assert_eq!(xs.len(), ws.len());
        let n = xs.len();
        if n < 2 {
            return self.fit_weighted(xs, ys, ws);
        }
        let mut rng = Rng::for_stream(seed, RANK_PAIR_STREAM);
        let n_draws = n.saturating_mul(PAIRS_PER_SAMPLE);
        let mut pairs: Vec<(usize, usize, f64)> = Vec::with_capacity(n_draws);
        for _ in 0..n_draws {
            let i = rng.gen_range(n);
            let j = rng.gen_range(n);
            if i == j || ys[i] == ys[j] || ws[i] <= 0.0 || ws[j] <= 0.0 {
                continue;
            }
            let (hi, lo) = if ys[i] > ys[j] { (i, j) } else { (j, i) };
            pairs.push((hi, lo, ws[hi].min(ws[lo])));
        }
        if pairs.is_empty() {
            return self.fit_weighted(xs, ys, ws);
        }
        self.trees.clear();
        self.base = 0.0;
        // Per-sample weight = total pair mass touching the sample; fixed
        // across boosting rounds so the split search stays stable.
        let mut wsum = vec![0.0f64; n];
        for &(hi, lo, w) in &pairs {
            wsum[hi] += w;
            wsum[lo] += w;
        }
        let idx: Vec<usize> = (0..n).collect();
        let mut pred = vec![0.0f64; n];
        for _ in 0..self.n_trees {
            let mut grad = vec![0.0f64; n];
            for &(hi, lo, w) in &pairs {
                // Negative gradient of ln(1 + e^{-(s_hi − s_lo)}):
                // push the better sample up, the worse one down.
                let d = 1.0 / (1.0 + (pred[hi] - pred[lo]).exp());
                grad[hi] += w * d;
                grad[lo] -= w * d;
            }
            let target: Vec<f64> = grad
                .iter()
                .zip(&wsum)
                .map(|(g, &w)| if w > 0.0 { g / w } else { 0.0 })
                .collect();
            let tree = Tree::fit_w(xs, &target, &wsum, &idx, self.depth, self.min_leaf);
            for (p, x) in pred.iter_mut().zip(xs.iter()) {
                *p += self.learning_rate * tree.predict(x);
            }
            self.trees.push(tree);
        }
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.base
            + self
                .trees
                .iter()
                .map(|t| self.learning_rate * t.predict(x))
                .sum::<f64>()
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.gen_f64() * 4.0;
            let b = rng.gen_f64() * 4.0;
            let c = rng.gen_f64();
            // Nonlinear with interactions — a tree-friendly target.
            let y = if a > 2.0 { 3.0 * b } else { b * b } + 0.5 * c;
            xs.push(vec![a, b, c]);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn fits_nonlinear_function() {
        let (xs, ys) = synth(400, 1);
        let mut m = Gbt::new(60, 4, 0.15);
        m.fit(&xs, &ys);
        let (xt, yt) = synth(100, 2);
        let pred = m.predict(&xt);
        let mse: f64 = pred
            .iter()
            .zip(&yt)
            .map(|(p, y)| (p - y).powi(2))
            .sum::<f64>()
            / yt.len() as f64;
        let var: f64 = {
            let m = yt.iter().sum::<f64>() / yt.len() as f64;
            yt.iter().map(|y| (y - m).powi(2)).sum::<f64>() / yt.len() as f64
        };
        assert!(mse < var * 0.2, "mse {mse} vs var {var}");
    }

    #[test]
    fn ranking_quality_on_holdout() {
        // For the search what matters is ordering, not absolute error.
        let (xs, ys) = synth(300, 3);
        let mut m = Gbt::new(50, 4, 0.15);
        m.fit(&xs, &ys);
        let (xt, yt) = synth(80, 4);
        let pred = m.predict(&xt);
        // Count concordant pairs.
        let mut conc = 0;
        let mut total = 0;
        for i in 0..yt.len() {
            for j in (i + 1)..yt.len() {
                if (yt[i] - yt[j]).abs() < 1e-9 {
                    continue;
                }
                total += 1;
                if (yt[i] > yt[j]) == (pred[i] > pred[j]) {
                    conc += 1;
                }
            }
        }
        let tau = conc as f64 / total as f64;
        assert!(tau > 0.8, "concordance {tau}");
    }

    #[test]
    fn weighted_fit_with_uniform_weights_matches_plain_fit() {
        let (xs, ys) = synth(200, 7);
        let mut a = Gbt::new(30, 4, 0.2);
        a.fit(&xs, &ys);
        let mut b = Gbt::new(30, 4, 0.2);
        b.fit_weighted(&xs, &ys, &vec![1.0; ys.len()]);
        let (xt, _) = synth(40, 8);
        for x in &xt {
            assert_eq!(a.predict_one(x), b.predict_one(x), "uniform weights must be the identity");
        }
    }

    #[test]
    fn discounted_samples_pull_less_than_native_ones() {
        // Two populations disagree about y at the same x-region; the fit
        // must land nearer whichever carries more weight.
        let xs: Vec<Vec<f64>> = (0..40).map(|i| vec![(i % 4) as f64]).collect();
        let native: Vec<f64> = xs.iter().map(|_| 10.0).collect();
        let prior: Vec<f64> = xs.iter().map(|_| 0.0).collect();
        let all_x: Vec<Vec<f64>> = xs.iter().chain(xs.iter()).cloned().collect();
        let all_y: Vec<f64> = native.iter().chain(prior.iter()).copied().collect();
        let mut ws = vec![1.0; native.len()];
        ws.extend(vec![0.25; prior.len()]);
        let mut m = Gbt::new(20, 3, 0.3);
        m.fit_weighted(&all_x, &all_y, &ws);
        let p = m.predict_one(&[1.0]);
        // Weighted mean of 10 (w 1) and 0 (w 0.25) = 8; unweighted = 5.
        assert!(p > 6.5, "discounted prior pulled too hard: {p}");
        // Sanity: equal weights land in the middle.
        let mut eq = Gbt::new(20, 3, 0.3);
        eq.fit_weighted(&all_x, &all_y, &vec![1.0; all_y.len()]);
        let pe = eq.predict_one(&[1.0]);
        assert!((pe - 5.0).abs() < 1.0, "{pe}");
        assert!(p > pe);
    }

    #[test]
    fn weighted_fit_learns_nonlinear_structure_too() {
        let (xs, ys) = synth(300, 11);
        let ws: Vec<f64> = (0..ys.len()).map(|i| if i % 2 == 0 { 1.0 } else { 0.5 }).collect();
        let mut m = Gbt::new(50, 4, 0.15);
        m.fit_weighted(&xs, &ys, &ws);
        let (xt, yt) = synth(80, 12);
        let pred = m.predict(&xt);
        let mse: f64 =
            pred.iter().zip(&yt).map(|(p, y)| (p - y).powi(2)).sum::<f64>() / yt.len() as f64;
        let var: f64 = {
            let mean = yt.iter().sum::<f64>() / yt.len() as f64;
            yt.iter().map(|y| (y - mean).powi(2)).sum::<f64>() / yt.len() as f64
        };
        assert!(mse < var * 0.3, "mse {mse} vs var {var}");
    }

    #[test]
    fn objective_parse_and_label_round_trip() {
        assert_eq!(Objective::parse("mse"), Some(Objective::Regression));
        assert_eq!(Objective::parse("MSE"), Some(Objective::Regression));
        assert_eq!(Objective::parse("regression"), Some(Objective::Regression));
        assert_eq!(Objective::parse("rank"), Some(Objective::PairwiseRank));
        assert_eq!(Objective::parse("pairwise-rank"), Some(Objective::PairwiseRank));
        assert_eq!(Objective::parse("nope"), None);
        assert_eq!(Objective::Regression.label(), "mse");
        assert_eq!(Objective::PairwiseRank.label(), "rank");
        assert_eq!(Objective::default(), Objective::Regression);
    }

    #[test]
    fn ranked_fit_orders_training_data() {
        let (xs, ys) = synth(200, 21);
        let ws = vec![1.0; ys.len()];
        let mut m = Gbt::new(50, 4, 0.15);
        m.fit_ranked(&xs, &ys, &ws, 5);
        let pred = m.predict(&xs);
        let mut conc = 0;
        let mut total = 0;
        for i in 0..ys.len() {
            for j in (i + 1)..ys.len() {
                if (ys[i] - ys[j]).abs() < 1e-9 {
                    continue;
                }
                total += 1;
                if (ys[i] > ys[j]) == (pred[i] > pred[j]) {
                    conc += 1;
                }
            }
        }
        let tau = conc as f64 / total as f64;
        assert!(tau > 0.85, "training concordance {tau}");
    }

    #[test]
    fn ranked_fit_is_invariant_under_monotone_relabeling() {
        // Scaling by a power of two is a bit-exact strictly monotone
        // bijection on the label range here, so order AND float ties are
        // preserved exactly — the rank fit must not notice.
        let (xs, ys) = synth(150, 23);
        let ws = vec![1.0; ys.len()];
        let scaled: Vec<f64> = ys.iter().map(|y| y * 4.0).collect();
        let mut a = Gbt::new(40, 4, 0.2);
        a.fit_ranked(&xs, &ys, &ws, 9);
        let mut b = Gbt::new(40, 4, 0.2);
        b.fit_ranked(&xs, &scaled, &ws, 9);
        let (xt, _) = synth(40, 24);
        for x in &xt {
            assert_eq!(
                a.predict_one(x),
                b.predict_one(x),
                "rank objective must only see label order"
            );
        }
        // Regression, by contrast, chases absolute values: the same
        // relabeling must move its predictions.
        let mut ra = Gbt::new(40, 4, 0.2);
        ra.fit(&xs, &ys);
        let mut rb = Gbt::new(40, 4, 0.2);
        rb.fit(&xs, &scaled);
        assert!(
            xt.iter().any(|x| ra.predict_one(x) != rb.predict_one(x)),
            "regression should be label-scale sensitive"
        );
    }

    #[test]
    fn ranked_fit_discounts_low_weight_pairs() {
        // Native samples say feature 0 ranks ascending; heavily
        // discounted priors say the opposite. The rank fit must follow
        // the natives.
        let xs: Vec<Vec<f64>> = (0..32).map(|i| vec![(i % 8) as f64]).collect();
        let native: Vec<f64> = xs.iter().map(|x| x[0]).collect();
        let prior: Vec<f64> = xs.iter().map(|x| -x[0]).collect();
        let all_x: Vec<Vec<f64>> = xs.iter().chain(xs.iter()).cloned().collect();
        let all_y: Vec<f64> = native.iter().chain(prior.iter()).copied().collect();
        let mut ws = vec![1.0; native.len()];
        ws.extend(vec![0.05; prior.len()]);
        let mut m = Gbt::new(30, 3, 0.3);
        m.fit_ranked(&all_x, &all_y, &ws, 13);
        assert!(
            m.predict_one(&[7.0]) > m.predict_one(&[0.0]),
            "native ordering must win over discounted priors"
        );
    }

    #[test]
    fn ranked_fit_degenerate_inputs_fall_back() {
        // Single sample: delegates to the weighted fit.
        let mut m = Gbt::new(10, 3, 0.3);
        m.fit_ranked(&[vec![1.0]], &[5.0], &[1.0], 1);
        assert!((m.predict_one(&[1.0]) - 5.0).abs() < 1e-9);
        // All-tied labels: no usable pairs, same fallback.
        let xs = vec![vec![1.0], vec![2.0], vec![3.0]];
        let ys = vec![7.0, 7.0, 7.0];
        let mut t = Gbt::new(10, 3, 0.3);
        t.fit_ranked(&xs, &ys, &[1.0, 1.0, 1.0], 2);
        assert!((t.predict_one(&[2.5]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn empty_and_constant_data() {
        let mut m = Gbt::new(10, 3, 0.3);
        m.fit(&[], &[]);
        assert_eq!(m.predict_one(&[1.0, 2.0]), 0.0);
        let xs = vec![vec![1.0], vec![2.0], vec![3.0]];
        let ys = vec![5.0, 5.0, 5.0];
        m.fit(&xs, &ys);
        assert!((m.predict_one(&[1.5]) - 5.0).abs() < 1e-9);
    }
}
