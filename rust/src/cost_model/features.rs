//! Program feature extraction for the learned cost model.
//!
//! A fixed-length vector of structural/arithmetic features in the style of
//! the feature sets used by prior learned cost models [10, 43]: flop
//! counts, loop structure (parallel/vector/unroll/thread extents), memory
//! access volume, working-set footprints at cache-like sweep depths, and
//! reuse ratios. Per-block features are aggregated flop-weighted so the
//! dominant block drives the prediction.

use std::collections::HashMap;

use crate::tir::analysis::{iter_env, region_footprint_elems, sweep_env};
use crate::tir::{ItemId, LoopKind, Program, Scope};

/// Dimensionality of the feature vector.
pub const FEAT_DIM: usize = 24;

fn ln1p(x: f64) -> f64 {
    (1.0 + x.max(0.0)).ln()
}

/// Per-block raw features, before aggregation.
fn block_features(p: &Program, block: ItemId) -> ([f64; FEAT_DIM], f64) {
    let bd = p.block_data(block);
    let loops = p.loops_above(block);
    let extents: Vec<i64> = loops.iter().map(|&l| p.loop_data(l).extent).collect();
    let instances: f64 = extents.iter().map(|&e| e as f64).product();
    let flops = instances * bd.body.flops();

    let mut parallel_extent = 1.0;
    let mut vector_extent = 1.0;
    let mut unroll_extent = 1.0;
    let mut grid_extent = 1.0;
    let mut thread_extent = 1.0;
    let mut serial_extent = 1.0;
    let mut unroll_pragma = 0.0f64;
    for &l in &loops {
        let ld = p.loop_data(l);
        let e = ld.extent as f64;
        match &ld.kind {
            LoopKind::Parallel => parallel_extent *= e,
            LoopKind::Vectorized => vector_extent *= e,
            LoopKind::Unrolled => unroll_extent *= e,
            LoopKind::ThreadBinding(t) if t.starts_with("blockIdx") => grid_extent *= e,
            LoopKind::ThreadBinding(_) => thread_extent *= e,
            LoopKind::Serial => serial_extent *= e,
        }
        if let Some(v) = ld.annotations.get("pragma_auto_unroll_max_step") {
            unroll_pragma = unroll_pragma.max(v.parse::<f64>().unwrap_or(0.0));
        }
    }
    let innermost_extent = extents.last().copied().unwrap_or(1) as f64;

    // Memory: per-instance access bytes + footprints swept at three depths.
    let mut access_bytes = 0.0;
    let mut shared_bytes = 0.0;
    for r in bd.reads.iter().chain(bd.writes.iter()) {
        let buf = &p.buffers[r.buffer];
        let b = r.extent_numel() as f64 * buf.dtype.bytes() as f64;
        match buf.scope {
            Scope::Global => access_bytes += b,
            _ => shared_bytes += b,
        }
    }
    let total_access = instances * access_bytes;
    let footprint_at = |d: usize| -> f64 {
        if d > loops.len() {
            return 0.0;
        }
        let sweep = sweep_env(p, &loops[d.min(loops.len())..]);
        let mut env = iter_env(p, block, &sweep);
        for (k, v) in &sweep {
            env.insert(*k, *v);
        }
        bd.reads
            .iter()
            .chain(bd.writes.iter())
            .map(|r| {
                region_footprint_elems(&r.ranges, &env) as f64
                    * p.buffers[r.buffer].dtype.bytes() as f64
            })
            .sum()
    };
    let fp_full = footprint_at(0); // whole-nest working set
    let fp_half = footprint_at(loops.len() / 2);
    let fp_inner = footprint_at(loops.len().saturating_sub(1));

    let ai = if total_access > 0.0 { flops / total_access } else { 0.0 };
    let reuse = if fp_full > 0.0 { total_access / fp_full } else { 0.0 };

    let (intrin_flag, intrin_speedup) = match bd.annotations.get("tensor_intrin") {
        Some(name) => (
            1.0,
            crate::schedule::blockize::find_intrin(name)
                .map(|i| i.speedup)
                .unwrap_or(1.0),
        ),
        None => (0.0, 1.0),
    };

    // Loop extents start at 1 ("none"), so use ln(max(x,1)): zero means
    // the structural property is absent.
    let lnx = |x: f64| x.max(1.0).ln();
    let mut f = [0.0; FEAT_DIM];
    f[0] = ln1p(flops);
    f[1] = ln1p(instances);
    f[2] = ln1p(ai);
    f[3] = ln1p(total_access);
    f[4] = ln1p(fp_full);
    f[5] = ln1p(fp_half);
    f[6] = ln1p(fp_inner);
    f[7] = ln1p(reuse);
    f[8] = lnx(parallel_extent);
    f[9] = lnx(vector_extent);
    f[10] = lnx(unroll_extent);
    f[11] = lnx(grid_extent);
    f[12] = lnx(thread_extent);
    f[13] = lnx(serial_extent);
    f[14] = lnx(innermost_extent);
    f[15] = ln1p(unroll_pragma);
    f[16] = loops.len() as f64;
    f[17] = if bd.body.is_reduction() { 1.0 } else { 0.0 };
    f[18] = intrin_flag;
    f[19] = ln1p(intrin_speedup);
    f[20] = ln1p(shared_bytes * instances);
    f[21] = innermost_contiguity(p, block);
    // f[22], f[23] filled at program level.
    (f, flops)
}

/// Fraction of accesses whose *linearized row-major address* moves with
/// stride <= 1 per step of the innermost loop variable (vectorization
/// friendliness; stride 0 = broadcast also counts).
fn innermost_contiguity(p: &Program, block: ItemId) -> f64 {
    let loops = p.loops_above(block);
    let Some(&inner) = loops.last() else { return 1.0 };
    let lvar = p.loop_data(inner).var;
    let bd = p.block_data(block);
    let bindings: HashMap<_, _> = bd
        .iters
        .iter()
        .map(|iv| (iv.var, iv.binding.clone()))
        .collect();
    let mut total = 0;
    let mut contig = 0;
    for r in bd.reads.iter().chain(bd.writes.iter()) {
        total += 1;
        if crate::tir::analysis::linear_stride(p, r, &bindings, lvar).abs() <= 1 {
            contig += 1;
        }
    }
    if total == 0 { 1.0 } else { contig as f64 / total as f64 }
}

/// Extract the program-level feature vector: flop-weighted mean of block
/// features plus program-level summary dims.
pub fn extract(p: &Program) -> Vec<f64> {
    let blocks = p.blocks();
    let mut acc = [0.0; FEAT_DIM];
    let mut wsum = 0.0;
    for &b in &blocks {
        let (f, w) = block_features(p, b);
        let w = w.max(1.0);
        for (a, x) in acc.iter_mut().zip(f.iter()) {
            *a += w * x;
        }
        wsum += w;
    }
    if wsum > 0.0 {
        for a in acc.iter_mut() {
            *a /= wsum;
        }
    }
    acc[22] = blocks.len() as f64;
    acc[23] = p.roots.len() as f64;
    acc.to_vec()
}

/// Extract the feature matrix for a candidate batch (one row per
/// program). This is the cost model's batched entry point: the search
/// scores whole generations through it instead of program-at-a-time.
pub fn extract_batch(progs: &[&Program]) -> Vec<Vec<f64>> {
    progs.iter().map(|&p| extract(p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::trace::FactorArg;
    use crate::workloads;

    #[test]
    fn batch_extraction_matches_single() {
        let a = workloads::matmul(1, 64, 64, 64);
        let b = workloads::softmax(1, 32, 32);
        let batch = extract_batch(&[&a, &b]);
        assert_eq!(batch, vec![extract(&a), extract(&b)]);
    }

    #[test]
    fn feature_vector_has_fixed_dim() {
        for w in workloads::suite() {
            let f = extract(&(w.build)());
            assert_eq!(f.len(), FEAT_DIM, "{}", w.name);
            assert!(f.iter().all(|x| x.is_finite()), "{}", w.name);
        }
    }

    #[test]
    fn schedule_changes_move_features() {
        let prog = workloads::matmul(1, 128, 128, 128);
        let base = extract(&prog);
        let mut s = Schedule::new(prog, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.parallel(loops[1]).unwrap();
        let par = extract(&s.prog);
        assert!(par[8] > base[8], "parallel extent feature must increase");
        assert_eq!(base[8], 0.0);
    }

    #[test]
    fn vectorize_and_tiling_visible() {
        let prog = workloads::matmul(1, 128, 128, 128);
        let mut s = Schedule::new(prog, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let j = s
            .split(loops[2], &[FactorArg::Lit(8), FactorArg::Lit(16)])
            .unwrap();
        let tiled = extract(&s.prog);
        assert_eq!(tiled[16], 5.0); // loop-count feature
        // Move the inner j tile innermost (below k), then vectorize it.
        let loops2 = s.get_loops(b).unwrap();
        s.reorder(&[loops2[4], j[1]]).unwrap();
        let loops3 = s.get_loops(b).unwrap();
        s.vectorize(*loops3.last().unwrap()).unwrap();
        let vec = extract(&s.prog);
        assert!(vec[9] > 0.0);
    }

    #[test]
    fn tensorized_block_flagged() {
        let prog = workloads::matmul(1, 64, 64, 64);
        let mut s = Schedule::new(prog, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let i = s.split(loops[1], &[FactorArg::Lit(4), FactorArg::Lit(16)]).unwrap();
        let j = s.split(loops[2], &[FactorArg::Lit(4), FactorArg::Lit(16)]).unwrap();
        let k = s.split(loops[3], &[FactorArg::Lit(4), FactorArg::Lit(16)]).unwrap();
        s.reorder(&[i[0], j[0], k[0], i[1], j[1], k[1]]).unwrap();
        s.tensorize(i[1], "wmma_16x16x16").unwrap();
        let f = extract(&s.prog);
        assert!(f[18] > 0.9);
        assert!(f[19] > 0.0);
    }

    #[test]
    fn contiguity_reflects_stride() {
        // Innermost loop of the e_0 nest is k: A[b,i,k] is stride-1,
        // C[b,i,j] is stride-0 (broadcast), but B[b,k,j] jumps a whole row
        // per k step => 2/3 friendly.
        let prog = workloads::matmul(1, 64, 64, 64);
        let blk = prog.find_block("matmul").unwrap();
        let c = innermost_contiguity(&prog, blk);
        assert!((c - 2.0 / 3.0).abs() < 1e-9, "{c}");
        // Transpose: innermost s is stride-1 on the write K_t[h,d,s] but
        // jumps head*dim elements on the read K[s,h,d] => 1/2 friendly.
        let t = workloads::transpose_batch_matmul(32, 4, 16);
        let tb = t.find_block("transpose").unwrap();
        let c = innermost_contiguity(&t, tb);
        assert!((c - 0.5).abs() < 1e-9, "{c}");
    }
}
