//! Learned cost models (paper §4, "Cost model"): a tree-boosting regressor
//! over structural program features, updated online from measured
//! latencies, plus a random baseline. Models predict a *score*
//! (`-ln(latency)`), so higher is better and ordering matches throughput.

pub mod feature_cache;
pub mod features;
pub mod gbt;

pub use feature_cache::{FeatKey, FeatureCache};
pub use features::{extract, extract_batch, FEAT_DIM};
pub use gbt::{Gbt, Objective};

use crate::tir::Program;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Convert a measured latency to the regression target.
pub fn latency_to_score(latency_s: f64) -> f64 {
    -latency_s.max(1e-12).ln()
}

/// A cost model the search can query and update. `Send + Sync` so worker
/// chains can score candidate batches concurrently through a shared
/// reference; mutation (`update`) stays exclusive on the coordinator.
pub trait CostModel: Send + Sync {
    /// Predicted score for each program (higher = faster). Implementations
    /// should treat the slice as one batch (feature matrix in, score
    /// vector out) rather than looping one-at-a-time internally.
    fn predict(&self, progs: &[&Program]) -> Vec<f64>;
    /// Feed back measured latencies (seconds) for the given programs.
    fn update(&mut self, progs: &[&Program], latencies_s: &[f64]);
    /// Feed *prior* samples — e.g. latencies measured on a different
    /// target during cross-target transfer — whose influence on the fit
    /// is discounted by `weight` in `(0, 1]` relative to native samples.
    /// The default delegates to [`CostModel::update`] (models without
    /// sample weighting treat priors as full samples); weight-aware
    /// models override it. `weight <= 0` must be a no-op.
    fn update_prior(&mut self, progs: &[&Program], latencies_s: &[f64], weight: f64) {
        if weight > 0.0 {
            self.update(progs, latencies_s);
        }
    }
    /// Like [`CostModel::predict`], with a per-program feature-cache key
    /// (`None` = no key available) so feature-based models can serve
    /// repeat candidates from the search's cache instead of re-running
    /// `extract`. Results MUST be element-exact equal to `predict` —
    /// the cache is an acceleration, never an input. The default ignores
    /// the cache; models that do not featurize (e.g. [`RandomModel`])
    /// keep it.
    fn predict_cached(
        &self,
        progs: &[&Program],
        keys: &[Option<FeatKey>],
        cache: &FeatureCache,
    ) -> Vec<f64> {
        let _ = (keys, cache);
        self.predict(progs)
    }
    /// Like [`CostModel::update`], with feature-cache keys: models that
    /// featurize training samples internally can reuse (and fill) the
    /// search's cache — measured candidates were almost always just
    /// scored, so their vectors are already resident. Same contract as
    /// `predict_cached`: identical fit to `update`, cache or not.
    fn update_cached(
        &mut self,
        progs: &[&Program],
        latencies_s: &[f64],
        keys: &[Option<FeatKey>],
        cache: &FeatureCache,
    ) {
        let _ = (keys, cache);
        self.update(progs, latencies_s);
    }
    fn name(&self) -> &'static str;
    /// Provenance label of a *non-default* training objective (e.g.
    /// `"rank"`), stamped onto committed tuning records. The empty
    /// string means "the historical default" and keeps record bytes
    /// identical to pre-objective databases — models without an
    /// objective knob inherit that.
    fn objective_label(&self) -> &'static str {
        ""
    }
}

/// Cached handles for the `cost_model_*` metric family. Fetched once per
/// model construction; observation-only (never changes fits or scores).
struct CostModelTelemetry {
    retrains: Arc<crate::telemetry::Counter>,
    samples: Arc<crate::telemetry::Counter>,
    prior_samples: Arc<crate::telemetry::Counter>,
}

impl CostModelTelemetry {
    fn from_global() -> CostModelTelemetry {
        let m = crate::telemetry::global();
        CostModelTelemetry {
            retrains: m.counter(
                "cost_model_retrains_total",
                "GBT cost-model refits over the accumulated sample set",
            ),
            samples: m.counter(
                "cost_model_samples_total",
                "native measured samples accepted into cost-model training sets",
            ),
            prior_samples: m.counter(
                "cost_model_prior_samples_total",
                "discounted transfer-prior samples accepted into cost-model training sets",
            ),
        }
    }
}

/// Tree-boosting cost model (default, as in the paper). Samples carry a
/// weight: native destination measurements weigh 1, transferred
/// cross-target priors weigh their mismatch discount — so the prior
/// shapes the early fit but native evidence outweighs it as it arrives.
pub struct GbtCostModel {
    model: Gbt,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    /// Per-sample fit weights, parallel to `xs`/`ys` (1.0 = native).
    ws: Vec<f64>,
    /// Retrain after this many new samples accumulate.
    pub retrain_every: usize,
    staged: usize,
    /// Training objective; [`Objective::Regression`] is the bit-identical
    /// historical path.
    objective: Objective,
    tel: CostModelTelemetry,
}

/// Fixed seed for rank-loss pair sampling: per-retrain pair sets must not
/// depend on thread count or call interleaving, only on the sample set.
const RANK_FIT_SEED: u64 = 0x5eed_c0de;

impl GbtCostModel {
    pub fn new() -> GbtCostModel {
        GbtCostModel {
            model: Gbt::new(50, 5, 0.2),
            xs: Vec::new(),
            ys: Vec::new(),
            ws: Vec::new(),
            retrain_every: 32,
            staged: 0,
            objective: Objective::Regression,
            tel: CostModelTelemetry::from_global(),
        }
    }

    /// A model trained under the given objective (`new()` = regression).
    pub fn with_objective(objective: Objective) -> GbtCostModel {
        let mut m = GbtCostModel::new();
        m.objective = objective;
        m
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn n_samples(&self) -> usize {
        self.xs.len()
    }

    /// Force a retrain on all accumulated data.
    pub fn retrain(&mut self) {
        match self.objective {
            Objective::Regression => self.model.fit_weighted(&self.xs, &self.ys, &self.ws),
            Objective::PairwiseRank => {
                self.model.fit_ranked(&self.xs, &self.ys, &self.ws, RANK_FIT_SEED)
            }
        }
        self.staged = 0;
        self.tel.retrains.inc();
    }

    fn push_samples(&mut self, progs: &[&Program], latencies_s: &[f64], weight: f64) {
        self.push_samples_keyed(progs, latencies_s, weight, None);
    }

    /// `push_samples` with optional feature-cache keys: a keyed sample
    /// whose vector is already cached skips `extract` entirely. The
    /// cached vector is the output of the same pure `extract`, so the
    /// accumulated training matrix — and every later fit — is element-
    /// identical with or without the cache.
    fn push_samples_keyed(
        &mut self,
        progs: &[&Program],
        latencies_s: &[f64],
        weight: f64,
        cache: Option<(&[Option<FeatKey>], &FeatureCache)>,
    ) {
        for (i, (p, &l)) in progs.iter().zip(latencies_s).enumerate() {
            if !l.is_finite() || l <= 0.0 {
                continue;
            }
            let x = match cache {
                Some((keys, c)) => match keys.get(i).and_then(|k| k.as_ref()) {
                    Some(key) => c.get_or_extract(key, p).as_ref().clone(),
                    None => extract(p),
                },
                None => extract(p),
            };
            self.xs.push(x);
            self.ys.push(latency_to_score(l));
            self.ws.push(weight);
            self.staged += 1;
            if weight >= 1.0 {
                self.tel.samples.inc();
            } else {
                self.tel.prior_samples.inc();
            }
        }
        if self.staged >= self.retrain_every || !self.model.is_fit() {
            self.retrain();
        }
    }
}

impl Default for GbtCostModel {
    fn default() -> Self {
        Self::new()
    }
}

impl CostModel for GbtCostModel {
    fn predict(&self, progs: &[&Program]) -> Vec<f64> {
        if !self.model.is_fit() {
            // Cold model: neutral scores; the search falls back to its
            // prior (random exploration + measured elites).
            return vec![0.0; progs.len()];
        }
        // One feature matrix, one ensemble pass — the batched path the
        // parallel chains score whole candidate generations through.
        self.model.predict(&extract_batch(progs))
    }

    fn update(&mut self, progs: &[&Program], latencies_s: &[f64]) {
        self.push_samples(progs, latencies_s, 1.0);
    }

    fn predict_cached(
        &self,
        progs: &[&Program],
        keys: &[Option<FeatKey>],
        cache: &FeatureCache,
    ) -> Vec<f64> {
        if !self.model.is_fit() {
            return vec![0.0; progs.len()];
        }
        debug_assert_eq!(progs.len(), keys.len());
        let rows: Vec<Vec<f64>> = progs
            .iter()
            .zip(keys)
            .map(|(p, k)| match k {
                Some(key) => cache.get_or_extract(key, p).as_ref().clone(),
                None => extract(p),
            })
            .collect();
        self.model.predict(&rows)
    }

    fn update_cached(
        &mut self,
        progs: &[&Program],
        latencies_s: &[f64],
        keys: &[Option<FeatKey>],
        cache: &FeatureCache,
    ) {
        self.push_samples_keyed(progs, latencies_s, 1.0, Some((keys, cache)));
    }

    fn update_prior(&mut self, progs: &[&Program], latencies_s: &[f64], weight: f64) {
        let w = if weight.is_finite() { weight.clamp(0.0, 1.0) } else { 0.0 };
        if w == 0.0 {
            return;
        }
        let before = self.xs.len();
        self.push_samples(progs, latencies_s, w);
        // Priors arrive once, before round 1 of a search — they must
        // shape the very next prediction, not wait out the
        // `retrain_every` batch an already-fit (warm-started) model
        // would otherwise impose. `staged > 0` means push_samples did
        // not already retrain.
        if self.xs.len() > before && self.staged > 0 {
            self.retrain();
        }
    }

    fn name(&self) -> &'static str {
        match self.objective {
            Objective::Regression => "gbt",
            Objective::PairwiseRank => "gbt-rank",
        }
    }

    fn objective_label(&self) -> &'static str {
        match self.objective {
            // Empty for the compat default: record bytes stay identical
            // to pre-objective databases.
            Objective::Regression => "",
            Objective::PairwiseRank => "rank",
        }
    }
}

/// Random cost model (ablation baseline): a fixed pseudo-random score per
/// program, keyed by `(seed, structural hash)`. Pure `predict` — no
/// interior state — so concurrent worker chains scoring through a shared
/// reference stay deterministic regardless of call interleaving (the same
/// property the search's `(seed, 1 thread) == (seed, N threads)`
/// guarantee relies on).
pub struct RandomModel {
    seed: u64,
}

impl RandomModel {
    pub fn new(seed: u64) -> RandomModel {
        RandomModel { seed }
    }
}

impl CostModel for RandomModel {
    fn predict(&self, progs: &[&Program]) -> Vec<f64> {
        progs
            .iter()
            .map(|p| Rng::for_stream(self.seed, crate::tir::structural_hash(p)).gen_f64())
            .collect()
    }

    fn update(&mut self, _progs: &[&Program], _latencies_s: &[f64]) {}

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::sim::{simulate, Target};
    use crate::workloads;

    /// Generate schedule variants with different parallelism and collect
    /// (program, simulated latency) pairs.
    fn variants() -> Vec<(Program, f64)> {
        let t = Target::cpu_avx512();
        let mut out = Vec::new();
        for par in [false, true] {
            for vec in [false, true] {
                let prog = workloads::matmul(1, 256, 256, 256);
                let mut s = Schedule::new(prog, 0);
                let b = s.get_block("matmul").unwrap();
                let loops = s.get_loops(b).unwrap();
                if par {
                    s.parallel(loops[1]).unwrap();
                }
                if vec {
                    // Swap j and k so j (spatial, stride-1 on B and C) is
                    // innermost, then vectorize it.
                    let l = s.get_loops(b).unwrap();
                    s.reorder(&[l[3], l[2]]).unwrap();
                    let l2 = s.get_loops(b).unwrap();
                    s.vectorize(*l2.last().unwrap()).unwrap();
                }
                let lat = simulate(&s.prog, &t).unwrap().total_s;
                out.push((s.prog, lat));
            }
        }
        out
    }

    #[test]
    fn gbt_learns_to_rank_schedules() {
        let data = variants();
        let mut m = GbtCostModel::new();
        m.retrain_every = 1;
        let progs: Vec<&Program> = data.iter().map(|(p, _)| p).collect();
        let lats: Vec<f64> = data.iter().map(|(_, l)| *l).collect();
        // Train on repeated observations (small set, fit should interpolate).
        for _ in 0..3 {
            m.update(&progs, &lats);
        }
        let pred = m.predict(&progs);
        // Best-latency program must get the best score.
        let best_true = lats
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let best_pred = pred
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best_true, best_pred);
    }

    #[test]
    fn update_prior_discounts_against_native_evidence() {
        let data = variants();
        let progs: Vec<&Program> = data.iter().map(|(p, _)| p).collect();
        let lats: Vec<f64> = data.iter().map(|(_, l)| *l).collect();
        // Prior-only: the model fits (warm start), sample count grows.
        let mut m = GbtCostModel::new();
        m.update_prior(&progs, &lats, 0.5);
        assert_eq!(m.n_samples(), progs.len());
        assert!(m.predict(&[progs[0]])[0] != 0.0, "prior alone must warm the model");
        // Zero/invalid weight is a no-op.
        m.update_prior(&progs, &lats, 0.0);
        m.update_prior(&progs, &lats, f64::NAN);
        assert_eq!(m.n_samples(), progs.len());
        // Conflicting native evidence outweighs the discounted prior:
        // prior says program 0 is 100x slower than it is, native says
        // the truth; the fitted score must land nearer the truth than
        // the prior's claim.
        let mut m2 = GbtCostModel::new();
        m2.retrain_every = 1;
        let wrong = vec![lats[0] * 100.0];
        m2.update_prior(&[progs[0]], &wrong, 0.25);
        m2.update(&[progs[0]], &[lats[0]]);
        let score = m2.predict(&[progs[0]])[0];
        let truth = latency_to_score(lats[0]);
        let claim = latency_to_score(wrong[0]);
        assert!(
            (score - truth).abs() < (score - claim).abs(),
            "score {score} nearer prior claim {claim} than truth {truth}"
        );
        // A model already fit on native data must incorporate a later
        // prior batch immediately, not wait out the retrain_every
        // threshold (the warm-destination transfer path).
        let mut m3 = GbtCostModel::new();
        m3.update(&progs, &lats); // cold -> fits
        let before = m3.predict(&[progs[0]])[0];
        let shifted: Vec<f64> = lats.iter().map(|l| l * 1000.0).collect();
        m3.update_prior(&progs, &shifted, 0.5);
        let after = m3.predict(&[progs[0]])[0];
        assert!(after != before, "prior batch left unfitted on a warm model");
    }

    #[test]
    fn cached_paths_are_element_exact() {
        // predict_cached/update_cached with a shared feature cache must
        // produce bit-identical scores to the uncached paths — the cache
        // is an acceleration, never an input.
        use crate::tir::structural_hash;
        use crate::trace::{InternArena, Trace};

        let data = variants();
        let progs: Vec<&Program> = data.iter().map(|(p, _)| p).collect();
        let lats: Vec<f64> = data.iter().map(|(_, l)| *l).collect();
        let metrics = crate::telemetry::Metrics::new();
        let cache = FeatureCache::new(&metrics);
        let arena = InternArena::new();
        let keys: Vec<Option<FeatKey>> = progs
            .iter()
            .map(|p| {
                Some(FeatKey {
                    workload: structural_hash(p),
                    trace: arena.intern(&Trace::default()),
                })
            })
            .collect();
        let mut plain = GbtCostModel::new();
        let mut cached = GbtCostModel::new();
        plain.update(&progs, &lats);
        cached.update_cached(&progs, &lats, &keys, &cache);
        assert!(cache.misses() > 0, "update_cached did not fill the cache");
        assert_eq!(plain.predict(&progs), cached.predict_cached(&progs, &keys, &cache));
        // A second cached scoring pass serves from the cache and still
        // matches exactly.
        let hits_before = cache.hits();
        assert_eq!(cached.predict_cached(&progs, &keys, &cache), plain.predict(&progs));
        assert!(cache.hits() > hits_before, "repeat scoring did not hit the cache");
        // The default (ignore-the-cache) trait path: RandomModel.
        let rnd = RandomModel::new(3);
        assert_eq!(rnd.predict(&progs), rnd.predict_cached(&progs, &keys, &cache));
    }

    #[test]
    fn default_objective_is_bit_identical_to_historical_path() {
        // `with_objective(Regression)` and plain `new()` must produce the
        // exact same fits — the objective knob cannot perturb the compat
        // default's float sequence.
        let data = variants();
        let progs: Vec<&Program> = data.iter().map(|(p, _)| p).collect();
        let lats: Vec<f64> = data.iter().map(|(_, l)| *l).collect();
        let mut plain = GbtCostModel::new();
        let mut explicit = GbtCostModel::with_objective(Objective::Regression);
        plain.retrain_every = 1;
        explicit.retrain_every = 1;
        for _ in 0..3 {
            plain.update(&progs, &lats);
            explicit.update(&progs, &lats);
        }
        assert_eq!(plain.predict(&progs), explicit.predict(&progs));
        assert_eq!(plain.name(), "gbt");
        assert_eq!(plain.objective_label(), "");
        assert_eq!(explicit.objective(), Objective::Regression);
    }

    #[test]
    fn rank_objective_orders_schedule_variants() {
        let data = variants();
        let progs: Vec<&Program> = data.iter().map(|(p, _)| p).collect();
        let lats: Vec<f64> = data.iter().map(|(_, l)| *l).collect();
        let mut m = GbtCostModel::with_objective(Objective::PairwiseRank);
        m.retrain_every = 1;
        for _ in 0..3 {
            m.update(&progs, &lats);
        }
        assert_eq!(m.name(), "gbt-rank");
        assert_eq!(m.objective_label(), "rank");
        let pred = m.predict(&progs);
        let best_true = lats
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let best_pred = pred
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best_true, best_pred, "rank objective must rank the fastest variant first");
    }

    #[test]
    fn cold_model_returns_neutral() {
        let m = GbtCostModel::new();
        let p = workloads::matmul(1, 64, 64, 64);
        assert_eq!(m.predict(&[&p]), vec![0.0]);
    }

    #[test]
    fn score_monotone_in_latency() {
        assert!(latency_to_score(1e-6) > latency_to_score(1e-3));
    }

    #[test]
    fn random_model_is_stateless_noise() {
        let mut m = RandomModel::new(7);
        let p = workloads::matmul(1, 16, 16, 16);
        let a = m.predict(&[&p, &p, &p]);
        assert_eq!(a.len(), 3);
        m.update(&[&p], &[1.0]); // no-op
        let b = m.predict(&[&p]);
        assert!(b[0] >= 0.0 && b[0] <= 1.0);
    }
}
