//! Trace replay: re-execute a recorded instruction sequence against the
//! initial program, optionally overriding sampling decisions.
//!
//! Replay is the workhorse of the search (paper §4): every mutation
//! proposal is validated by replaying the mutated trace; decisions that
//! fall off the support surface as `ScheduleError`s and the candidate is
//! rejected — this *is* the trace validator.

use std::collections::HashMap;

use crate::schedule::{BlockRv, ExprRv, LoopRv, SchResult, Schedule, ScheduleError};
use crate::tir::Program;
use crate::trace::{Inst, Trace};

/// An override for one sampling instruction's decision.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    Tile(Vec<i64>),
    Categorical(usize),
    Location(i64),
}

/// Replay `trace` on `prog` using the recorded decisions.
pub fn replay(trace: &Trace, prog: &Program, seed: u64) -> SchResult<Schedule> {
    replay_with_decisions(trace, prog, seed, &HashMap::new())
}

/// Replay `trace` on `prog`, overriding decisions at the given instruction
/// indices. Non-overridden sampling instructions keep their recorded
/// decisions, so the result is deterministic given the trace.
pub fn replay_with_decisions(
    trace: &Trace,
    prog: &Program,
    seed: u64,
    overrides: &HashMap<usize, Decision>,
) -> SchResult<Schedule> {
    let mut sch = Schedule::new(prog.clone(), seed);
    for (idx, inst) in trace.insts.iter().enumerate() {
        apply(&mut sch, idx, inst, overrides.get(&idx), false)?;
    }
    Ok(sch)
}

/// Replay `trace` on `prog`, redrawing every sampling decision from its
/// (state-dependent) distribution. This is "fork-and-sample": how the
/// search initializes a population from one design-space trace (paper §4,
/// "conceptually ... sampling the program conditioned on the execution
/// sequence").
pub fn replay_fresh(trace: &Trace, prog: &Program, seed: u64) -> SchResult<Schedule> {
    let mut sch = Schedule::new(prog.clone(), seed);
    for (idx, inst) in trace.insts.iter().enumerate() {
        apply(&mut sch, idx, inst, None, true)?;
    }
    Ok(sch)
}

fn expect_outs(got: &[usize], want: &[usize]) -> SchResult<()> {
    if got != want {
        return Err(ScheduleError::Unsupported(format!(
            "replay RV misalignment: got {got:?}, trace says {want:?}"
        )));
    }
    Ok(())
}

fn apply(
    sch: &mut Schedule,
    _idx: usize,
    inst: &Inst,
    over: Option<&Decision>,
    fresh: bool,
) -> SchResult<()> {
    match inst {
        Inst::GetBlock { name, out } => {
            let rv = sch.get_block(name)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::GetLoops { block, outs } => {
            let rvs = sch.get_loops(BlockRv(*block))?;
            expect_outs(&rvs.iter().map(|r| r.0).collect::<Vec<_>>(), outs)
        }
        Inst::GetProducers { block, outs } => {
            let rvs = sch.get_producers(BlockRv(*block))?;
            expect_outs(&rvs.iter().map(|r| r.0).collect::<Vec<_>>(), outs)
        }
        Inst::GetConsumers { block, outs } => {
            let rvs = sch.get_consumers(BlockRv(*block))?;
            expect_outs(&rvs.iter().map(|r| r.0).collect::<Vec<_>>(), outs)
        }
        Inst::SamplePerfectTile {
            loop_rv,
            n,
            max_innermost,
            outs,
            decision,
        } => {
            let d = match over {
                Some(Decision::Tile(t)) => t.clone(),
                Some(_) => {
                    return Err(ScheduleError::InvalidDecision(
                        "override kind mismatch for perfect-tile".into(),
                    ))
                }
                None => decision.clone(),
            };
            let d = if fresh && over.is_none() { None } else { Some(d) };
            let rvs = sch.sample_perfect_tile_decided(LoopRv(*loop_rv), *n, *max_innermost, d)?;
            expect_outs(&rvs.iter().map(|r| r.0).collect::<Vec<_>>(), outs)
        }
        Inst::SampleCategorical {
            candidates,
            probs,
            out,
            decision,
        } => {
            let d = match over {
                Some(Decision::Categorical(i)) => *i,
                Some(_) => {
                    return Err(ScheduleError::InvalidDecision(
                        "override kind mismatch for categorical".into(),
                    ))
                }
                None => *decision,
            };
            let d = if fresh && over.is_none() { None } else { Some(d) };
            let rv = sch.sample_categorical_decided(candidates, probs, d)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::SampleComputeLocation {
            block,
            out,
            decision,
        } => {
            let d = match over {
                Some(Decision::Location(l)) => *l,
                Some(_) => {
                    return Err(ScheduleError::InvalidDecision(
                        "override kind mismatch for compute-location".into(),
                    ))
                }
                None => *decision,
            };
            let d = if fresh && over.is_none() { None } else { Some(d) };
            let rv = sch.sample_compute_location_decided(BlockRv(*block), d)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::Split {
            loop_rv,
            factors,
            outs,
        } => {
            let rvs = sch.split(LoopRv(*loop_rv), factors)?;
            expect_outs(&rvs.iter().map(|r| r.0).collect::<Vec<_>>(), outs)
        }
        Inst::Fuse { loops, out } => {
            let ls: Vec<LoopRv> = loops.iter().map(|&l| LoopRv(l)).collect();
            let rv = sch.fuse(&ls)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::Reorder { loops } => {
            let ls: Vec<LoopRv> = loops.iter().map(|&l| LoopRv(l)).collect();
            sch.reorder(&ls)
        }
        Inst::Parallel { loop_rv } => sch.parallel(LoopRv(*loop_rv)),
        Inst::Vectorize { loop_rv } => sch.vectorize(LoopRv(*loop_rv)),
        Inst::Unroll { loop_rv } => sch.unroll(LoopRv(*loop_rv)),
        Inst::Bind { loop_rv, thread } => sch.bind(LoopRv(*loop_rv), thread),
        Inst::AddUnitLoop { block, out } => {
            let rv = sch.add_unit_loop(BlockRv(*block))?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::CacheRead {
            block,
            read_idx,
            scope,
            out,
        } => {
            let rv = sch.cache_read(BlockRv(*block), *read_idx, scope)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::CacheWrite {
            block,
            write_idx,
            scope,
            out,
        } => {
            let rv = sch.cache_write(BlockRv(*block), *write_idx, scope)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::SetScope {
            block,
            write_idx,
            scope,
        } => sch.set_scope(BlockRv(*block), *write_idx, scope),
        Inst::StorageAlign {
            block,
            write_idx,
            axis,
            factor,
        } => sch.storage_align(BlockRv(*block), *write_idx, *axis, *factor),
        Inst::TransformLayout {
            block,
            read_idx,
            perm,
            out,
        } => {
            let rv = sch.transform_layout(BlockRv(*block), *read_idx, perm)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::ComputeAt { block, loop_rv } => sch.compute_at(BlockRv(*block), LoopRv(*loop_rv)),
        Inst::ReverseComputeAt { block, loop_rv } => {
            sch.reverse_compute_at(BlockRv(*block), LoopRv(*loop_rv))
        }
        Inst::ComputeInline { block } => sch.compute_inline(BlockRv(*block)),
        Inst::ReverseComputeInline { block } => sch.reverse_compute_inline(BlockRv(*block)),
        Inst::RFactor {
            block,
            loop_rv,
            out,
        } => {
            let rv = sch.rfactor(BlockRv(*block), LoopRv(*loop_rv))?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::DecomposeReduction {
            block,
            loop_rv,
            out,
        } => {
            let rv = sch.decompose_reduction(BlockRv(*block), LoopRv(*loop_rv))?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::Blockize { loop_rv, out } => {
            let rv = sch.blockize(LoopRv(*loop_rv))?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::Tensorize {
            loop_rv,
            intrin,
            out,
        } => {
            let rv = sch.tensorize(LoopRv(*loop_rv), intrin)?;
            expect_outs(&[rv.0], &[*out])
        }
        Inst::AnnotateBlock { block, key, value } => {
            sch.annotate_block(BlockRv(*block), key, value)
        }
        Inst::AnnotateLoop {
            loop_rv,
            key,
            value,
        } => sch.annotate_loop(LoopRv(*loop_rv), key, value),
        Inst::UnannotateBlock { block, key } => sch.unannotate_block(BlockRv(*block), key),
        Inst::EnterPostproc => {
            sch.record(Inst::EnterPostproc);
            Ok(())
        }
    }
}

/// Extract the decisions of all sampling instructions in a trace
/// (index -> decision), used by mutators.
pub fn decisions_of(trace: &Trace) -> HashMap<usize, Decision> {
    let mut out = HashMap::new();
    for (idx, inst) in trace.insts.iter().enumerate() {
        match inst {
            Inst::SamplePerfectTile { decision, .. } => {
                out.insert(idx, Decision::Tile(decision.clone()));
            }
            Inst::SampleCategorical { decision, .. } => {
                out.insert(idx, Decision::Categorical(*decision));
            }
            Inst::SampleComputeLocation { decision, .. } => {
                out.insert(idx, Decision::Location(*decision));
            }
            _ => {}
        }
    }
    out
}

/// ExprRv helper used by generated code in modules.
pub fn expr_rvs(ids: &[usize]) -> Vec<ExprRv> {
    ids.iter().map(|&i| ExprRv(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::{dense_relu_prog, matmul_prog};
    use crate::tir::printer::structural_hash;
    use crate::trace::FactorArg;

    /// Record a little schedule with sampling, then replay it.
    fn sample_schedule(seed: u64) -> (Program, Schedule) {
        let prog = matmul_prog(64, 32);
        let mut s = Schedule::new(prog.clone(), seed);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let t = s.sample_perfect_tile(loops[0], 2, 16).unwrap();
        s.split(
            loops[0],
            &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)],
        )
        .unwrap();
        let v = s.sample_categorical(&[0, 16, 64], &[0.3, 0.3, 0.4]).unwrap();
        let loops2 = s.get_loops(b).unwrap();
        s.annotate_loop(loops2[0], "pragma_unroll", &s.expr_value(v).to_string())
            .unwrap();
        (prog, s)
    }

    #[test]
    fn replay_reproduces_program_exactly() {
        let (prog, s) = sample_schedule(42);
        let r = replay(&s.trace, &prog, 0).unwrap();
        assert_eq!(structural_hash(&s.prog), structural_hash(&r.prog));
        assert_eq!(r.trace.insts.len(), s.trace.insts.len());
    }

    #[test]
    fn replay_with_override_changes_tiling() {
        let (prog, s) = sample_schedule(42);
        // Find the perfect-tile instruction.
        let idx = s
            .trace
            .insts
            .iter()
            .position(|i| matches!(i, Inst::SamplePerfectTile { .. }))
            .unwrap();
        let mut overrides = HashMap::new();
        overrides.insert(idx, Decision::Tile(vec![16, 4]));
        let r = replay_with_decisions(&s.trace, &prog, 0, &overrides).unwrap();
        // The replayed trace records the overridden decision.
        match &r.trace.insts[idx] {
            Inst::SamplePerfectTile { decision, .. } => assert_eq!(decision, &vec![16, 4]),
            _ => panic!(),
        }
    }

    #[test]
    fn replay_with_invalid_override_rejected() {
        let (prog, s) = sample_schedule(42);
        let idx = s
            .trace
            .insts
            .iter()
            .position(|i| matches!(i, Inst::SamplePerfectTile { .. }))
            .unwrap();
        let mut overrides = HashMap::new();
        overrides.insert(idx, Decision::Tile(vec![5, 13])); // 65 != 64
        assert!(replay_with_decisions(&s.trace, &prog, 0, &overrides).is_err());
    }

    #[test]
    fn replay_complex_trace_with_fusion() {
        let prog = dense_relu_prog(16, 8);
        let mut s = Schedule::new(prog.clone(), 1);
        let dense = s.get_block("matmul").unwrap();
        let relu = s.get_block("relu").unwrap();
        let loops = s.get_loops(dense).unwrap();
        let t = s.sample_perfect_tile(loops[0], 2, 8).unwrap();
        let parts = s
            .split(loops[0], &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])
            .unwrap();
        s.reverse_compute_at(relu, parts[0]).unwrap();
        s.prog.check_integrity().unwrap();
        let r = replay(&s.trace, &prog, 7).unwrap();
        assert_eq!(structural_hash(&s.prog), structural_hash(&r.prog));
    }

    #[test]
    fn decisions_of_extracts_all_sampling() {
        let (_, s) = sample_schedule(42);
        let d = decisions_of(&s.trace);
        assert_eq!(d.len(), 2);
        assert!(d.values().any(|x| matches!(x, Decision::Tile(_))));
        assert!(d.values().any(|x| matches!(x, Decision::Categorical(_))));
    }
}
