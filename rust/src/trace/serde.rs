//! Text serialization of traces (one instruction per line) with an exact
//! parse round-trip. The search database persists tuned traces in this
//! format, mirroring how TVM MetaSchedule stores tuning records.

use crate::trace::{FactorArg, Inst, Trace};

fn ints(v: &[i64]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

fn usizes(v: &[usize]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

fn floats(v: &[f64]) -> String {
    v.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(",")
}

fn factors(v: &[FactorArg]) -> String {
    v.iter()
        .map(|f| match f {
            FactorArg::Rv(r) => format!("rv{r}"),
            FactorArg::Lit(l) => format!("{l}"),
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Escape a string value (names, scopes) for the line format. The
/// parser tokenizes with `split_whitespace`, which splits on *all*
/// Unicode whitespace — so every whitespace char must be escaped, not
/// just ASCII space and newlines (which would also break the
/// line-per-instruction framing and the JSONL tuning database built on
/// it). Common ones get short escapes; the rest go through `\u{hex}`.
fn esc(s: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            ' ' => out.push_str("\\s"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if c.is_whitespace() => {
                let _ = write!(out, "\\u{{{:x}}}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Single-pass inverse of [`esc`]. A scanner, not chained `str::replace`
/// calls — replace-chains mis-decode adjacent sequences (e.g. the name
/// `\s` escapes to `\\s`, which a `\s -> space` replace would corrupt).
fn unesc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars().peekable();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('s') => out.push(' '),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') if chars.peek() == Some(&'{') => {
                chars.next(); // consume '{'
                let mut hex = String::new();
                let mut closed = false;
                for h in chars.by_ref() {
                    if h == '}' {
                        closed = true;
                        break;
                    }
                    hex.push(h);
                }
                match u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                    Some(ch) if closed => out.push(ch),
                    // Lenient: malformed \u{...} kept literally.
                    _ => {
                        out.push_str("\\u{");
                        out.push_str(&hex);
                        if closed {
                            out.push('}');
                        }
                    }
                }
            }
            // Lenient: unknown escape (or trailing backslash) kept as-is.
            Some(other) => {
                out.push('\\');
                out.push(other);
            }
            None => out.push('\\'),
        }
    }
    out
}

/// Serialize one instruction to a line.
pub fn inst_to_line(inst: &Inst) -> String {
    match inst {
        Inst::GetBlock { name, out } => format!("get-block name={} out={out}", esc(name)),
        Inst::GetLoops { block, outs } => {
            format!("get-loops block={block} outs={}", usizes(outs))
        }
        Inst::GetProducers { block, outs } => {
            format!("get-producers block={block} outs={}", usizes(outs))
        }
        Inst::GetConsumers { block, outs } => {
            format!("get-consumers block={block} outs={}", usizes(outs))
        }
        Inst::SamplePerfectTile {
            loop_rv,
            n,
            max_innermost,
            outs,
            decision,
        } => format!(
            "sample-perfect-tile loop={loop_rv} n={n} max={max_innermost} outs={} decision={}",
            usizes(outs),
            ints(decision)
        ),
        Inst::SampleCategorical {
            candidates,
            probs,
            out,
            decision,
        } => format!(
            "sample-categorical candidates={} probs={} out={out} decision={decision}",
            ints(candidates),
            floats(probs)
        ),
        Inst::SampleComputeLocation {
            block,
            out,
            decision,
        } => format!("sample-compute-location block={block} out={out} decision={decision}"),
        Inst::Split {
            loop_rv,
            factors: f,
            outs,
        } => format!(
            "split loop={loop_rv} factors={} outs={}",
            factors(f),
            usizes(outs)
        ),
        Inst::Fuse { loops, out } => format!("fuse loops={} out={out}", usizes(loops)),
        Inst::Reorder { loops } => format!("reorder loops={}", usizes(loops)),
        Inst::Parallel { loop_rv } => format!("parallel loop={loop_rv}"),
        Inst::Vectorize { loop_rv } => format!("vectorize loop={loop_rv}"),
        Inst::Unroll { loop_rv } => format!("unroll loop={loop_rv}"),
        Inst::Bind { loop_rv, thread } => format!("bind loop={loop_rv} thread={}", esc(thread)),
        Inst::AddUnitLoop { block, out } => format!("add-unit-loop block={block} out={out}"),
        Inst::CacheRead {
            block,
            read_idx,
            scope,
            out,
        } => format!(
            "cache-read block={block} idx={read_idx} scope={} out={out}",
            esc(scope)
        ),
        Inst::CacheWrite {
            block,
            write_idx,
            scope,
            out,
        } => format!(
            "cache-write block={block} idx={write_idx} scope={} out={out}",
            esc(scope)
        ),
        Inst::SetScope {
            block,
            write_idx,
            scope,
        } => format!("set-scope block={block} idx={write_idx} scope={}", esc(scope)),
        Inst::StorageAlign {
            block,
            write_idx,
            axis,
            factor,
        } => format!("storage-align block={block} idx={write_idx} axis={axis} factor={factor}"),
        Inst::TransformLayout {
            block,
            read_idx,
            perm,
            out,
        } => format!(
            "transform-layout block={block} idx={read_idx} perm={} out={out}",
            usizes(perm)
        ),
        Inst::ComputeAt { block, loop_rv } => format!("compute-at block={block} loop={loop_rv}"),
        Inst::ReverseComputeAt { block, loop_rv } => {
            format!("reverse-compute-at block={block} loop={loop_rv}")
        }
        Inst::ComputeInline { block } => format!("compute-inline block={block}"),
        Inst::ReverseComputeInline { block } => format!("reverse-compute-inline block={block}"),
        Inst::RFactor {
            block,
            loop_rv,
            out,
        } => format!("rfactor block={block} loop={loop_rv} out={out}"),
        Inst::DecomposeReduction {
            block,
            loop_rv,
            out,
        } => format!("decompose-reduction block={block} loop={loop_rv} out={out}"),
        Inst::Blockize { loop_rv, out } => format!("blockize loop={loop_rv} out={out}"),
        Inst::Tensorize {
            loop_rv,
            intrin,
            out,
        } => format!("tensorize loop={loop_rv} intrin={} out={out}", esc(intrin)),
        Inst::AnnotateBlock { block, key, value } => format!(
            "annotate-block block={block} key={} value={}",
            esc(key),
            esc(value)
        ),
        Inst::AnnotateLoop {
            loop_rv,
            key,
            value,
        } => format!(
            "annotate-loop loop={loop_rv} key={} value={}",
            esc(key),
            esc(value)
        ),
        Inst::UnannotateBlock { block, key } => {
            format!("unannotate-block block={block} key={}", esc(key))
        }
        Inst::EnterPostproc => "enter-postproc".to_string(),
    }
}

/// Serialize a whole trace.
pub fn trace_to_text(trace: &Trace) -> String {
    let mut out = String::new();
    for inst in &trace.insts {
        out.push_str(&inst_to_line(inst));
        out.push('\n');
    }
    out
}

fn kv(parts: &[&str], key: &str) -> Result<String, String> {
    for p in parts {
        if let Some(v) = p.strip_prefix(&format!("{key}=")) {
            return Ok(v.to_string());
        }
    }
    Err(format!("missing key {key}"))
}

fn p_usize(parts: &[&str], key: &str) -> Result<usize, String> {
    kv(parts, key)?.parse().map_err(|e| format!("{key}: {e}"))
}

fn p_i64(parts: &[&str], key: &str) -> Result<i64, String> {
    kv(parts, key)?.parse().map_err(|e| format!("{key}: {e}"))
}

fn p_usizes(parts: &[&str], key: &str) -> Result<Vec<usize>, String> {
    let raw = kv(parts, key)?;
    if raw.is_empty() {
        return Ok(vec![]);
    }
    raw.split(',')
        .map(|s| s.parse().map_err(|e| format!("{key}: {e}")))
        .collect()
}

fn p_i64s(parts: &[&str], key: &str) -> Result<Vec<i64>, String> {
    let raw = kv(parts, key)?;
    if raw.is_empty() {
        return Ok(vec![]);
    }
    raw.split(',')
        .map(|s| s.parse().map_err(|e| format!("{key}: {e}")))
        .collect()
}

fn p_f64s(parts: &[&str], key: &str) -> Result<Vec<f64>, String> {
    let raw = kv(parts, key)?;
    if raw.is_empty() {
        return Ok(vec![]);
    }
    raw.split(',')
        .map(|s| s.parse().map_err(|e| format!("{key}: {e}")))
        .collect()
}

fn p_factors(parts: &[&str], key: &str) -> Result<Vec<FactorArg>, String> {
    let raw = kv(parts, key)?;
    raw.split(',')
        .map(|s| {
            if let Some(rv) = s.strip_prefix("rv") {
                rv.parse().map(FactorArg::Rv).map_err(|e| format!("{e}"))
            } else {
                s.parse().map(FactorArg::Lit).map_err(|e| format!("{e}"))
            }
        })
        .collect()
}

/// Parse one instruction line.
pub fn line_to_inst(line: &str) -> Result<Inst, String> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    let op = *parts.first().ok_or("empty line")?;
    let p = &parts[1..];
    Ok(match op {
        "get-block" => Inst::GetBlock {
            name: unesc(&kv(p, "name")?),
            out: p_usize(p, "out")?,
        },
        "get-loops" => Inst::GetLoops {
            block: p_usize(p, "block")?,
            outs: p_usizes(p, "outs")?,
        },
        "get-producers" => Inst::GetProducers {
            block: p_usize(p, "block")?,
            outs: p_usizes(p, "outs")?,
        },
        "get-consumers" => Inst::GetConsumers {
            block: p_usize(p, "block")?,
            outs: p_usizes(p, "outs")?,
        },
        "sample-perfect-tile" => Inst::SamplePerfectTile {
            loop_rv: p_usize(p, "loop")?,
            n: p_usize(p, "n")?,
            max_innermost: p_i64(p, "max")?,
            outs: p_usizes(p, "outs")?,
            decision: p_i64s(p, "decision")?,
        },
        "sample-categorical" => Inst::SampleCategorical {
            candidates: p_i64s(p, "candidates")?,
            probs: p_f64s(p, "probs")?,
            out: p_usize(p, "out")?,
            decision: p_usize(p, "decision")?,
        },
        "sample-compute-location" => Inst::SampleComputeLocation {
            block: p_usize(p, "block")?,
            out: p_usize(p, "out")?,
            decision: p_i64(p, "decision")?,
        },
        "split" => Inst::Split {
            loop_rv: p_usize(p, "loop")?,
            factors: p_factors(p, "factors")?,
            outs: p_usizes(p, "outs")?,
        },
        "fuse" => Inst::Fuse {
            loops: p_usizes(p, "loops")?,
            out: p_usize(p, "out")?,
        },
        "reorder" => Inst::Reorder {
            loops: p_usizes(p, "loops")?,
        },
        "parallel" => Inst::Parallel {
            loop_rv: p_usize(p, "loop")?,
        },
        "vectorize" => Inst::Vectorize {
            loop_rv: p_usize(p, "loop")?,
        },
        "unroll" => Inst::Unroll {
            loop_rv: p_usize(p, "loop")?,
        },
        "bind" => Inst::Bind {
            loop_rv: p_usize(p, "loop")?,
            thread: unesc(&kv(p, "thread")?),
        },
        "add-unit-loop" => Inst::AddUnitLoop {
            block: p_usize(p, "block")?,
            out: p_usize(p, "out")?,
        },
        "cache-read" => Inst::CacheRead {
            block: p_usize(p, "block")?,
            read_idx: p_usize(p, "idx")?,
            scope: unesc(&kv(p, "scope")?),
            out: p_usize(p, "out")?,
        },
        "cache-write" => Inst::CacheWrite {
            block: p_usize(p, "block")?,
            write_idx: p_usize(p, "idx")?,
            scope: unesc(&kv(p, "scope")?),
            out: p_usize(p, "out")?,
        },
        "set-scope" => Inst::SetScope {
            block: p_usize(p, "block")?,
            write_idx: p_usize(p, "idx")?,
            scope: unesc(&kv(p, "scope")?),
        },
        "storage-align" => Inst::StorageAlign {
            block: p_usize(p, "block")?,
            write_idx: p_usize(p, "idx")?,
            axis: p_usize(p, "axis")?,
            factor: p_i64(p, "factor")?,
        },
        "transform-layout" => Inst::TransformLayout {
            block: p_usize(p, "block")?,
            read_idx: p_usize(p, "idx")?,
            perm: p_usizes(p, "perm")?,
            out: p_usize(p, "out")?,
        },
        "compute-at" => Inst::ComputeAt {
            block: p_usize(p, "block")?,
            loop_rv: p_usize(p, "loop")?,
        },
        "reverse-compute-at" => Inst::ReverseComputeAt {
            block: p_usize(p, "block")?,
            loop_rv: p_usize(p, "loop")?,
        },
        "compute-inline" => Inst::ComputeInline {
            block: p_usize(p, "block")?,
        },
        "reverse-compute-inline" => Inst::ReverseComputeInline {
            block: p_usize(p, "block")?,
        },
        "rfactor" => Inst::RFactor {
            block: p_usize(p, "block")?,
            loop_rv: p_usize(p, "loop")?,
            out: p_usize(p, "out")?,
        },
        "decompose-reduction" => Inst::DecomposeReduction {
            block: p_usize(p, "block")?,
            loop_rv: p_usize(p, "loop")?,
            out: p_usize(p, "out")?,
        },
        "blockize" => Inst::Blockize {
            loop_rv: p_usize(p, "loop")?,
            out: p_usize(p, "out")?,
        },
        "tensorize" => Inst::Tensorize {
            loop_rv: p_usize(p, "loop")?,
            intrin: unesc(&kv(p, "intrin")?),
            out: p_usize(p, "out")?,
        },
        "annotate-block" => Inst::AnnotateBlock {
            block: p_usize(p, "block")?,
            key: unesc(&kv(p, "key")?),
            value: unesc(&kv(p, "value")?),
        },
        "annotate-loop" => Inst::AnnotateLoop {
            loop_rv: p_usize(p, "loop")?,
            key: unesc(&kv(p, "key")?),
            value: unesc(&kv(p, "value")?),
        },
        "unannotate-block" => Inst::UnannotateBlock {
            block: p_usize(p, "block")?,
            key: unesc(&kv(p, "key")?),
        },
        "enter-postproc" => Inst::EnterPostproc,
        other => return Err(format!("unknown opcode {other}")),
    })
}

/// Parse a whole trace (blank lines and `#` comments ignored).
pub fn text_to_trace(text: &str) -> Result<Trace, String> {
    let mut insts = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        insts.push(line_to_inst(line).map_err(|e| format!("line {}: {e}", no + 1))?);
    }
    Ok(Trace { insts })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_insts() -> Vec<Inst> {
        vec![
            Inst::GetBlock {
                name: "T dense".into(),
                out: 0,
            },
            Inst::GetLoops {
                block: 0,
                outs: vec![1, 2, 3],
            },
            Inst::SamplePerfectTile {
                loop_rv: 1,
                n: 4,
                max_innermost: 16,
                outs: vec![4, 5, 6, 7],
                decision: vec![2, 8, 2, 2],
            },
            Inst::SampleCategorical {
                candidates: vec![0, 16, 64],
                probs: vec![0.25, 0.5, 0.25],
                out: 8,
                decision: 1,
            },
            Inst::Split {
                loop_rv: 1,
                factors: vec![FactorArg::Rv(4), FactorArg::Lit(8)],
                outs: vec![9, 10],
            },
            Inst::Fuse {
                loops: vec![9, 10],
                out: 11,
            },
            Inst::Reorder {
                loops: vec![11, 2],
            },
            Inst::Bind {
                loop_rv: 11,
                thread: "blockIdx.x".into(),
            },
            Inst::CacheRead {
                block: 0,
                read_idx: 1,
                scope: "shared.dyn".into(),
                out: 12,
            },
            Inst::ComputeAt {
                block: 12,
                loop_rv: 2,
            },
            Inst::TransformLayout {
                block: 0,
                read_idx: 1,
                perm: vec![1, 0],
                out: 14,
            },
            Inst::Tensorize {
                loop_rv: 2,
                intrin: "wmma_16x16x16".into(),
                out: 13,
            },
            Inst::AnnotateBlock {
                block: 0,
                key: "software pipeline".into(),
                value: "0,0,1".into(),
            },
            Inst::EnterPostproc,
        ]
    }

    #[test]
    fn every_inst_roundtrips() {
        for inst in sample_insts() {
            let line = inst_to_line(&inst);
            let back = line_to_inst(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, inst, "line: {line}");
        }
    }

    #[test]
    fn whole_trace_roundtrips() {
        let t = Trace {
            insts: sample_insts(),
        };
        let text = trace_to_text(&t);
        let back = text_to_trace(&text).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let text = "# a comment\n\nparallel loop=3\n";
        let t = text_to_trace(text).unwrap();
        assert_eq!(t.insts, vec![Inst::Parallel { loop_rv: 3 }]);
    }

    #[test]
    fn unknown_opcode_errors() {
        assert!(text_to_trace("frobnicate x=1").is_err());
    }

    #[test]
    fn escaped_spaces_in_names() {
        let inst = Inst::GetBlock {
            name: "a b".into(),
            out: 0,
        };
        let line = inst_to_line(&inst);
        assert!(!line.contains("a b"));
        assert_eq!(line_to_inst(&line).unwrap(), inst);
    }

    #[test]
    fn hostile_names_roundtrip() {
        // Newlines must not break the line-per-instruction framing (a
        // block named "a\nb" once corrupted the whole trace file), and
        // escape-adjacent names must not confuse the decoder.
        let names = [
            "a\nb",
            "a\r\nb",
            "tab\there",
            "back\\slash",
            "\\s",
            "\\\\s",
            "trailing\\",
            "mix \\n literal",
            " lead and trail ",
            // Non-ASCII / exotic whitespace: split_whitespace() splits on
            // all of these, so esc() must catch them too.
            "a\u{a0}b",
            "v\u{0b}tab",
            "ff\u{0c}",
            "line\u{2028}sep",
            "em\u{2003}space",
            // Literal text that *looks* like the \u escape must survive.
            "\\u{b}",
            "u{b}",
        ];
        for name in names {
            let inst = Inst::GetBlock {
                name: name.into(),
                out: 0,
            };
            let line = inst_to_line(&inst);
            // `get-block name=... out=...` must stay exactly 3 tokens —
            // any whitespace leaking out of esc() would split more.
            assert_eq!(
                line.split_whitespace().count(),
                3,
                "name {name:?} leaked whitespace that splits tokens: {line:?}"
            );
            assert!(!line.contains('\n'), "name {name:?} leaked a newline into the line format");
            assert_eq!(line_to_inst(&line).unwrap(), inst, "name {name:?}");
        }
        // Whole-trace framing survives a newline-bearing annotation value.
        let t = Trace {
            insts: vec![
                Inst::GetBlock {
                    name: "evil\nname".into(),
                    out: 0,
                },
                Inst::AnnotateBlock {
                    block: 0,
                    key: "k v".into(),
                    value: "line1\nline2\r\n".into(),
                },
            ],
        };
        let text = trace_to_text(&t);
        assert_eq!(text.lines().count(), 2);
        assert_eq!(text_to_trace(&text).unwrap(), t);
    }

    #[test]
    fn unesc_is_exact_inverse_on_adjacent_sequences() {
        for s in ["\\s", "a\\sb", "\\\\", "\\n\\r", "x\\", "\\u{a0}", "\u{a0}", "\\u{", "u{}"] {
            assert_eq!(super::unesc(&super::esc(s)), s);
        }
    }
}
