//! Hash-consed trace IR: intern instructions once, address traces as
//! chains of canonical node ids (the ROADMAP's arena + global-value-
//! numbering item).
//!
//! The evolutionary hot path compares, dedups, and featurizes thousands
//! of candidate traces per round. Interning gives every distinct
//! instruction exactly one numbered node in an [`InternArena`], so that
//!
//! - structural equality of traces is id-chain equality — no field-wise
//!   compare, no re-serialization ([`InternedTrace`] hashes and compares
//!   by its ids, which is what the search's dedup set keys on);
//! - a mutated candidate shares every unchanged node with its parent:
//!   [`InternArena::intern_mutated`] re-interns exactly the one rewritten
//!   decision node (the mutators rewrite one sampling decision at a
//!   time) and `Arc`-shares the memoized sampling-index list;
//! - derived per-trace data memoizes on the chain: sampling indices are
//!   computed once at intern time ([`InternedTrace::sampling_indices`])
//!   instead of rescanned per mutation proposal, and the cost model's
//!   feature cache ([`crate::cost_model::FeatureCache`]) keys on
//!   `(workload, id chain)`.
//!
//! Node-id *values* depend on interning order: single-threaded sessions
//! assign identical chains across runs, while concurrent interning may
//! permute ids with thread interleaving. Determinism is preserved
//! because ids are injective per arena and every consumer depends only
//! on id *equality*, never on the numeric value — which is also why the
//! dedup and cache keys are full id chains rather than a folded 64-bit
//! fingerprint (a fingerprint collision would change behaviour
//! nondeterministically). On-disk formats are untouched: `cand_hash`
//! stays the structural hash of the scheduled program (docs/DB_FORMAT.md
//! pins this).
//!
//! Instructions are fingerprinted through their canonical serialization
//! ([`crate::trace::serde::inst_to_line`]) — the same text the database
//! round-trips byte-for-byte — with bitwise `f64` comparison resolving
//! hash-bucket collisions, so even NaN-carrying `SampleCategorical`
//! probability vectors intern stably.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::telemetry::Counter;
use crate::trace::{serde, Inst, Trace};

/// A canonical instruction id: index into the owning arena's node table.
/// Only meaningful within the arena that issued it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// A trace addressed as a chain of canonical node ids, plus the memoized
/// pre-postproc sampling-instruction indices. Cloning is two `Arc` bumps;
/// equality and hashing cover the id chain only (the sampling list is
/// derived data). Comparisons are only meaningful between traces interned
/// in the same [`InternArena`].
#[derive(Debug, Clone)]
pub struct InternedTrace {
    ids: Arc<[NodeId]>,
    sampling: Arc<[usize]>,
}

impl PartialEq for InternedTrace {
    fn eq(&self, other: &Self) -> bool {
        self.ids == other.ids
    }
}

impl Eq for InternedTrace {}

impl std::hash::Hash for InternedTrace {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.ids.hash(state);
    }
}

impl InternedTrace {
    /// The canonical id chain.
    pub fn ids(&self) -> &[NodeId] {
        &self.ids
    }

    /// Indices of decision-bearing (sampling) instructions before the
    /// `EnterPostproc` marker — [`Trace::sampling_indices`], computed
    /// once at intern time instead of rescanned per proposal.
    pub fn sampling_indices(&self) -> &[usize] {
        &self.sampling
    }

    /// Number of instructions.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// FNV-1a fold over the id chain. Diagnostics only — behaviour never
    /// branches on it (a collision must not be able to change results).
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for id in self.ids.iter() {
            for b in id.0.to_le_bytes() {
                h = fnv1a_byte(h, b);
            }
        }
        h
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

#[inline]
fn fnv1a_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = fnv1a_byte(h, b);
    }
    h
}

/// Fingerprint an instruction through its canonical text line. Bitwise-
/// distinct NaN probability payloads all format as `NaN` and share a
/// bucket; [`inst_bits_eq`] resolves them within the collision chain.
fn inst_fp(inst: &Inst) -> u64 {
    fnv1a(serde::inst_to_line(inst).as_bytes())
}

/// Interning equality: the derived `PartialEq` for every variant except
/// `SampleCategorical`, whose probability vector compares by `f64` bit
/// pattern — `NaN == NaN` is false under IEEE comparison, which would
/// allocate a fresh node on every lookup and leak the arena.
fn inst_bits_eq(a: &Inst, b: &Inst) -> bool {
    match (a, b) {
        (
            Inst::SampleCategorical { candidates: ca, probs: pa, out: oa, decision: da },
            Inst::SampleCategorical { candidates: cb, probs: pb, out: ob, decision: db },
        ) => {
            ca == cb
                && oa == ob
                && da == db
                && pa.len() == pb.len()
                && pa.iter().zip(pb).all(|(x, y)| x.to_bits() == y.to_bits())
        }
        _ => a == b,
    }
}

struct ArenaInner {
    /// Instruction fingerprint -> node ids with that fingerprint (the
    /// collision chain is almost always length 1).
    index: HashMap<u64, Vec<NodeId>>,
    nodes: Vec<Inst>,
}

/// The hash-consing arena: every structurally distinct instruction is
/// stored once and addressed by [`NodeId`]. Shared immutably across the
/// search's worker chains (`RwLock` inside); lookups of already-interned
/// instructions — the steady-state hot path — take only the read lock.
pub struct InternArena {
    inner: RwLock<ArenaInner>,
    /// Lookups resolved to an existing node (structural sharing at work).
    hits: Arc<Counter>,
    /// Fresh nodes allocated; equals the node count.
    allocated: Arc<Counter>,
}

impl InternArena {
    pub fn new() -> InternArena {
        InternArena::with_counters(Arc::new(Counter::new()), Arc::new(Counter::new()))
    }

    /// An arena recording hit/allocation counts into caller-registered
    /// counters (the `TuneContext` passes handles from its own metrics
    /// registry so `--explain-space` reports exact per-context counts).
    pub fn with_counters(hits: Arc<Counter>, allocated: Arc<Counter>) -> InternArena {
        InternArena {
            inner: RwLock::new(ArenaInner { index: HashMap::new(), nodes: Vec::new() }),
            hits,
            allocated,
        }
    }

    /// Number of distinct instructions interned so far.
    pub fn num_nodes(&self) -> usize {
        self.inner.read().unwrap().nodes.len()
    }

    /// Lookups that resolved to an existing node.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Intern one instruction, returning its canonical id.
    pub fn intern_inst(&self, inst: &Inst) -> NodeId {
        let fp = inst_fp(inst);
        {
            let g = self.inner.read().unwrap();
            if let Some(id) = Self::lookup(&g, fp, inst) {
                drop(g);
                self.hits.inc();
                return id;
            }
        }
        let mut g = self.inner.write().unwrap();
        // Re-check under the write lock: a racing interner may have won.
        if let Some(id) = Self::lookup(&g, fp, inst) {
            drop(g);
            self.hits.inc();
            return id;
        }
        assert!(g.nodes.len() < u32::MAX as usize, "intern arena exhausted u32 node ids");
        let id = NodeId(g.nodes.len() as u32);
        g.nodes.push(inst.clone());
        g.index.entry(fp).or_default().push(id);
        drop(g);
        self.allocated.inc();
        id
    }

    fn lookup(g: &ArenaInner, fp: u64, inst: &Inst) -> Option<NodeId> {
        g.index
            .get(&fp)?
            .iter()
            .copied()
            .find(|id| inst_bits_eq(&g.nodes[id.0 as usize], inst))
    }

    /// Intern a whole trace: canonical id chain plus memoized sampling
    /// indices, in one pass.
    pub fn intern(&self, trace: &Trace) -> InternedTrace {
        let mut ids = Vec::with_capacity(trace.insts.len());
        let mut sampling = Vec::new();
        let mut postproc = false;
        for (i, inst) in trace.insts.iter().enumerate() {
            if matches!(inst, Inst::EnterPostproc) {
                postproc = true;
            }
            if !postproc && inst.is_sampling() {
                sampling.push(i);
            }
            ids.push(self.intern_inst(inst));
        }
        InternedTrace { ids: ids.into(), sampling: sampling.into() }
    }

    /// Intern a single-decision mutation of `parent`: only the rewritten
    /// instruction at `idx` is re-interned; the prefix/suffix ids and the
    /// sampling-index list are shared with the parent. Falls back to a
    /// full [`InternArena::intern`] if `mutated` is not actually a
    /// same-shape single-instruction rewrite (defensive — the mutators
    /// only ever change one decision in place).
    pub fn intern_mutated(&self, parent: &InternedTrace, idx: usize, mutated: &Trace) -> InternedTrace {
        if mutated.insts.len() != parent.ids.len() || idx >= mutated.insts.len() {
            return self.intern(mutated);
        }
        let mut ids: Vec<NodeId> = parent.ids.to_vec();
        ids[idx] = self.intern_inst(&mutated.insts[idx]);
        let out = InternedTrace { ids: ids.into(), sampling: Arc::clone(&parent.sampling) };
        #[cfg(debug_assertions)]
        {
            let full = self.intern(mutated);
            debug_assert_eq!(
                full.ids(),
                out.ids(),
                "intern_mutated: mutated trace differs from parent beyond instruction {idx}"
            );
            debug_assert_eq!(
                full.sampling_indices(),
                out.sampling_indices(),
                "intern_mutated: decision rewrite changed the sampling-index set"
            );
        }
        out
    }

    /// Reconstruct the concrete trace behind an id chain. Panics if an id
    /// came from a different arena and is out of range.
    pub fn materialize(&self, it: &InternedTrace) -> Trace {
        let g = self.inner.read().unwrap();
        Trace { insts: it.ids.iter().map(|id| g.nodes[id.0 as usize].clone()).collect() }
    }

    /// The instruction behind one node id, if it exists in this arena.
    pub fn resolve(&self, id: NodeId) -> Option<Inst> {
        self.inner.read().unwrap().nodes.get(id.0 as usize).cloned()
    }
}

impl Default for InternArena {
    fn default() -> Self {
        InternArena::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::FactorArg;

    fn sample_trace() -> Trace {
        Trace {
            insts: vec![
                Inst::GetBlock { name: "matmul".into(), out: 0 },
                Inst::GetLoops { block: 0, outs: vec![1, 2, 3] },
                Inst::SamplePerfectTile {
                    loop_rv: 1,
                    n: 2,
                    max_innermost: 16,
                    outs: vec![4, 5],
                    decision: vec![8, 16],
                },
                Inst::Split {
                    loop_rv: 1,
                    factors: vec![FactorArg::Rv(4), FactorArg::Rv(5)],
                    outs: vec![6, 7],
                },
                Inst::EnterPostproc,
                Inst::Parallel { loop_rv: 6 },
            ],
        }
    }

    #[test]
    fn equal_insts_share_one_node() {
        let arena = InternArena::new();
        let a = Inst::GetBlock { name: "x".into(), out: 3 };
        let b = Inst::GetBlock { name: "x".into(), out: 3 };
        assert_eq!(arena.intern_inst(&a), arena.intern_inst(&b));
        assert_eq!(arena.num_nodes(), 1);
        assert_eq!(arena.hits(), 1);
        let c = Inst::GetBlock { name: "x".into(), out: 4 };
        assert_ne!(arena.intern_inst(&a), arena.intern_inst(&c));
        assert_eq!(arena.num_nodes(), 2);
    }

    #[test]
    fn intern_materialize_round_trips() {
        let arena = InternArena::new();
        let t = sample_trace();
        let it = arena.intern(&t);
        assert_eq!(arena.materialize(&it), t);
        assert_eq!(it.len(), t.len());
    }

    #[test]
    fn sampling_memo_matches_trace_scan() {
        let arena = InternArena::new();
        let t = sample_trace();
        assert_eq!(arena.intern(&t).sampling_indices(), t.sampling_indices().as_slice());
        // Sampling instruction after the postproc marker: excluded.
        let mut post = sample_trace();
        post.insts.push(Inst::SampleCategorical {
            candidates: vec![0, 1],
            probs: vec![0.5, 0.5],
            out: 9,
            decision: 0,
        });
        assert_eq!(arena.intern(&post).sampling_indices(), post.sampling_indices().as_slice());
    }

    #[test]
    fn equal_traces_equal_chains_unequal_traces_differ() {
        let arena = InternArena::new();
        let a = arena.intern(&sample_trace());
        let b = arena.intern(&sample_trace());
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut other = sample_trace();
        other.insts[5] = Inst::Vectorize { loop_rv: 6 };
        let c = arena.intern(&other);
        assert_ne!(a, c);
    }

    #[test]
    fn intern_mutated_shares_prefix_and_suffix() {
        let arena = InternArena::new();
        let t = sample_trace();
        let parent = arena.intern(&t);
        let mut mutated = t.clone();
        mutated.insts[2] = Inst::SamplePerfectTile {
            loop_rv: 1,
            n: 2,
            max_innermost: 16,
            outs: vec![4, 5],
            decision: vec![16, 8],
        };
        let child = arena.intern_mutated(&parent, 2, &mutated);
        assert_ne!(parent, child);
        for (i, (p, c)) in parent.ids().iter().zip(child.ids()).enumerate() {
            if i == 2 {
                assert_ne!(p, c);
            } else {
                assert_eq!(p, c);
            }
        }
        assert_eq!(arena.materialize(&child), mutated);
        // Same chain as a from-scratch intern of the mutated trace.
        assert_eq!(child, arena.intern(&mutated));
    }

    #[test]
    fn nan_probs_intern_stably() {
        // IEEE `NaN != NaN` must not defeat hash-consing: the same
        // NaN-carrying instruction interns to one node, and bitwise-
        // distinct NaN payloads stay distinct nodes.
        let arena = InternArena::new();
        let mk = |bits: u64| Inst::SampleCategorical {
            candidates: vec![0, 1],
            probs: vec![f64::from_bits(bits), 1.0],
            out: 0,
            decision: 1,
        };
        let quiet = f64::NAN.to_bits();
        let a = arena.intern_inst(&mk(quiet));
        let b = arena.intern_inst(&mk(quiet));
        assert_eq!(a, b);
        let payload = quiet | 1;
        assert_ne!(a, arena.intern_inst(&mk(payload)));
        // Negative zero is bitwise distinct from positive zero.
        let z = Inst::SampleCategorical { candidates: vec![0], probs: vec![0.0], out: 0, decision: 0 };
        let nz = Inst::SampleCategorical { candidates: vec![0], probs: vec![-0.0], out: 0, decision: 0 };
        assert_ne!(arena.intern_inst(&z), arena.intern_inst(&nz));
    }

    #[test]
    fn fresh_arenas_assign_identical_chains() {
        // Same intern order, fresh arenas: identical id values — the
        // cross-session canonical-id property the invariants suite
        // exercises over real design spaces.
        let a = InternArena::new();
        let b = InternArena::new();
        let traces = [sample_trace(), sample_trace()];
        for t in &traces {
            assert_eq!(a.intern(t).ids(), b.intern(t).ids());
        }
    }
}
