//! Execution traces: the linearized probabilistic programs of Figure 6.
//!
//! Running a MetaSchedule program records every sampling and transformation
//! instruction (host-language control flow is *not* recorded). The trace can
//! be re-executed against the initial program, its sampling decisions can be
//! overridden/mutated, and it serializes to a line-oriented text format.

pub mod intern;
pub mod replay;
pub mod serde;

pub use intern::{InternArena, InternedTrace, NodeId};
pub use replay::{replay, replay_with_decisions};

/// A `split` factor argument: either a previously-sampled expression RV or
/// an inline literal.
#[derive(Debug, Clone, PartialEq)]
pub enum FactorArg {
    Rv(usize),
    Lit(i64),
}

/// One recorded instruction. RV operands are indices into the schedule's
/// block/loop/expr tables; `out*` fields are the indices the instruction's
/// results were bound to (replay re-binds in the same order and asserts).
#[derive(Debug, Clone, PartialEq)]
pub enum Inst {
    // -- state queries ------------------------------------------------------
    GetBlock { name: String, out: usize },
    GetLoops { block: usize, outs: Vec<usize> },
    GetProducers { block: usize, outs: Vec<usize> },
    GetConsumers { block: usize, outs: Vec<usize> },
    // -- sampling (decision-bearing) -----------------------------------------
    SamplePerfectTile {
        loop_rv: usize,
        n: usize,
        max_innermost: i64,
        outs: Vec<usize>,
        decision: Vec<i64>,
    },
    SampleCategorical {
        candidates: Vec<i64>,
        probs: Vec<f64>,
        out: usize,
        decision: usize,
    },
    SampleComputeLocation {
        block: usize,
        out: usize,
        /// -1 = root, -2 = inlined, k >= 0 = k-th candidate loop.
        decision: i64,
    },
    // -- loop transformations -------------------------------------------------
    Split { loop_rv: usize, factors: Vec<FactorArg>, outs: Vec<usize> },
    Fuse { loops: Vec<usize>, out: usize },
    Reorder { loops: Vec<usize> },
    Parallel { loop_rv: usize },
    Vectorize { loop_rv: usize },
    Unroll { loop_rv: usize },
    Bind { loop_rv: usize, thread: String },
    AddUnitLoop { block: usize, out: usize },
    // -- caching / memory ------------------------------------------------------
    CacheRead { block: usize, read_idx: usize, scope: String, out: usize },
    CacheWrite { block: usize, write_idx: usize, scope: String, out: usize },
    SetScope { block: usize, write_idx: usize, scope: String },
    StorageAlign { block: usize, write_idx: usize, axis: usize, factor: i64 },
    TransformLayout { block: usize, read_idx: usize, perm: Vec<usize>, out: usize },
    // -- compute location --------------------------------------------------------
    ComputeAt { block: usize, loop_rv: usize },
    ReverseComputeAt { block: usize, loop_rv: usize },
    ComputeInline { block: usize },
    ReverseComputeInline { block: usize },
    // -- reductions ---------------------------------------------------------------
    RFactor { block: usize, loop_rv: usize, out: usize },
    DecomposeReduction { block: usize, loop_rv: usize, out: usize },
    // -- tensorization ---------------------------------------------------------------
    Blockize { loop_rv: usize, out: usize },
    Tensorize { loop_rv: usize, intrin: String, out: usize },
    // -- annotations -----------------------------------------------------------------
    AnnotateBlock { block: usize, key: String, value: String },
    AnnotateLoop { loop_rv: usize, key: String, value: String },
    UnannotateBlock { block: usize, key: String },
    /// Marks the boundary after which instructions are postprocessing (the
    /// search mutates only decisions before this marker).
    EnterPostproc,
}

impl Inst {
    /// Whether this instruction carries a mutable sampling decision.
    pub fn is_sampling(&self) -> bool {
        matches!(
            self,
            Inst::SamplePerfectTile { .. }
                | Inst::SampleCategorical { .. }
                | Inst::SampleComputeLocation { .. }
        )
    }

    /// Instruction mnemonic (used by serialization and stats).
    pub fn opcode(&self) -> &'static str {
        match self {
            Inst::GetBlock { .. } => "get-block",
            Inst::GetLoops { .. } => "get-loops",
            Inst::GetProducers { .. } => "get-producers",
            Inst::GetConsumers { .. } => "get-consumers",
            Inst::SamplePerfectTile { .. } => "sample-perfect-tile",
            Inst::SampleCategorical { .. } => "sample-categorical",
            Inst::SampleComputeLocation { .. } => "sample-compute-location",
            Inst::Split { .. } => "split",
            Inst::Fuse { .. } => "fuse",
            Inst::Reorder { .. } => "reorder",
            Inst::Parallel { .. } => "parallel",
            Inst::Vectorize { .. } => "vectorize",
            Inst::Unroll { .. } => "unroll",
            Inst::Bind { .. } => "bind",
            Inst::AddUnitLoop { .. } => "add-unit-loop",
            Inst::CacheRead { .. } => "cache-read",
            Inst::CacheWrite { .. } => "cache-write",
            Inst::SetScope { .. } => "set-scope",
            Inst::StorageAlign { .. } => "storage-align",
            Inst::TransformLayout { .. } => "transform-layout",
            Inst::ComputeAt { .. } => "compute-at",
            Inst::ReverseComputeAt { .. } => "reverse-compute-at",
            Inst::ComputeInline { .. } => "compute-inline",
            Inst::ReverseComputeInline { .. } => "reverse-compute-inline",
            Inst::RFactor { .. } => "rfactor",
            Inst::DecomposeReduction { .. } => "decompose-reduction",
            Inst::Blockize { .. } => "blockize",
            Inst::Tensorize { .. } => "tensorize",
            Inst::AnnotateBlock { .. } => "annotate-block",
            Inst::AnnotateLoop { .. } => "annotate-loop",
            Inst::UnannotateBlock { .. } => "unannotate-block",
            Inst::EnterPostproc => "enter-postproc",
        }
    }
}

/// A linearized probabilistic program: the recorded instruction sequence.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub insts: Vec<Inst>,
}

impl Trace {
    /// Indices of decision-bearing (sampling) instructions, excluding any
    /// after the `EnterPostproc` marker.
    pub fn sampling_indices(&self) -> Vec<usize> {
        let postproc = self
            .insts
            .iter()
            .position(|i| matches!(i, Inst::EnterPostproc))
            .unwrap_or(self.insts.len());
        self.insts[..postproc]
            .iter()
            .enumerate()
            .filter(|(_, i)| i.is_sampling())
            .map(|(idx, _)| idx)
            .collect()
    }

    /// Number of instructions.
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }
}
