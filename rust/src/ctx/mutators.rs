//! Trace mutators (paper §4, Figure 7), trait-ified: propose a new
//! variant of a trace by changing one random variable's sampling
//! decision, then validate by replaying. Replay failure = the proposal
//! left the support set and is rejected — the *trace validator*.
//!
//! Each [`Mutator`] owns one decision kind (tile transfer, categorical
//! redraw, compute-location move); a [`MutatorSet`] composes them with
//! configurable weights, so callers can extend or reweight mutation the
//! same way they extend the rule set. With the default set (exactly one
//! mutator per decision kind, equal weights) the RNG draw sequence is
//! bit-identical to the pre-trait free functions: the instruction pick is
//! uniform, and a weight draw only happens when *several* mutators claim
//! the same instruction.

use std::collections::HashMap;

use crate::schedule::Schedule;
use crate::telemetry::Counter;
use crate::tir::Program;
use crate::trace::replay::{replay_with_decisions, Decision};
use crate::trace::{Inst, Trace};
use crate::util::rng::Rng;

/// A per-decision-kind trace mutator. `applies` declares which sampling
/// instructions the mutator can rewrite; `propose` draws an alternative
/// decision (or `None` when the instruction has no alternative).
/// `Send + Sync` because the search's worker chains share one
/// [`crate::ctx::TuneContext`].
pub trait Mutator: Send + Sync {
    fn name(&self) -> &str;
    fn applies(&self, inst: &Inst) -> bool;
    fn propose(&self, trace: &Trace, idx: usize, prog: &Program, rng: &mut Rng) -> Option<Decision>;
}

/// Divisors of `x` greater than 1.
fn proper_divisors(x: i64) -> Vec<i64> {
    let mut out = Vec::new();
    let mut d = 2;
    while d * d <= x {
        if x % d == 0 {
            out.push(d);
            if d != x / d {
                out.push(x / d);
            }
        }
        d += 1;
    }
    if x > 1 {
        out.push(x);
    }
    out.sort_unstable();
    out
}

/// Tile-size transfer: move a divisor from one tile level to another
/// (preserves the factor product, i.e. stays a perfect tile).
pub struct TileTransfer;

impl Mutator for TileTransfer {
    fn name(&self) -> &str {
        "tile-transfer"
    }

    fn applies(&self, inst: &Inst) -> bool {
        matches!(inst, Inst::SamplePerfectTile { .. })
    }

    fn propose(&self, trace: &Trace, idx: usize, _prog: &Program, rng: &mut Rng) -> Option<Decision> {
        let Some(Inst::SamplePerfectTile { decision, max_innermost, .. }) = trace.insts.get(idx)
        else {
            return None;
        };
        let n = decision.len();
        if n < 2 {
            return None;
        }
        for _ in 0..16 {
            let src = rng.gen_range(n);
            let dst = rng.gen_range(n);
            if src == dst || decision[src] <= 1 {
                continue;
            }
            let divs = proper_divisors(decision[src]);
            if divs.is_empty() {
                continue;
            }
            let d = *rng.choose(&divs);
            let mut new = decision.clone();
            new[src] /= d;
            new[dst] *= d;
            if *max_innermost > 0 && *new.last().unwrap() > *max_innermost {
                continue;
            }
            if new != *decision {
                return Some(Decision::Tile(new));
            }
        }
        None
    }
}

/// Re-draw a different categorical index, weighted by the instruction's
/// own probabilities.
pub struct CategoricalRedraw;

impl Mutator for CategoricalRedraw {
    fn name(&self) -> &str {
        "categorical-redraw"
    }

    fn applies(&self, inst: &Inst) -> bool {
        matches!(inst, Inst::SampleCategorical { .. })
    }

    fn propose(&self, trace: &Trace, idx: usize, _prog: &Program, rng: &mut Rng) -> Option<Decision> {
        let Some(Inst::SampleCategorical { candidates, probs, decision, .. }) = trace.insts.get(idx)
        else {
            return None;
        };
        if candidates.len() < 2 {
            return None;
        }
        for _ in 0..16 {
            let i = rng.sample_weighted(probs);
            if i != *decision {
                return Some(Decision::Categorical(i));
            }
        }
        None
    }
}

/// Compute-location move: the candidate set is state-dependent, so the
/// trace prefix is replayed to recover the program state at that point.
pub struct ComputeLocationMove;

impl Mutator for ComputeLocationMove {
    fn name(&self) -> &str {
        "compute-location-move"
    }

    fn applies(&self, inst: &Inst) -> bool {
        matches!(inst, Inst::SampleComputeLocation { .. })
    }

    fn propose(&self, trace: &Trace, idx: usize, prog: &Program, rng: &mut Rng) -> Option<Decision> {
        let (block, old) = match trace.insts.get(idx) {
            Some(Inst::SampleComputeLocation { block, decision, .. }) => (*block, *decision),
            _ => return None,
        };
        // Replay everything before idx to recover the program state.
        let prefix = Trace {
            insts: trace.insts[..idx].to_vec(),
        };
        let sch = crate::trace::replay(&prefix, prog, 0).ok()?;
        let item = sch.block(crate::schedule::BlockRv(block)).ok()?;
        let n = sch.compute_location_candidates(item).len();
        // Candidates: {-1 (root)} ∪ {0..n}; try to find one different from old.
        let mut options: Vec<i64> = vec![-1];
        options.extend(0..n as i64);
        options.retain(|&d| d != old);
        if options.is_empty() {
            return None;
        }
        Some(Decision::Location(*rng.choose(&options)))
    }
}

struct Entry {
    mutator: Box<dyn Mutator>,
    weight: f64,
    /// Proposals dispatched to this mutator (diagnostics only; a
    /// standalone telemetry counter — the set outlives no registry, so
    /// the instrument is unregistered).
    proposed: Counter,
}

/// A weighted, ordered set of mutators — the mutation half of a
/// [`crate::ctx::TuneContext`].
pub struct MutatorSet {
    entries: Vec<Entry>,
}

impl MutatorSet {
    pub fn new() -> MutatorSet {
        MutatorSet { entries: Vec::new() }
    }

    /// The built-in default: one mutator per decision kind, equal weight
    /// — RNG-for-RNG the pre-registry mutation behaviour.
    pub fn builtin_default() -> MutatorSet {
        let mut set = MutatorSet::new();
        set.push(Box::new(TileTransfer), 1.0);
        set.push(Box::new(CategoricalRedraw), 1.0);
        set.push(Box::new(ComputeLocationMove), 1.0);
        set
    }

    pub fn push(&mut self, mutator: Box<dyn Mutator>, weight: f64) {
        self.entries.push(Entry {
            mutator,
            weight: weight.max(0.0),
            proposed: Counter::new(),
        });
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Canonical label: names joined with `,`, weights appended as
    /// `:w` only when not 1.
    pub fn label(&self) -> String {
        self.entries
            .iter()
            .map(|e| {
                if (e.weight - 1.0).abs() < 1e-12 {
                    e.mutator.name().to_string()
                } else {
                    format!("{}:{}", e.mutator.name(), e.weight)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// `(name, weight, proposals so far)` per mutator, for diagnostics.
    pub fn stats(&self) -> Vec<(String, f64, usize)> {
        self.entries
            .iter()
            .map(|e| (e.mutator.name().to_string(), e.weight, e.proposed.get() as usize))
            .collect()
    }

    /// Propose a mutated decision for the sampling instruction at `idx`:
    /// dispatch to the applicable mutator (weight-sampled only when more
    /// than one applies, so the default set draws nothing extra). The
    /// common exactly-one-applies case dispatches allocation-free — this
    /// runs inside the innermost search loop, where the old free
    /// functions dispatched with a bare `match`.
    pub fn propose_for(&self, trace: &Trace, idx: usize, prog: &Program, rng: &mut Rng) -> Option<Decision> {
        // A proposable index must name a pre-postproc sampling
        // instruction. Anything else — out of range, non-sampling, or
        // past the `EnterPostproc` marker — is a skip, never a panic:
        // stale indices reach here via traces loaded from a database
        // whose schedule primitives have since changed, and a trace
        // whose only sampling instructions sit in the postproc tail has
        // no mutable decision at all.
        let inst = trace.insts.get(idx)?;
        if !inst.is_sampling()
            || trace.insts[..idx].iter().any(|i| matches!(i, Inst::EnterPostproc))
        {
            return None;
        }
        let mut first: Option<usize> = None;
        let mut multiple = false;
        for (i, e) in self.entries.iter().enumerate() {
            if e.weight > 0.0 && e.mutator.applies(inst) {
                if first.is_none() {
                    first = Some(i);
                } else {
                    multiple = true;
                    break;
                }
            }
        }
        let pick = match first {
            None => return None,
            Some(i) if !multiple => i,
            Some(_) => {
                // Rare path (several mutators claim one decision kind):
                // collect for the weighted draw.
                let applicable: Vec<usize> = self
                    .entries
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.weight > 0.0 && e.mutator.applies(inst))
                    .map(|(i, _)| i)
                    .collect();
                let weights: Vec<f64> = applicable.iter().map(|&i| self.entries[i].weight).collect();
                applicable[rng.sample_weighted(&weights)]
            }
        };
        let e = &self.entries[pick];
        e.proposed.inc();
        e.mutator.propose(trace, idx, prog, rng)
    }

    /// Mutate one sampling decision of `trace` and validate by replay
    /// plus the caller's `validate` hook (the context's postprocessors).
    /// Returns the new schedule (with its updated trace), or `None` if no
    /// proposal was possible or validation rejected every attempt.
    pub fn mutate_with<F>(
        &self,
        trace: &Trace,
        prog: &Program,
        rng: &mut Rng,
        seed: u64,
        validate: F,
    ) -> Option<Schedule>
    where
        F: Fn(&Schedule) -> bool,
    {
        let sampling = trace.sampling_indices();
        self.mutate_with_sampling(trace, &sampling, prog, rng, seed, validate)
            .map(|(sch, _)| sch)
    }

    /// Hot-path variant of [`MutatorSet::mutate_with`]: the caller
    /// supplies the pre-postproc sampling indices — memoized on an
    /// [`crate::trace::InternedTrace`] in the search — so the proposal
    /// loop does not rescan the whole trace per candidate per
    /// generation. Returns the mutated instruction index alongside the
    /// schedule so the caller can re-intern just that one node.
    /// RNG-for-RNG identical to `mutate_with` whenever `sampling ==
    /// trace.sampling_indices()` (pinned by the invariants suite).
    pub fn mutate_with_sampling<F>(
        &self,
        trace: &Trace,
        sampling: &[usize],
        prog: &Program,
        rng: &mut Rng,
        seed: u64,
        validate: F,
    ) -> Option<(Schedule, usize)>
    where
        F: Fn(&Schedule) -> bool,
    {
        if sampling.is_empty() {
            return None;
        }
        // Try a few instruction picks before giving up.
        for _ in 0..4 {
            let idx = *rng.choose(sampling);
            let Some(decision) = self.propose_for(trace, idx, prog, rng) else {
                continue;
            };
            let mut overrides = HashMap::new();
            overrides.insert(idx, decision);
            // Validation: replay with the override; off-support decisions fail.
            if let Ok(sch) = replay_with_decisions(trace, prog, seed, &overrides) {
                if validate(&sch) {
                    return Some((sch, idx));
                }
            }
        }
        None
    }
}

impl Default for MutatorSet {
    fn default() -> Self {
        MutatorSet::builtin_default()
    }
}

/// Convenience free function with the pre-registry signature: the default
/// mutator set plus program-integrity validation. Benches and property
/// tests use this; the search itself goes through
/// [`crate::ctx::TuneContext::mutate`] so custom mutators and
/// postprocessors take effect. The set is built once (`OnceLock`) so
/// per-call cost matches the old free function — this IS the mutation
/// row of `benches/hot_path.rs`, which must not measure set
/// construction. (The shared set's proposal counters aggregate across
/// all callers; they are diagnostics and nothing reads them here.)
pub fn mutate(trace: &Trace, prog: &Program, rng: &mut Rng, seed: u64) -> Option<Schedule> {
    static DEFAULT_SET: std::sync::OnceLock<MutatorSet> = std::sync::OnceLock::new();
    DEFAULT_SET
        .get_or_init(MutatorSet::builtin_default)
        .mutate_with(trace, prog, rng, seed, |sch| sch.prog.check_integrity().is_ok())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ctx::TuneContext;
    use crate::schedule::Schedule;
    use crate::sim::Target;
    use crate::tir::structural_hash;
    use crate::trace::FactorArg;
    use crate::workloads;

    fn tiled_matmul(seed: u64) -> (Program, Schedule) {
        let prog = workloads::matmul(1, 64, 64, 64);
        let mut s = Schedule::new(prog.clone(), seed);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let t = s.sample_perfect_tile(loops[1], 2, 0).unwrap();
        s.split(loops[1], &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])
            .unwrap();
        (prog, s)
    }

    #[test]
    fn tile_transfer_preserves_product() {
        let (prog, s) = tiled_matmul(5);
        let mut rng = Rng::seed_from_u64(1);
        let idx = s
            .trace
            .sampling_indices()
            .first()
            .copied()
            .expect("tiled fixture records a sampling instruction");
        let old = match &s.trace.insts[idx] {
            Inst::SamplePerfectTile { decision, .. } => decision.clone(),
            _ => panic!(),
        };
        let m = TileTransfer;
        assert!(m.applies(&s.trace.insts[idx]));
        for _ in 0..10 {
            if let Some(Decision::Tile(new)) = m.propose(&s.trace, idx, &prog, &mut rng) {
                assert_eq!(new.iter().product::<i64>(), old.iter().product::<i64>());
                assert_ne!(new, old);
            }
        }
    }

    #[test]
    fn mutate_produces_structurally_different_valid_schedule() {
        let (prog, s) = tiled_matmul(5);
        let mut rng = Rng::seed_from_u64(2);
        let mut seen_diff = false;
        for i in 0..10 {
            if let Some(m) = mutate(&s.trace, &prog, &mut rng, i) {
                m.prog.check_integrity().unwrap();
                if structural_hash(&m.prog) != structural_hash(&s.prog) {
                    seen_diff = true;
                }
            }
        }
        assert!(seen_diff);
    }

    #[test]
    fn mutate_composed_space_traces() {
        // Mutations over realistic traces from the space generator.
        let prog = workloads::fused_dense(64, 128, 64);
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let states = ctx.generate(&prog, 11);
        let mut rng = Rng::seed_from_u64(3);
        let mut successes = 0;
        for s in &states {
            for i in 0..8 {
                if let Some(m) = ctx.mutate(&s.trace, &prog, &mut rng, i) {
                    m.prog.check_integrity().unwrap();
                    successes += 1;
                }
            }
        }
        assert!(successes > 0, "no successful mutations");
    }

    #[test]
    fn empty_trace_cannot_mutate() {
        let prog = workloads::matmul(1, 16, 16, 16);
        let t = Trace::default();
        let mut rng = Rng::seed_from_u64(0);
        assert!(mutate(&t, &prog, &mut rng, 0).is_none());
    }

    #[test]
    fn postproc_only_sampling_trace_skips_instead_of_panicking() {
        // Regression: a trace whose only sampling instructions sit after
        // the `EnterPostproc` marker has no mutable decision. Every
        // entry point — sampling_indices, mutate, and a hostile direct
        // propose_for on the postproc (or out-of-range) index — must
        // skip, not panic.
        let prog = workloads::matmul(1, 16, 16, 16);
        let t = Trace {
            insts: vec![
                Inst::GetBlock { name: "matmul".into(), out: 0 },
                Inst::EnterPostproc,
                Inst::SampleCategorical {
                    candidates: vec![0, 16, 64],
                    probs: vec![0.25, 0.5, 0.25],
                    out: 1,
                    decision: 1,
                },
            ],
        };
        assert!(t.sampling_indices().is_empty());
        let mut rng = Rng::seed_from_u64(21);
        assert!(mutate(&t, &prog, &mut rng, 0).is_none());
        let set = MutatorSet::builtin_default();
        assert!(set.mutate_with(&t, &prog, &mut rng, 0, |_| true).is_none());
        // Direct dispatch on the post-postproc sampling index: skipped.
        assert!(set.propose_for(&t, 2, &prog, &mut rng).is_none());
        // Non-sampling and out-of-range indices: also skipped.
        assert!(set.propose_for(&t, 0, &prog, &mut rng).is_none());
        assert!(set.propose_for(&t, 99, &prog, &mut rng).is_none());
        // The individual mutators are just as defensive about bad indices.
        assert!(TileTransfer.propose(&t, 99, &prog, &mut rng).is_none());
        assert!(CategoricalRedraw.propose(&t, 99, &prog, &mut rng).is_none());
        assert!(ComputeLocationMove.propose(&t, 99, &prog, &mut rng).is_none());
    }

    #[test]
    fn mutate_with_sampling_matches_mutate_with_rng_for_rng() {
        // The memoized-sampling hot path must draw the identical RNG
        // sequence as the rescanning path: same proposals, same
        // schedules, same RNG state afterwards.
        let prog = workloads::fused_dense(64, 128, 64);
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let states = ctx.generate(&prog, 6);
        let set = MutatorSet::builtin_default();
        for s in &states {
            let sampling = s.trace.sampling_indices();
            let mut rng_a = Rng::seed_from_u64(31);
            let mut rng_b = Rng::seed_from_u64(31);
            for i in 0..6 {
                let a = set.mutate_with(&s.trace, &prog, &mut rng_a, i, |_| true);
                let b = set.mutate_with_sampling(&s.trace, &sampling, &prog, &mut rng_b, i, |_| true);
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some((y, idx))) => {
                        assert_eq!(structural_hash(&x.prog), structural_hash(&y.prog));
                        assert!(sampling.contains(&idx), "mutated index {idx} not a sampling index");
                    }
                    (x, y) => panic!("diverged: {:?} vs {:?}", x.is_some(), y.is_some()),
                }
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG state diverged");
        }
    }

    #[test]
    fn default_set_matches_free_function_rng_for_rng() {
        // The trait-ified default set must draw the identical RNG
        // sequence as the convenience free function (itself the old
        // behaviour): same seed, same proposals, same schedules.
        let prog = workloads::fused_dense(64, 128, 64);
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let states = ctx.generate(&prog, 4);
        let set = MutatorSet::builtin_default();
        for s in &states {
            let mut rng_a = Rng::seed_from_u64(9);
            let mut rng_b = Rng::seed_from_u64(9);
            for i in 0..6 {
                let a = mutate(&s.trace, &prog, &mut rng_a, i);
                let b = set.mutate_with(&s.trace, &prog, &mut rng_b, i, |sch| {
                    sch.prog.check_integrity().is_ok()
                });
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(structural_hash(&x.prog), structural_hash(&y.prog));
                    }
                    (x, y) => panic!("diverged: {:?} vs {:?}", x.is_some(), y.is_some()),
                }
            }
        }
    }

    #[test]
    fn zero_weight_disables_a_mutator() {
        let (prog, s) = tiled_matmul(7);
        let mut set = MutatorSet::new();
        set.push(Box::new(TileTransfer), 0.0);
        let mut rng = Rng::seed_from_u64(3);
        for i in 0..8 {
            assert!(set
                .mutate_with(&s.trace, &prog, &mut rng, i, |_| true)
                .is_none());
        }
        assert_eq!(set.stats()[0].2, 0, "disabled mutator must never propose");
    }

    #[test]
    fn labels_and_stats_render() {
        let mut set = MutatorSet::builtin_default();
        set.push(Box::new(TileTransfer), 2.5);
        assert_eq!(
            set.label(),
            "tile-transfer,categorical-redraw,compute-location-move,tile-transfer:2.5"
        );
        assert_eq!(set.stats().len(), 4);
    }
}
