//! Postprocessors: named, ordered validity checks a candidate schedule
//! must pass after mutation, before it may enter the population.
//!
//! Before the registry redesign these were implicit fixups buried in the
//! search loop (a bare `check_integrity` call after replay). Naming them
//! makes the pipeline extensible — a custom backend can demand its own
//! invariants — and inspectable: `tune --explain-space` reports per-
//! postproc pass/reject counts.
//!
//! The default set is exactly `verify-integrity`, which reproduces the
//! pre-redesign search behaviour bit-for-bit. `sim-validity` is available
//! by name for callers that prefer rejecting target-invalid candidates
//! before spending a measurement on them (a *policy change*: the default
//! search measures them and records the failure for cross-session dedup).

use crate::schedule::Schedule;
use crate::sim::Target;

/// A named schedule check. `Ok(())` = the candidate passes; `Err` carries
/// a human-readable reason for the diagnostics. Checks must be pure —
/// they run on every mutation proposal inside the deterministic search.
pub trait Postproc: Send + Sync {
    fn name(&self) -> &str;
    /// One-line human description for `--explain-space`.
    fn describe(&self) -> String {
        String::new()
    }
    fn check(&self, sch: &Schedule, target: &Target) -> Result<(), String>;
}

/// Structural program integrity (the former implicit `check_integrity`
/// call in the mutation-validation path).
pub struct VerifyIntegrity;

impl Postproc for VerifyIntegrity {
    fn name(&self) -> &str {
        "verify-integrity"
    }

    fn describe(&self) -> String {
        "reject candidates whose program fails the structural integrity check".into()
    }

    fn check(&self, sch: &Schedule, _target: &Target) -> Result<(), String> {
        sch.prog.check_integrity().map_err(|e| format!("{e}"))
    }
}

/// Reject candidates the hardware simulator deems invalid on the target
/// (scratchpad overflow, thread limits). NOT in the default set: the
/// default search measures such candidates and records the failure so
/// warm starts skip them — filtering here trades that dedup record for a
/// cheaper round.
pub struct SimValidity;

impl Postproc for SimValidity {
    fn name(&self) -> &str {
        "sim-validity"
    }

    fn describe(&self) -> String {
        "reject candidates invalid on the simulated target before measuring them".into()
    }

    fn check(&self, sch: &Schedule, target: &Target) -> Result<(), String> {
        crate::sim::simulate(&sch.prog, target).map(|_| ()).map_err(|e| format!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn verify_integrity_passes_valid_schedules() {
        let prog = workloads::matmul(1, 32, 32, 32);
        let sch = Schedule::new(prog, 0);
        assert!(VerifyIntegrity.check(&sch, &Target::cpu_avx512()).is_ok());
        assert_eq!(VerifyIntegrity.name(), "verify-integrity");
    }

    #[test]
    fn sim_validity_rejects_overbound_gpu_kernels() {
        // 4096 threads on one loop -> invalid on the GPU model.
        let mut s = Schedule::new(workloads::matmul(1, 4096, 16, 16), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.bind(loops[1], "threadIdx.x").unwrap();
        assert!(SimValidity.check(&s, &Target::gpu()).is_err());
        // But integrity still holds — the two checks are independent.
        assert!(VerifyIntegrity.check(&s, &Target::gpu()).is_ok());
        // And the same schedule is fine on a valid-size workload.
        let ok = Schedule::new(workloads::matmul(1, 32, 32, 32), 0);
        assert!(SimValidity.check(&ok, &Target::gpu()).is_ok());
    }
}
