//! Named component registries and per-target defaults-as-data.
//!
//! This is the inversion at the heart of the `TuneContext` redesign: the
//! search-space composition is no longer a `match target.kind` baked into
//! the space module — it is a *name list* resolved against a registry of
//! factories. The per-target default lists below are plain data; a custom
//! rule/mutator/postproc registers under a name through
//! [`RegistrySet`] and is then addressable from `--rules`/`--mutators`/
//! `--postprocs` specs exactly like the built-ins.
//!
//! Spec grammar (comma-separated, whitespace-tolerant):
//! - rules:     `default`, `default-tc`, or names (`auto-inline,mlt-cpu,…`);
//!   `default` tokens splice the target's default list in place.
//! - mutators:  `default` or `name[:weight]` items (`tile-transfer:2`).
//! - postprocs: `default` or names (`verify-integrity,sim-validity`).

use std::sync::Arc;

use crate::ctx::mutators::{CategoricalRedraw, ComputeLocationMove, Mutator, MutatorSet, TileTransfer};
use crate::ctx::postproc::{Postproc, SimValidity, VerifyIntegrity};
use crate::sim::{Target, TargetKind};
use crate::space::{
    AddRfactor, AutoInline, CrossThreadReduction, LayoutRewrite, MultiLevelTiling,
    ParallelVectorizeUnroll, RandomComputeLocation, ScheduleRule, ThreadBind, UseTensorCore,
};

/// Per-target default rule lists — the Figure 5 generic composition,
/// expressed as data instead of `match` arms. `multi-level-tiling`
/// resolves to the CPU or GPU tiling structure via its factory.
pub const DEFAULT_RULES_CPU: &[&str] = &[
    "auto-inline",
    "multi-level-tiling",
    "add-rfactor",
    "random-compute-location",
    "parallel-vectorize-unroll",
];

/// GPU counterpart of [`DEFAULT_RULES_CPU`].
pub const DEFAULT_RULES_GPU: &[&str] = &[
    "auto-inline",
    "multi-level-tiling",
    "cross-thread-reduction",
    "random-compute-location",
    "thread-bind",
];

/// Default mutator names (one per decision kind, weight 1).
pub const DEFAULT_MUTATORS: &[&str] =
    &["tile-transfer", "categorical-redraw", "compute-location-move"];

/// Default postprocessor names (the pre-redesign implicit pipeline).
pub const DEFAULT_POSTPROCS: &[&str] = &["verify-integrity"];

/// The default rule names for a target kind.
pub fn default_rule_names(kind: TargetKind) -> &'static [&'static str] {
    match kind {
        TargetKind::Cpu => DEFAULT_RULES_CPU,
        TargetKind::Gpu => DEFAULT_RULES_GPU,
    }
}

/// A name -> factory table for one component family. `T` is the
/// object-safe trait (`dyn ScheduleRule`, `dyn Mutator`, `dyn Postproc`);
/// factories take the target so one name can resolve target-adaptively
/// (e.g. `multi-level-tiling`). Registration is last-wins, so a custom
/// build can shadow a built-in under the same name.
pub struct Registry<T: ?Sized> {
    entries: Vec<(String, Arc<dyn Fn(&Target) -> Box<T> + Send + Sync>)>,
}

impl<T: ?Sized> Registry<T> {
    pub fn new() -> Registry<T> {
        Registry { entries: Vec::new() }
    }

    /// Register (or shadow) a factory under `name`.
    pub fn register<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&Target) -> Box<T> + Send + Sync + 'static,
    {
        if let Some(entry) = self.entries.iter_mut().find(|(n, _)| n == name) {
            entry.1 = Arc::new(factory);
        } else {
            self.entries.push((name.to_string(), Arc::new(factory)));
        }
    }

    /// Instantiate the component registered under `name` for `target`.
    pub fn make(&self, name: &str, target: &Target) -> Option<Box<T>> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, f)| f(target))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|(n, _)| n == name)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }
}

impl<T: ?Sized> Default for Registry<T> {
    fn default() -> Self {
        Registry::new()
    }
}

/// The three component registries a [`crate::ctx::TuneContext`] resolves
/// specs against. [`RegistrySet::builtin`] carries every in-tree
/// component; extend it with `set.rules.register(...)` (and friends) to
/// make custom components addressable by name.
pub struct RegistrySet {
    pub rules: Registry<dyn ScheduleRule>,
    pub mutators: Registry<dyn Mutator>,
    pub postprocs: Registry<dyn Postproc>,
}

impl RegistrySet {
    /// All built-in rules, mutators, and postprocessors.
    pub fn builtin() -> RegistrySet {
        let mut rules: Registry<dyn ScheduleRule> = Registry::new();
        rules.register("auto-inline", |_| Box::new(AutoInline::new()) as Box<dyn ScheduleRule>);
        rules.register("multi-level-tiling", |t: &Target| -> Box<dyn ScheduleRule> {
            match t.kind {
                TargetKind::Cpu => Box::new(MultiLevelTiling::cpu()),
                TargetKind::Gpu => Box::new(MultiLevelTiling::gpu()),
            }
        });
        rules.register("mlt-cpu", |_| Box::new(MultiLevelTiling::cpu()) as Box<dyn ScheduleRule>);
        rules.register("mlt-gpu", |_| Box::new(MultiLevelTiling::gpu()) as Box<dyn ScheduleRule>);
        rules.register("add-rfactor", |_| Box::new(AddRfactor::new()) as Box<dyn ScheduleRule>);
        rules.register("cross-thread-reduction", |_| Box::new(CrossThreadReduction::new()) as Box<dyn ScheduleRule>);
        rules.register("random-compute-location", |_| Box::new(RandomComputeLocation::new()) as Box<dyn ScheduleRule>);
        rules.register("parallel-vectorize-unroll", |_| Box::new(ParallelVectorizeUnroll::new()) as Box<dyn ScheduleRule>);
        rules.register("thread-bind", |_| Box::new(ThreadBind::new()) as Box<dyn ScheduleRule>);
        rules.register("use-tensor-core", |_| Box::new(UseTensorCore::wmma()) as Box<dyn ScheduleRule>);
        rules.register("use-tensor-core-mxu", |_| Box::new(UseTensorCore::mxu()) as Box<dyn ScheduleRule>);
        rules.register("layout-rewrite", |_| Box::new(LayoutRewrite::new()) as Box<dyn ScheduleRule>);

        let mut mutators: Registry<dyn Mutator> = Registry::new();
        mutators.register("tile-transfer", |_| Box::new(TileTransfer) as Box<dyn Mutator>);
        mutators.register("categorical-redraw", |_| Box::new(CategoricalRedraw) as Box<dyn Mutator>);
        mutators.register("compute-location-move", |_| Box::new(ComputeLocationMove) as Box<dyn Mutator>);

        let mut postprocs: Registry<dyn Postproc> = Registry::new();
        postprocs.register("verify-integrity", |_| Box::new(VerifyIntegrity) as Box<dyn Postproc>);
        postprocs.register("sim-validity", |_| Box::new(SimValidity) as Box<dyn Postproc>);

        RegistrySet { rules, mutators, postprocs }
    }
}

/// Names of every builtin rule, computed once (the builtin set is
/// immutable at runtime). [`crate::ctx::TuneContext`] seeds its
/// transfer-compatibility vocabulary from this without paying a full
/// [`RegistrySet::builtin`] construction per context.
pub fn builtin_rule_names() -> &'static [String] {
    static NAMES: std::sync::OnceLock<Vec<String>> = std::sync::OnceLock::new();
    NAMES.get_or_init(|| {
        RegistrySet::builtin().rules.names().iter().map(|s| s.to_string()).collect()
    })
}

/// Split a comma-separated spec into trimmed, non-empty tokens.
fn tokens(spec: &str) -> Vec<&str> {
    spec.split(',').map(str::trim).filter(|t| !t.is_empty()).collect()
}

/// Expand a rule spec to concrete registry names: `default` splices the
/// target's default list, `default-tc` the same with `use-tensor-core`
/// inserted after `auto-inline` (the Figure 10 composition).
pub fn expand_rule_spec(spec: &str, target: &Target) -> Vec<String> {
    let mut out = Vec::new();
    for tok in tokens(spec) {
        match tok {
            "default" => {
                out.extend(default_rule_names(target.kind).iter().map(|s| s.to_string()));
            }
            "default-tc" => {
                for (i, name) in default_rule_names(target.kind).iter().enumerate() {
                    out.push(name.to_string());
                    if i == 0 {
                        out.push("use-tensor-core".to_string());
                    }
                }
            }
            other => out.push(other.to_string()),
        }
    }
    out
}

/// Resolve a rule spec to instances. Unknown names error with the list of
/// registered names (a CLI typo must not silently shrink the space).
pub fn parse_rules(reg: &RegistrySet, spec: &str, target: &Target) -> Result<Vec<Box<dyn ScheduleRule>>, String> {
    let names = expand_rule_spec(spec, target);
    if names.is_empty() {
        return Err("empty rule spec".to_string());
    }
    // Duplicates are almost always a spec mistake ("auto-inline,default"
    // meant as a reorder): each rule already applies to every block once
    // per pass, so applying it twice compounds silently. Fail fast, like
    // unknown names.
    for (i, n) in names.iter().enumerate() {
        if names[..i].contains(n) {
            return Err(format!("rule {n:?} appears twice in spec {spec:?} (after default expansion)"));
        }
    }
    names
        .iter()
        .map(|n| {
            reg.rules.make(n, target).ok_or_else(|| {
                format!("unknown rule {n:?}; registered: {}", reg.rules.names().join(", "))
            })
        })
        .collect()
}

/// Resolve a mutator spec (`default` or `name[:weight]` items) to a
/// weighted [`MutatorSet`].
pub fn parse_mutators(reg: &RegistrySet, spec: &str, target: &Target) -> Result<MutatorSet, String> {
    let mut set = MutatorSet::new();
    for tok in tokens(spec) {
        if tok == "default" {
            for name in DEFAULT_MUTATORS {
                let m = reg
                    .mutators
                    .make(name, target)
                    .ok_or_else(|| format!("builtin mutator {name:?} missing from registry"))?;
                set.push(m, 1.0);
            }
            continue;
        }
        let (name, weight) = match tok.split_once(':') {
            Some((n, w)) => {
                let w: f64 = w
                    .trim()
                    .parse()
                    .map_err(|_| format!("mutator weight {w:?} in {tok:?} is not a number"))?;
                if !(w.is_finite() && w >= 0.0) {
                    return Err(format!("mutator weight in {tok:?} must be finite and >= 0"));
                }
                (n.trim(), w)
            }
            None => (tok, 1.0),
        };
        let m = reg.mutators.make(name, target).ok_or_else(|| {
            format!("unknown mutator {name:?}; registered: {}", reg.mutators.names().join(", "))
        })?;
        set.push(m, weight);
    }
    if set.is_empty() {
        return Err("empty mutator spec".to_string());
    }
    if set.stats().iter().all(|(_, w, _)| *w <= 0.0) {
        // Weight 0 disables a mutator; all-zero would silently disable
        // mutation entirely — the same "typo must not silently shrink
        // the search" failure parse_rules guards against.
        return Err("mutator spec disables every mutator (all weights are 0)".to_string());
    }
    Ok(set)
}

/// Resolve a postproc spec to an ordered pipeline.
pub fn parse_postprocs(reg: &RegistrySet, spec: &str, target: &Target) -> Result<Vec<Box<dyn Postproc>>, String> {
    let mut out: Vec<Box<dyn Postproc>> = Vec::new();
    for tok in tokens(spec) {
        if tok == "default" {
            for name in DEFAULT_POSTPROCS {
                let p = reg
                    .postprocs
                    .make(name, target)
                    .ok_or_else(|| format!("builtin postproc {name:?} missing from registry"))?;
                out.push(p);
            }
            continue;
        }
        let p = reg.postprocs.make(tok, target).ok_or_else(|| {
            format!("unknown postproc {tok:?}; registered: {}", reg.postprocs.names().join(", "))
        })?;
        out.push(p);
    }
    if out.is_empty() {
        return Err("empty postproc spec".to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_makes_every_default_rule() {
        let reg = RegistrySet::builtin();
        for target in [Target::cpu_avx512(), Target::gpu()] {
            for name in default_rule_names(target.kind) {
                let r = reg.rules.make(name, &target).unwrap_or_else(|| panic!("missing {name}"));
                assert!(!r.name().is_empty());
            }
        }
    }

    #[test]
    fn default_spec_expands_per_target() {
        let cpu = expand_rule_spec("default", &Target::cpu_avx512());
        assert_eq!(cpu, DEFAULT_RULES_CPU.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        let gpu = expand_rule_spec("default", &Target::gpu());
        assert!(gpu.contains(&"thread-bind".to_string()));
        // default-tc splices use-tensor-core right after auto-inline.
        let tc = expand_rule_spec("default-tc", &Target::gpu());
        assert_eq!(tc[0], "auto-inline");
        assert_eq!(tc[1], "use-tensor-core");
        assert_eq!(tc.len(), gpu.len() + 1);
        // Mixed specs splice defaults in place.
        let mixed = expand_rule_spec(" thread-bind , default ", &Target::cpu_avx512());
        assert_eq!(mixed[0], "thread-bind");
        assert_eq!(mixed.len(), DEFAULT_RULES_CPU.len() + 1);
    }

    #[test]
    fn unknown_names_error_with_suggestions() {
        let reg = RegistrySet::builtin();
        let t = Target::cpu_avx512();
        let err = parse_rules(&reg, "auto-inline,frobnicate", &t).unwrap_err();
        assert!(err.contains("frobnicate") && err.contains("auto-inline"), "{err}");
        assert!(parse_mutators(&reg, "nope", &t).is_err());
        assert!(parse_postprocs(&reg, "nope", &t).is_err());
        assert!(parse_rules(&reg, "", &t).is_err());
        // Duplicates (directly or via default expansion) fail fast too.
        assert!(parse_rules(&reg, "auto-inline,default", &t).is_err());
        assert!(parse_rules(&reg, "default,default", &t).is_err());
    }

    #[test]
    fn mutator_weights_parse_and_validate() {
        let reg = RegistrySet::builtin();
        let t = Target::cpu_avx512();
        let set = parse_mutators(&reg, "tile-transfer:2.5,categorical-redraw", &t).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.stats()[0].1, 2.5);
        assert_eq!(set.stats()[1].1, 1.0);
        assert!(parse_mutators(&reg, "tile-transfer:abc", &t).is_err());
        assert!(parse_mutators(&reg, "tile-transfer:-1", &t).is_err());
        // All-zero weights would disable mutation outright: rejected.
        assert!(parse_mutators(&reg, "tile-transfer:0,categorical-redraw:0", &t).is_err());
        // A zero weight among live ones stays legal (selective disable).
        assert!(parse_mutators(&reg, "tile-transfer:0,categorical-redraw", &t).is_ok());
    }

    #[test]
    fn registration_is_last_wins() {
        let mut reg = RegistrySet::builtin();
        reg.rules.register("auto-inline", |_| {
            Box::new(AutoInline { into_producer: false }) as Box<dyn ScheduleRule>
        });
        let t = Target::cpu_avx512();
        let r = reg.rules.make("auto-inline", &t).unwrap();
        assert_eq!(r.params(), vec![("into-producer".to_string(), "false".to_string())]);
        // Name count unchanged (shadowed, not duplicated).
        assert_eq!(reg.rules.names().iter().filter(|&&n| n == "auto-inline").count(), 1);
    }
}
