//! `TuneContext`: the pluggable component bundle the search runs against.
//!
//! MetaSchedule's headline claim is that domain experts *modularly grow*
//! the search space. This module is that claim's API surface: a
//! [`TuneContext`] owns four component families behind object-safe
//! traits —
//!
//! - [`crate::space::ScheduleRule`] (space construction, §3.2),
//! - [`crate::space::SpaceGenerator`] (the composer, built from a *named*
//!   rule set resolved against [`registry::RegistrySet`]),
//! - [`Mutator`] (per-decision-kind trace mutation with configurable
//!   weights, §4),
//! - [`Postproc`] (named, ordered candidate validity checks),
//!
//! — and the search ([`crate::search`]) consumes only this bundle: no
//! concrete rule type is named anywhere inside the search layer, which is
//! what makes a custom rule registered purely through the public API a
//! first-class citizen of tuning, diagnostics (`--explain-space`), and
//! record provenance (the rule-set label stamped into every
//! [`crate::db::TuningRecord`]).
//!
//! The default context ([`TuneContext::generic`]) is byte-identical to
//! the pre-registry hardcoded composition: same rules in the same order,
//! same RNG draw sequence in mutation, same integrity check gating
//! mutation validation. (Postprocs additionally gate fresh-sample and
//! elite admission into the population — with the default
//! `verify-integrity` pipeline that accepts every successful replay, so
//! default behaviour is unchanged; an opt-in `sim-validity` really does
//! filter before measurement.) Pinned by the equivalence suite in
//! `rust/tests/space_registry.rs`.

pub mod mutators;
pub mod postproc;
pub mod registry;

pub use mutators::{mutate, CategoricalRedraw, ComputeLocationMove, Mutator, MutatorSet, TileTransfer};
pub use postproc::{Postproc, SimValidity, VerifyIntegrity};
pub use registry::{
    builtin_rule_names, default_rule_names, expand_rule_spec, parse_mutators, parse_postprocs,
    parse_rules, Registry, RegistrySet, DEFAULT_MUTATORS, DEFAULT_POSTPROCS, DEFAULT_RULES_CPU,
    DEFAULT_RULES_GPU,
};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::cost_model::{FeatKey, FeatureCache};
use crate::schedule::Schedule;
use crate::sim::Target;
use crate::space::{ScheduleRule, SpaceGenerator};
use crate::telemetry::{maybe_span, sanitize_name, Counter, Metrics, Span, TraceSink};
use crate::tir::Program;
use crate::trace::{InternArena, InternedTrace, Trace};
use crate::util::rng::Rng;

/// Pass/reject counters for one postprocessor (diagnostics only),
/// registered in the context's metrics registry as
/// `postproc_<name>_{pass,reject}_total`.
struct PostprocStat {
    pass: Arc<Counter>,
    reject: Arc<Counter>,
    notes: Mutex<Vec<String>>,
}

impl PostprocStat {
    fn new(name: &str, metrics: &Metrics) -> PostprocStat {
        let frag = sanitize_name(name);
        PostprocStat {
            pass: metrics.counter_unique(
                &format!("postproc_{frag}_pass_total"),
                "candidates this postprocessor accepted",
            ),
            reject: metrics.counter_unique(
                &format!("postproc_{frag}_reject_total"),
                "candidates this postprocessor rejected",
            ),
            notes: Mutex::new(Vec::new()),
        }
    }
}

/// The tuning context: target + space generator + mutators + postprocs,
/// plus the provenance label and diagnostic counters. Shared immutably
/// (`&TuneContext`) across the search's worker threads; all counters are
/// atomics, so recording diagnostics never perturbs determinism.
pub struct TuneContext {
    target: Target,
    space: SpaceGenerator,
    mutators: MutatorSet,
    postprocs: Vec<Box<dyn Postproc>>,
    postproc_stats: Vec<PostprocStat>,
    mutations_accepted: Arc<Counter>,
    /// This context's metrics registry — the space generator's, adopted,
    /// so rule, postproc, and mutation counters all live in one place.
    /// Per-context (not process-global): `--explain-space` reports exact
    /// counts for *this* context.
    metrics: Arc<Metrics>,
    /// Optional trace sink (`tune --profile`); search layers open spans
    /// through [`TuneContext::span`], which is free when unset.
    trace_sink: OnceLock<Arc<TraceSink>>,
    /// Hash-consing arena for this context's traces: canonical id chains
    /// back the search's dedup set, the memoized sampling indices the
    /// mutation loop draws from, and the feature-cache keys.
    intern: InternArena,
    /// Per-canonical-trace feature vectors (see
    /// [`crate::cost_model::FeatureCache`]). Observation-equivalent: the
    /// search behaves byte-identically with it on or off.
    feature_cache: FeatureCache,
    /// `tune --no-feature-cache` escape hatch (and the CI byte-diff
    /// toggle). Disabling only forfeits the speedup.
    feature_cache_enabled: AtomicBool,
    rule_set: String,
    /// Rule names this context can vouch for when judging donor
    /// provenance: the resolving registry's full name list when the
    /// context came from specs, plus this context's own instance names.
    /// See [`TuneContext::transfer_compatible`].
    known_rules: Vec<String>,
}

/// Parse the rule-name list out of a canonical rule-set label
/// (`"name1,name2 #digest"` — see [`SpaceGenerator::rule_set`]). The
/// digest suffix is ignored; an empty label yields no names.
pub fn rule_set_names(label: &str) -> Vec<&str> {
    let names = label.split_once(" #").map(|(n, _)| n).unwrap_or(label);
    names.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
}

impl TuneContext {
    /// Assemble a context from concrete components. The rule-set label
    /// is canonical — the rule names joined with `,` plus a digest of
    /// their `(name, params)` sequence (see
    /// [`SpaceGenerator::rule_set`]) — so two contexts with the same
    /// rules share provenance no matter how they were spelled, and two
    /// differently-configured spaces never collide.
    pub fn new(
        rules: Vec<Box<dyn ScheduleRule>>,
        mutators: MutatorSet,
        postprocs: Vec<Box<dyn Postproc>>,
        target: Target,
    ) -> TuneContext {
        let space = SpaceGenerator::new(rules, target.clone());
        let rule_set = space.rule_set();
        let metrics = Arc::clone(space.metrics());
        let postproc_stats = postprocs.iter().map(|p| PostprocStat::new(p.name(), &metrics)).collect();
        let mutations_accepted =
            metrics.counter("ctx_mutations_accepted_total", "trace mutations that validated");
        let intern = InternArena::with_counters(
            metrics.counter("intern_hits_total", "trace instructions resolved to an existing interned node"),
            metrics.counter("intern_nodes_total", "distinct trace instructions interned"),
        );
        let feature_cache = FeatureCache::new(&metrics);
        // Every builtin name is always vouched for; contexts resolved
        // through `from_specs_in` extend this with their registry's
        // custom names.
        let mut known_rules: Vec<String> = registry::builtin_rule_names().to_vec();
        for r in space.rules() {
            if !known_rules.iter().any(|k| k == r.name()) {
                known_rules.push(r.name().to_string());
            }
        }
        TuneContext {
            target,
            space,
            mutators,
            postprocs,
            postproc_stats,
            mutations_accepted,
            metrics,
            trace_sink: OnceLock::new(),
            intern,
            feature_cache,
            feature_cache_enabled: AtomicBool::new(true),
            rule_set,
            known_rules,
        }
    }

    /// The paper's generic per-target composition (Figure 5 right, minus
    /// hardware-specific rules), resolved from the registry defaults.
    pub fn generic(target: Target) -> TuneContext {
        TuneContext::from_specs(target, "default", "default", "default")
            .expect("builtin default specs must resolve")
    }

    /// Generic composition plus the hardware-specific `Use-Tensor-Core`
    /// rule (Figure 5 right / Figure 10), inserted after `auto-inline` so
    /// it claims matmul-like blocks before generic tiling.
    pub fn with_tensor_core(target: Target) -> TuneContext {
        TuneContext::from_specs(target, "default-tc", "default", "default")
            .expect("builtin default-tc spec must resolve")
    }

    /// A context from explicit rule instances with default mutators and
    /// postprocessors (baselines and custom spaces use this).
    pub fn from_rules(rules: Vec<Box<dyn ScheduleRule>>, target: Target) -> TuneContext {
        let reg = RegistrySet::builtin();
        let mutators = parse_mutators(&reg, "default", &target).expect("builtin mutators");
        let postprocs = parse_postprocs(&reg, "default", &target).expect("builtin postprocs");
        TuneContext::new(rules, mutators, postprocs, target)
    }

    /// Parse `--rules`/`--mutators`/`--postprocs` specs against the
    /// built-in registry.
    pub fn from_specs(target: Target, rules: &str, mutators: &str, postprocs: &str) -> Result<TuneContext, String> {
        TuneContext::from_specs_in(&RegistrySet::builtin(), target, rules, mutators, postprocs)
    }

    /// Parse specs against a caller-extended registry — the public path
    /// by which a custom rule/mutator/postproc becomes addressable.
    pub fn from_specs_in(
        reg: &RegistrySet,
        target: Target,
        rules: &str,
        mutators: &str,
        postprocs: &str,
    ) -> Result<TuneContext, String> {
        let rules = parse_rules(reg, rules, &target)?;
        let mutators = parse_mutators(reg, mutators, &target)?;
        let postprocs = parse_postprocs(reg, postprocs, &target)?;
        let mut ctx = TuneContext::new(rules, mutators, postprocs, target);
        // The resolving registry's names (builtins + caller-registered
        // customs) are exactly the spaces this build can still express —
        // the vocabulary `transfer_compatible` judges donors against.
        for name in reg.rules.names() {
            if !ctx.known_rules.iter().any(|k| k == name) {
                ctx.known_rules.push(name.to_string());
            }
        }
        Ok(ctx)
    }

    pub fn target(&self) -> &Target {
        &self.target
    }

    pub fn space(&self) -> &SpaceGenerator {
        &self.space
    }

    pub fn mutators(&self) -> &MutatorSet {
        &self.mutators
    }

    /// This context's metrics registry: rule-diag, postproc, and
    /// mutation counters, addressable by name (see
    /// `docs/OBSERVABILITY.md` for the families).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Attach a trace sink (`tune --profile`). First call wins; later
    /// calls are ignored — a context profiles into at most one file.
    pub fn set_trace_sink(&self, sink: Arc<TraceSink>) {
        let _ = self.trace_sink.set(sink);
    }

    /// The attached trace sink, if profiling is on.
    pub fn trace_sink(&self) -> Option<&Arc<TraceSink>> {
        self.trace_sink.get()
    }

    /// Open a trace span against this context's sink — a disabled,
    /// free span when profiling is off.
    pub fn span(&self, name: impl Into<String>, cat: &'static str) -> Span {
        maybe_span(self.trace_sink.get(), name, cat)
    }

    /// Canonical rule-set label, stamped into tuning-record provenance.
    pub fn rule_set(&self) -> &str {
        &self.rule_set
    }

    /// Transfer-compatibility predicate over rule-set labels (the gate
    /// [`crate::transfer::TransferPool::collect`] applies before a donor
    /// record from another target may be injected as a prior): a donor's
    /// space is compatible when every rule name in its provenance label
    /// still resolves in the registry this context was built against.
    /// Pre-provenance records (empty label) are *not* compatible — a
    /// space we cannot even name is a space we cannot vouch for — and
    /// neither is a label naming a rule that no longer exists (e.g. a
    /// custom rule from a retired build). The donor's label does not
    /// have to equal this context's own: cross-target transfer is
    /// exactly the case where source and destination spaces differ.
    pub fn transfer_compatible(&self, donor_rule_set: &str) -> bool {
        if donor_rule_set.is_empty() {
            return false;
        }
        rule_set_names(donor_rule_set)
            .iter()
            .all(|n| self.known_rules.iter().any(|k| k == n))
    }

    /// Generate the design space for `prog` (see
    /// [`SpaceGenerator::generate`]).
    pub fn generate(&self, prog: &Program, seed: u64) -> Vec<Schedule> {
        self.space.generate(prog, seed)
    }

    /// This context's hash-consing arena (see [`crate::trace::intern`]).
    pub fn arena(&self) -> &InternArena {
        &self.intern
    }

    /// Intern a trace into this context's arena: canonical id chain plus
    /// memoized sampling indices.
    pub fn intern_trace(&self, trace: &Trace) -> InternedTrace {
        self.intern.intern(trace)
    }

    /// The per-canonical-trace feature cache, or `None` when disabled
    /// (`tune --no-feature-cache`). Callers fall back to the uncached
    /// cost-model paths on `None` — the results are identical either way.
    pub fn feature_cache(&self) -> Option<&FeatureCache> {
        if self.feature_cache_enabled.load(Ordering::Relaxed) {
            Some(&self.feature_cache)
        } else {
            None
        }
    }

    /// Toggle the feature cache (the `--no-feature-cache` escape hatch
    /// and the CI byte-diff smoke). Purely an execution knob: search
    /// results and database bytes are identical in both states.
    pub fn set_feature_cache_enabled(&self, enabled: bool) {
        self.feature_cache_enabled.store(enabled, Ordering::Relaxed);
    }

    /// The feature-cache key for an interned candidate of the workload
    /// whose *base* program hashes to `workload`.
    pub fn feat_key(&self, workload: u64, interned: &InternedTrace) -> FeatKey {
        FeatKey { workload, trace: interned.clone() }
    }

    /// Mutate one sampling decision of `trace`, validating candidates by
    /// replay plus this context's postprocessor pipeline.
    pub fn mutate(&self, trace: &Trace, prog: &Program, rng: &mut Rng, seed: u64) -> Option<Schedule> {
        let out = self.mutators.mutate_with(trace, prog, rng, seed, |sch| self.postprocess(sch));
        if out.is_some() {
            self.mutations_accepted.inc();
        }
        out
    }

    /// Interned-hot-path variant of [`TuneContext::mutate`]: `interned`
    /// is `trace`'s id chain in this context's arena. The mutation draws
    /// from the chain's memoized sampling indices (no per-proposal trace
    /// rescan) and the accepted child re-interns only its one rewritten
    /// decision node, sharing the rest with the parent. RNG-for-RNG
    /// identical to `mutate` — the determinism contract does not notice
    /// which path the search took.
    pub fn mutate_interned(
        &self,
        interned: &InternedTrace,
        trace: &Trace,
        prog: &Program,
        rng: &mut Rng,
        seed: u64,
    ) -> Option<(Schedule, InternedTrace)> {
        let (sch, idx) = self.mutators.mutate_with_sampling(
            trace,
            interned.sampling_indices(),
            prog,
            rng,
            seed,
            |sch| self.postprocess(sch),
        )?;
        self.mutations_accepted.inc();
        let child = self.intern.intern_mutated(interned, idx, &sch.trace);
        Some((sch, child))
    }

    /// Run the postprocessor pipeline in order; the first rejection wins.
    pub fn postprocess(&self, sch: &Schedule) -> bool {
        for (p, stat) in self.postprocs.iter().zip(&self.postproc_stats) {
            match p.check(sch, &self.target) {
                Ok(()) => {
                    stat.pass.inc();
                }
                Err(e) => {
                    stat.reject.inc();
                    let mut notes = stat.notes.lock().unwrap();
                    if notes.len() < 2 && !notes.contains(&e) {
                        notes.push(e);
                    }
                    return false;
                }
            }
        }
        true
    }

    /// Human-readable diagnostics: per-rule applicability/error counters,
    /// per-postproc pass/reject, per-mutator proposal counts — the
    /// `tune --explain-space` payload.
    pub fn explain(&self) -> String {
        let mut out = String::new();
        out.push_str("== search-space context ==\n");
        out.push_str(&format!("target: {}\n", self.target.name));
        out.push_str(&format!("rules: {}\n", self.rule_set));
        out.push_str(&format!("mutators: {}\n", self.mutators.label()));
        for (rule, diag) in self.space.rules().iter().zip(self.space.diag()) {
            out.push_str(&format!(
                "rule {}: applied {}, skipped {}, failed {}\n",
                diag.name(),
                diag.applied(),
                diag.skipped(),
                diag.failed()
            ));
            let desc = rule.describe();
            if !desc.is_empty() {
                out.push_str(&format!("    {desc}\n"));
            }
            for (k, v) in rule.params() {
                out.push_str(&format!("    param {k}={v}\n"));
            }
            for e in diag.errors() {
                out.push_str(&format!("    error: {e}\n"));
            }
        }
        for (p, stat) in self.postprocs.iter().zip(&self.postproc_stats) {
            out.push_str(&format!(
                "postproc {}: pass {}, reject {}\n",
                p.name(),
                stat.pass.get(),
                stat.reject.get()
            ));
            let desc = p.describe();
            if !desc.is_empty() {
                out.push_str(&format!("    {desc}\n"));
            }
            for e in stat.notes.lock().unwrap().iter() {
                out.push_str(&format!("    reject: {e}\n"));
            }
        }
        for (name, weight, proposed) in self.mutators.stats() {
            out.push_str(&format!("mutator {name} (weight {weight}): {proposed} proposals\n"));
        }
        out.push_str(&format!("mutations accepted: {}\n", self.mutations_accepted.get()));
        out.push_str(&format!(
            "intern arena: {} nodes, {} hits\n",
            self.intern.num_nodes(),
            self.intern.hits()
        ));
        out.push_str(&format!(
            "feature cache: {} hits, {} misses ({})\n",
            self.feature_cache.hits(),
            self.feature_cache.misses(),
            if self.feature_cache_enabled.load(Ordering::Relaxed) { "enabled" } else { "disabled" }
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn tune_context_is_shareable_across_threads() {
        assert_send_sync::<TuneContext>();
    }

    #[test]
    fn generic_context_has_canonical_labels() {
        let cpu = TuneContext::generic(Target::cpu_avx512());
        assert!(
            cpu.rule_set().starts_with(
                "auto-inline,multi-level-tiling,add-rfactor,random-compute-location,parallel-vectorize-unroll #"
            ),
            "{}",
            cpu.rule_set()
        );
        // Spelling the same list explicitly yields the identical label —
        // provenance does not depend on the `default` sugar.
        let explicit = TuneContext::from_specs(
            Target::cpu_avx512(),
            "auto-inline,multi-level-tiling,add-rfactor,random-compute-location,parallel-vectorize-unroll",
            "default",
            "default",
        )
        .unwrap();
        assert_eq!(cpu.rule_set(), explicit.rule_set());
        // The mlt-cpu alias resolves to the same instance name, so the
        // label is still canonical.
        let alias = TuneContext::from_specs(Target::cpu_avx512(), "mlt-cpu", "default", "default")
            .unwrap();
        assert!(alias.rule_set().starts_with("multi-level-tiling #"), "{}", alias.rule_set());
        // ...and the digest distinguishes spaces the names alone cannot:
        // the CPU tiling structure resolved on a GPU target is a
        // DIFFERENT space from the GPU default, and must stamp a
        // different label even though every rule family name matches.
        let gpu_default = TuneContext::generic(Target::gpu());
        let gpu_with_cpu_mlt = TuneContext::from_specs(
            Target::gpu(),
            "auto-inline,mlt-cpu,cross-thread-reduction,random-compute-location,thread-bind",
            "default",
            "default",
        )
        .unwrap();
        assert_ne!(gpu_default.rule_set(), gpu_with_cpu_mlt.rule_set());
        // WMMA vs MXU tensor cores likewise.
        let wmma = TuneContext::from_specs(Target::gpu(), "use-tensor-core", "default", "default").unwrap();
        let mxu = TuneContext::from_specs(Target::gpu(), "use-tensor-core-mxu", "default", "default").unwrap();
        assert_ne!(wmma.rule_set(), mxu.rule_set());
    }

    #[test]
    fn transfer_compatibility_judges_rule_set_labels() {
        let gpu = TuneContext::generic(Target::gpu());
        let cpu = TuneContext::generic(Target::cpu_avx512());
        // A donor from the *other* target's default space is compatible:
        // every rule name is a builtin this build still knows.
        assert!(gpu.transfer_compatible(cpu.rule_set()));
        assert!(cpu.transfer_compatible(gpu.rule_set()));
        // Own label trivially compatible.
        assert!(gpu.transfer_compatible(gpu.rule_set()));
        // Pre-provenance (empty) and retired-rule labels are not.
        assert!(!gpu.transfer_compatible(""));
        assert!(!gpu.transfer_compatible("auto-inline,ghost-rule #00000000"));
        // Digest differences alone do not break compatibility (same
        // names, other params = still an expressible space).
        assert!(gpu.transfer_compatible("auto-inline,multi-level-tiling #deadbeef"));
        // A custom rule registered with the resolving registry IS
        // vouched for by contexts built from that registry.
        let mut reg = RegistrySet::builtin();
        reg.rules.register("toy-unroll", |_| {
            Box::new(crate::space::AutoInline::new()) as Box<dyn crate::space::ScheduleRule>
        });
        let custom =
            TuneContext::from_specs_in(&reg, Target::cpu_avx512(), "default", "default", "default")
                .unwrap();
        assert!(custom.transfer_compatible("toy-unroll #12345678"));
        assert!(!cpu.transfer_compatible("toy-unroll #12345678"));
    }

    #[test]
    fn rule_set_names_parse_labels() {
        assert_eq!(rule_set_names("a,b #1234"), vec!["a", "b"]);
        assert_eq!(rule_set_names("a , b"), vec!["a", "b"]);
        assert!(rule_set_names("").is_empty());
        assert_eq!(rule_set_names("solo #ff"), vec!["solo"]);
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(TuneContext::from_specs(Target::cpu_avx512(), "nope", "default", "default").is_err());
        assert!(TuneContext::from_specs(Target::cpu_avx512(), "default", "nope", "default").is_err());
        assert!(TuneContext::from_specs(Target::cpu_avx512(), "default", "default", "nope").is_err());
    }

    #[test]
    fn explain_reports_rules_postprocs_and_mutators() {
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let prog = workloads::matmul(1, 64, 64, 64);
        let _ = ctx.generate(&prog, 1);
        let text = ctx.explain();
        assert!(text.contains("rule auto-inline:"), "{text}");
        assert!(text.contains("rule multi-level-tiling:"), "{text}");
        assert!(text.contains("postproc verify-integrity:"), "{text}");
        assert!(text.contains("mutator tile-transfer"), "{text}");
        assert!(text.contains("rules: auto-inline,"), "{text}");
        assert!(text.contains("mutators: tile-transfer,categorical-redraw,compute-location-move"), "{text}");
        assert!(text.contains("intern arena: "), "{text}");
        assert!(text.contains("feature cache: 0 hits, 0 misses (enabled)"), "{text}");
        ctx.set_feature_cache_enabled(false);
        assert!(ctx.feature_cache().is_none());
        assert!(ctx.explain().contains("(disabled)"));
        ctx.set_feature_cache_enabled(true);
        assert!(ctx.feature_cache().is_some());
    }

    #[test]
    fn context_metrics_registry_tracks_diagnostics() {
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let prog = workloads::matmul(1, 64, 64, 64);
        let states = ctx.generate(&prog, 1);
        let m = ctx.metrics();
        assert_eq!(m.counter_value("space_generations_total"), Some(1));
        assert_eq!(m.counter_value("space_states_total"), Some(states.len() as u64));
        assert!(m.counter_value("space_rule_auto_inline_skipped_total").unwrap_or(0) > 0);
        assert_eq!(m.counter_value("ctx_mutations_accepted_total"), Some(0));
        crate::telemetry::parse_exposition(&m.render()).expect("registry renders valid exposition");
        // No sink attached: spans are disabled and free.
        assert!(ctx.trace_sink().is_none());
        assert!(!ctx.span("x", "test").is_enabled());
    }

    #[test]
    fn mutate_interned_matches_mutate_and_shares_nodes() {
        // Context-level pin of the interned hot path: identical RNG
        // draws and schedules as `mutate`, and the returned child chain
        // is exactly what a from-scratch intern of the mutated trace
        // yields.
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let prog = workloads::fused_dense(64, 128, 64);
        let states = ctx.generate(&prog, 2);
        let mut rng_a = Rng::seed_from_u64(8);
        let mut rng_b = Rng::seed_from_u64(8);
        let mut accepted = 0;
        for s in &states {
            let interned = ctx.intern_trace(&s.trace);
            assert_eq!(interned.sampling_indices(), s.trace.sampling_indices().as_slice());
            for i in 0..4 {
                let a = ctx.mutate(&s.trace, &prog, &mut rng_a, i);
                let b = ctx.mutate_interned(&interned, &s.trace, &prog, &mut rng_b, i);
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some((y, child))) => {
                        accepted += 1;
                        assert_eq!(
                            crate::tir::structural_hash(&x.prog),
                            crate::tir::structural_hash(&y.prog)
                        );
                        assert_eq!(ctx.arena().materialize(&child), y.trace);
                        assert_eq!(child, ctx.intern_trace(&y.trace));
                    }
                    (x, y) => panic!("paths diverged: {:?} vs {:?}", x.is_some(), y.is_some()),
                }
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG state diverged");
        }
        assert!(accepted > 0, "no mutation accepted on either path");
        assert!(ctx.arena().num_nodes() > 0);
    }

    #[test]
    fn postprocess_counts_passes_and_rejections() {
        let ctx = TuneContext::from_specs(Target::gpu(), "default", "default", "sim-validity")
            .unwrap();
        // Valid on the GPU model.
        let ok = Schedule::new(workloads::matmul(1, 32, 32, 32), 0);
        assert!(ctx.postprocess(&ok));
        // 4096 threads on one loop -> sim-invalid.
        let mut bad = Schedule::new(workloads::matmul(1, 4096, 16, 16), 0);
        let b = bad.get_block("matmul").unwrap();
        let loops = bad.get_loops(b).unwrap();
        bad.bind(loops[1], "threadIdx.x").unwrap();
        assert!(!ctx.postprocess(&bad));
        let text = ctx.explain();
        assert!(text.contains("postproc sim-validity: pass 1, reject 1"), "{text}");
    }
}
