//! Cross-target transfer priors (ROADMAP "cross-target transfer").
//!
//! The database keys every workload by `(structural hash, target)`, so a
//! program tuned for target A starts *cold* on target B even though the
//! two searches share most of their structure — and "Learning to
//! Optimize Tensor Programs" (Chen et al.) showed exactly this kind of
//! experience transfers. This module is the explicit bridge, built on
//! the provenance stamps PR 4 put into every record (`sim_version` +
//! canonical rule-set label) — the first feature that *reads* provenance
//! instead of just writing it.
//!
//! The contract is **priors, never truth**:
//!
//! - A donor record's latency was measured on another target. It never
//!   becomes a destination best, a curve point, or a committed record.
//! - **Elite seeding**: the best compatible donor traces are replayed
//!   against the destination space's postprocessor gate and then
//!   *re-measured on the destination target* inside the normal trial
//!   budget; only those destination measurements are committed (stamped
//!   with the destination target and the current `sim_version`).
//! - **Feature-space cost-model transfer**: donor `(program features,
//!   latency)` pairs pretrain the cost model as *discounted* samples
//!   ([`crate::cost_model::CostModel::update_prior`]) so round 1 ranks
//!   with a warm prior instead of the cold neutral score, while native
//!   destination measurements (weight 1) dominate as they accumulate.
//!
//! Compatibility is judged per donor record, not per donor database:
//! the record's `sim_version` must match [`crate::sim::SIM_VERSION`]
//! (latencies from an older simulator model are not commensurable), and
//! its rule-set label must pass the destination context's
//! [`crate::ctx::TuneContext::transfer_compatible`] predicate (a space
//! this build cannot even express is a space it cannot vouch for).
//! Incompatible donors are counted, never silently blended in.

use std::collections::HashSet;

use crate::cost_model::CostModel;
use crate::ctx::TuneContext;
use crate::db::{Database, TuningRecord};
use crate::schedule::Schedule;
use crate::tir::{structural_hash, Program};

/// Knobs for donor selection and prior injection.
#[derive(Debug, Clone)]
pub struct TransferConfig {
    /// *Compatible* donor records kept per source workload (best-first
    /// by *source* latency — latencies are only comparable within one
    /// source). The cap applies after compatibility filtering, so
    /// incompatible records can never crowd compatible ones out of the
    /// pool (the same crowd-out rule `pretrain_cost_model` follows).
    pub per_source_top_k: usize,
    /// Max donor-derived seed candidates eagerly re-measured on the
    /// destination target (also capped at half the trial budget by the
    /// search, so seeding can never starve the evolutionary rounds).
    pub max_seeds: usize,
    /// Max donor records replayed into cost-model prior samples.
    pub max_model_records: usize,
    /// Weight of a donor sample relative to a native destination
    /// measurement, in `(0, 1]` — the source-target mismatch discount.
    pub model_discount: f64,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            per_source_top_k: 32,
            max_seeds: 4,
            max_model_records: 256,
            model_discount: 0.5,
        }
    }
}

/// Compatible donor records for one `(workload, destination target)`
/// pair, plus the bookkeeping of what was refused. Built once per tuning
/// call by [`TransferPool::collect`] and handed to
/// [`crate::search::EvolutionarySearch::tune_with_db`] as an optional
/// prior source.
#[derive(Debug, Clone)]
pub struct TransferPool {
    pub cfg: TransferConfig,
    /// Distinct donor target names, in registration order.
    pub source_targets: Vec<String>,
    /// Compatible donor records: grouped by donor registration order,
    /// best-first within each donor (the deterministic order every
    /// consumer iterates in).
    pub records: Vec<TuningRecord>,
    /// Donor records refused for a `sim_version` mismatch.
    pub incompatible_sim: usize,
    /// Donor records refused by the rule-set compatibility predicate
    /// (unknown/retired rules, or pre-provenance records).
    pub incompatible_rules: usize,
}

impl TransferPool {
    /// Select compatible donor records for the workload `shash` about to
    /// be tuned on `dest_target`. `source_target` restricts donors to
    /// one named target (`tune --transfer-from`); `None` pools every
    /// other target's records. `ctx` is the **destination** tuning
    /// context — its registry vocabulary judges donor rule-set labels.
    pub fn collect(
        db: &dyn Database,
        shash: u64,
        dest_target: &str,
        source_target: Option<&str>,
        ctx: &TuneContext,
        cfg: TransferConfig,
    ) -> TransferPool {
        let mut pool = TransferPool {
            source_targets: Vec::new(),
            records: Vec::new(),
            incompatible_sim: 0,
            incompatible_rules: 0,
            cfg,
        };
        // Fetch every donor record and filter *before* applying the
        // per-source cap: truncating first would let incompatible
        // records crowd compatible ones out of the pool entirely.
        let candidates = db.query_transfer_candidates(shash, dest_target, source_target, usize::MAX);
        let mut kept_per_source: Vec<(String, usize)> = Vec::new();
        for rec in candidates {
            if rec.sim_version != crate::sim::SIM_VERSION {
                pool.incompatible_sim += 1;
                continue;
            }
            if !ctx.transfer_compatible(&rec.rule_set) {
                pool.incompatible_rules += 1;
                continue;
            }
            let idx = match kept_per_source.iter().position(|(t, _)| t == &rec.target) {
                Some(i) => i,
                None => {
                    kept_per_source.push((rec.target.clone(), 0));
                    kept_per_source.len() - 1
                }
            };
            if kept_per_source[idx].1 >= pool.cfg.per_source_top_k {
                continue; // cap compatible records per source (best-first order)
            }
            kept_per_source[idx].1 += 1;
            if !pool.source_targets.contains(&rec.target) {
                pool.source_targets.push(rec.target.clone());
            }
            pool.records.push(rec);
        }
        pool
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Donor records refused during [`TransferPool::collect`].
    pub fn incompatible(&self) -> usize {
        self.incompatible_sim + self.incompatible_rules
    }

    /// Feature-space cost-model transfer: replay up to
    /// `max_model_records` donors against the destination base program
    /// and feed `(program, donor latency)` pairs to the model as one
    /// discounted prior batch. Donor latencies carry cross-target scale
    /// error — the discount (plus the model's preference for ranking
    /// over absolute error) is what keeps them a prior rather than
    /// truth. Returns the number of samples fed.
    pub fn pretrain(&self, model: &mut dyn CostModel, prog: &Program) -> usize {
        let mut progs: Vec<Program> = Vec::new();
        let mut lats: Vec<f64> = Vec::new();
        for rec in self.records.iter().take(self.cfg.max_model_records) {
            let Some(lat) = rec.best_latency() else {
                continue;
            };
            if let Ok(sch) = crate::trace::replay(&rec.trace, prog, 0) {
                progs.push(sch.prog);
                lats.push(lat);
            }
        }
        if progs.is_empty() {
            return 0;
        }
        let refs: Vec<&Program> = progs.iter().collect();
        model.update_prior(&refs, &lats, self.cfg.model_discount);
        progs.len()
    }

    /// Elite seeding: replay the best donors into destination candidate
    /// schedules — gated by the destination context's postprocessor
    /// pipeline, deduplicated against `already_measured` (candidates the
    /// destination has already paid for) and against each other — for
    /// the search to re-measure on the destination target. Returns at
    /// most `max` `(schedule, candidate hash)` pairs, in donor order.
    /// Nothing here touches a database or a result: committing is the
    /// search's job, *after* the destination measurement.
    pub fn seed_schedules(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        already_measured: &HashSet<u64>,
        max: usize,
    ) -> Vec<(Schedule, u64)> {
        let mut out: Vec<(Schedule, u64)> = Vec::with_capacity(max.min(self.records.len()));
        let mut picked: HashSet<u64> = HashSet::new();
        for rec in &self.records {
            if out.len() >= max {
                break;
            }
            let Ok(sch) = crate::trace::replay(&rec.trace, prog, 0) else {
                continue;
            };
            if !ctx.postprocess(&sch) {
                continue;
            }
            let h = structural_hash(&sch.prog);
            if already_measured.contains(&h) || !picked.insert(h) {
                continue;
            }
            out.push((sch, h));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost_model::GbtCostModel;
    use crate::db::InMemoryDb;
    use crate::sim::Target;
    use crate::trace::replay::replay_fresh;
    use crate::trace::Trace;
    use crate::workloads;

    fn prog() -> Program {
        workloads::matmul(1, 64, 64, 64)
    }

    /// A replayable trace for the program, drawn from the cpu space.
    fn cpu_trace(seed: u64) -> Trace {
        let ctx = TuneContext::generic(Target::cpu_avx512());
        let designs = ctx.generate(&prog(), 1);
        for d in &designs {
            if let Ok(sch) = replay_fresh(&d.trace, &prog(), seed) {
                return sch.trace;
            }
        }
        panic!("no design replays");
    }

    fn donor_db(records: Vec<TuningRecord>) -> InMemoryDb {
        let mut db = InMemoryDb::new();
        let wid = db.register_workload("w", structural_hash(&prog()), "cpu-avx512");
        assert_eq!(wid, 0);
        for r in records {
            db.commit_record(r);
        }
        db
    }

    fn donor_rec(trace: Trace, lat: f64, sim: &str, rules: &str, cand: u64) -> TuningRecord {
        TuningRecord {
            workload: 0,
            trace,
            latencies: vec![lat],
            target: "cpu-avx512".into(),
            seed: 1,
            round: 0,
            cand_hash: cand,
            sim_version: sim.into(),
            rule_set: rules.into(),
            objective: String::new(),
        }
    }

    #[test]
    fn collect_filters_incompatible_donors() {
        let cpu_rules = TuneContext::generic(Target::cpu_avx512()).rule_set().to_string();
        let db = donor_db(vec![
            donor_rec(cpu_trace(1), 2e-6, crate::sim::SIM_VERSION, &cpu_rules, 1),
            donor_rec(cpu_trace(2), 1e-6, "sim-v0-retired", &cpu_rules, 2),
            donor_rec(cpu_trace(3), 3e-6, crate::sim::SIM_VERSION, "ghost-rule #00000000", 3),
            donor_rec(cpu_trace(4), 4e-6, crate::sim::SIM_VERSION, "", 4), // pre-provenance
        ]);
        let gpu_ctx = TuneContext::generic(Target::gpu());
        let pool = TransferPool::collect(
            &db,
            structural_hash(&prog()),
            "gpu-rtx3070",
            Some("cpu-avx512"),
            &gpu_ctx,
            TransferConfig::default(),
        );
        assert_eq!(pool.len(), 1, "only the fully compatible donor survives");
        assert_eq!(pool.records[0].cand_hash, 1);
        assert_eq!(pool.incompatible_sim, 1);
        assert_eq!(pool.incompatible_rules, 2);
        assert_eq!(pool.source_targets, vec!["cpu-avx512".to_string()]);
        // The same db offers nothing when the destination IS the source.
        let cpu_ctx = TuneContext::generic(Target::cpu_avx512());
        let self_pool = TransferPool::collect(
            &db,
            structural_hash(&prog()),
            "cpu-avx512",
            None,
            &cpu_ctx,
            TransferConfig::default(),
        );
        assert!(self_pool.is_empty(), "a target must never donate to itself");
        assert_eq!(self_pool.incompatible(), 0);
    }

    #[test]
    fn incompatible_donors_never_crowd_out_compatible_ones() {
        // The donor's BEST record is stale; with a per-source cap of 1,
        // the pool must still contain the (worse-ranked) compatible
        // record — filtering happens before the cap, not after.
        let cpu_rules = TuneContext::generic(Target::cpu_avx512()).rule_set().to_string();
        let db = donor_db(vec![
            donor_rec(cpu_trace(1), 1e-6, "sim-v0-retired", &cpu_rules, 1), // stale best
            donor_rec(cpu_trace(2), 2e-6, crate::sim::SIM_VERSION, &cpu_rules, 2),
        ]);
        let gpu_ctx = TuneContext::generic(Target::gpu());
        let cfg = TransferConfig { per_source_top_k: 1, ..TransferConfig::default() };
        let pool =
            TransferPool::collect(&db, structural_hash(&prog()), "gpu-rtx3070", None, &gpu_ctx, cfg);
        assert_eq!(pool.len(), 1, "compatible donor crowded out by a stale one");
        assert_eq!(pool.records[0].cand_hash, 2);
        assert_eq!(pool.incompatible_sim, 1);
        // And the cap itself still binds: two compatible records, cap 1.
        let db2 = donor_db(vec![
            donor_rec(cpu_trace(3), 1e-6, crate::sim::SIM_VERSION, &cpu_rules, 3),
            donor_rec(cpu_trace(4), 2e-6, crate::sim::SIM_VERSION, &cpu_rules, 4),
        ]);
        let cfg = TransferConfig { per_source_top_k: 1, ..TransferConfig::default() };
        let pool2 =
            TransferPool::collect(&db2, structural_hash(&prog()), "gpu-rtx3070", None, &gpu_ctx, cfg);
        assert_eq!(pool2.len(), 1);
        assert_eq!(pool2.records[0].cand_hash, 3, "cap must keep the best-ranked compatible record");
    }

    #[test]
    fn pretrain_feeds_discounted_donor_samples() {
        let cpu_rules = TuneContext::generic(Target::cpu_avx512()).rule_set().to_string();
        let db = donor_db(vec![
            donor_rec(cpu_trace(1), 2e-6, crate::sim::SIM_VERSION, &cpu_rules, 1),
            donor_rec(cpu_trace(2), 3e-6, crate::sim::SIM_VERSION, &cpu_rules, 2),
        ]);
        let gpu_ctx = TuneContext::generic(Target::gpu());
        let pool = TransferPool::collect(
            &db,
            structural_hash(&prog()),
            "gpu-rtx3070",
            None,
            &gpu_ctx,
            TransferConfig::default(),
        );
        let mut model = GbtCostModel::new();
        let fed = pool.pretrain(&mut model, &prog());
        assert_eq!(fed, 2);
        assert_eq!(model.n_samples(), 2);
        let p = prog();
        assert!(model.predict(&[&p])[0] != 0.0, "model still cold after donor pretraining");
    }

    #[test]
    fn seed_schedules_dedup_and_respect_caps() {
        let cpu_rules = TuneContext::generic(Target::cpu_avx512()).rule_set().to_string();
        let t = cpu_trace(1);
        let db = donor_db(vec![
            donor_rec(t.clone(), 2e-6, crate::sim::SIM_VERSION, &cpu_rules, 1),
            // Same trace again: replays to the same candidate, must dedup.
            donor_rec(t, 2.5e-6, crate::sim::SIM_VERSION, &cpu_rules, 1),
            donor_rec(cpu_trace(9), 3e-6, crate::sim::SIM_VERSION, &cpu_rules, 2),
        ]);
        let gpu_ctx = TuneContext::generic(Target::gpu());
        let pool = TransferPool::collect(
            &db,
            structural_hash(&prog()),
            "gpu-rtx3070",
            None,
            &gpu_ctx,
            TransferConfig::default(),
        );
        let seeds = pool.seed_schedules(&prog(), &gpu_ctx, &HashSet::new(), 8);
        let hashes: Vec<u64> = seeds.iter().map(|(_, h)| *h).collect();
        let unique: HashSet<u64> = hashes.iter().copied().collect();
        assert_eq!(unique.len(), hashes.len(), "duplicate seed candidates");
        assert!(!seeds.is_empty());
        // Already-measured candidates are skipped...
        let all: HashSet<u64> = hashes.iter().copied().collect();
        assert!(pool.seed_schedules(&prog(), &gpu_ctx, &all, 8).is_empty());
        // ...and the cap bounds the output.
        assert!(pool.seed_schedules(&prog(), &gpu_ctx, &HashSet::new(), 1).len() <= 1);
    }
}
