//! The indexed snapshot: everything a serving process needs to answer
//! "best known schedule for (structural hash, target)" in memory, built
//! once, immutable afterwards.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry};
use crate::schedule::Schedule;
use crate::tir::{structural_hash, Program};
use crate::trace::replay;

/// One served workload: its registry entry plus the top records,
/// best-first (ascending best latency, commit order breaking ties —
/// exactly [`Database::query_top_k`] order).
#[derive(Debug, Clone)]
pub struct ServedWorkload {
    pub entry: WorkloadEntry,
    pub top: Vec<TuningRecord>,
}

impl ServedWorkload {
    /// Reconstruct this workload's best schedule by replaying its best
    /// record against `prog` (the workload's base program), falling
    /// through to the next record when a stored trace no longer replays
    /// (schedule-primitive drift) — mirroring the search's warm start.
    pub fn apply(&self, prog: &Program) -> Option<Schedule> {
        self.top.iter().find_map(|rec| replay(&rec.trace, prog, 0).ok())
    }
}

/// Immutable, hash-indexed view of a tuning database. Lookups are a
/// `HashMap` probe on the structural hash plus a scan over the (few)
/// targets sharing it — no file I/O, no JSONL parsing, no allocation,
/// no lock. All data is owned, so the cache is `Send + Sync` and shares
/// across threads as a plain `Arc<ServingCache>`.
#[derive(Debug, Clone)]
pub struct ServingCache {
    /// Served workloads in registration order.
    slots: Vec<ServedWorkload>,
    /// shash -> indices into `slots` (one per target seen for the hash).
    by_hash: HashMap<u64, Vec<usize>>,
    /// Successful records indexed across all slots.
    records: usize,
}

impl ServingCache {
    /// Records retained per workload by default — matches the search's
    /// warm-start replay depth, so a fall-through on a stale best trace
    /// has the same candidates the search itself would see.
    pub const DEFAULT_TOP_K: usize = 8;

    /// Build a snapshot from any database backend, keeping the `top_k`
    /// best successful records per workload. Workloads with no
    /// successful record are indexed with an empty `top` (a lookup on
    /// them is a miss, but [`Self::num_workloads`] still counts them).
    pub fn build(db: &dyn Database, top_k: usize) -> ServingCache {
        let mut slots = Vec::new();
        let mut by_hash: HashMap<u64, Vec<usize>> = HashMap::new();
        let mut records = 0usize;
        for entry in db.workload_entries() {
            let top = db.query_top_k(entry.id, top_k);
            records += top.len();
            by_hash.entry(entry.shash).or_default().push(slots.len());
            slots.push(ServedWorkload { entry, top });
        }
        ServingCache { slots, by_hash, records }
    }

    /// Load a snapshot read-only from a JSONL database file: the file is
    /// parsed once here (with the same corruption recovery as
    /// [`crate::db::JsonFileDb::open`]) and never touched again — no
    /// append handle is opened, so a serving process can load from a
    /// file it has no write permission on. Returns the cache plus the
    /// number of corrupt lines skipped.
    pub fn load(path: impl AsRef<Path>, top_k: usize) -> Result<(ServingCache, usize), String> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(format!("no database at {}", path.display()));
        }
        let loaded = crate::db::json_file::read_index(path)?;
        Ok((ServingCache::build(&loaded.mem, top_k), loaded.skipped))
    }

    /// The served workload for `(shash, target)`, if registered.
    pub fn lookup_workload(&self, shash: u64, target: &str) -> Option<&ServedWorkload> {
        self.by_hash
            .get(&shash)?
            .iter()
            .map(|&i| &self.slots[i])
            .find(|w| w.entry.target == target)
    }

    /// Best known record for `(shash, target)`. `None` = unknown
    /// workload or no successful measurement on file.
    pub fn lookup(&self, shash: u64, target: &str) -> Option<&TuningRecord> {
        self.lookup_workload(shash, target).and_then(|w| w.top.first())
    }

    /// Best known latency for `(shash, target)`.
    pub fn best_latency(&self, shash: u64, target: &str) -> Option<f64> {
        self.lookup(shash, target).and_then(TuningRecord::best_latency)
    }

    /// Reconstruct the best schedule for `prog` on `target`: one lookup,
    /// then [`ServedWorkload::apply`]. Callers that already hold the
    /// [`ServedWorkload`] (e.g. after [`Self::lookup_workload`]) should
    /// call `apply` directly and skip the second hash + probe.
    pub fn apply_best(&self, prog: &Program, target: &str) -> Option<Schedule> {
        self.lookup_workload(structural_hash(prog), target)?.apply(prog)
    }

    /// Served workloads in registration order.
    pub fn workloads(&self) -> &[ServedWorkload] {
        &self.slots
    }

    pub fn num_workloads(&self) -> usize {
        self.slots.len()
    }

    /// Successful records indexed across all workloads.
    pub fn num_records(&self) -> usize {
        self.records
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// The swap point between one writer (tuner / compactor, which builds
/// fresh [`ServingCache`]s) and many readers. Readers take a brief lock
/// only to clone the current `Arc`; every lookup after that is lock-free
/// on an immutable snapshot, so a reader mid-batch keeps one consistent
/// view no matter how many publishes happen meanwhile — pre- or
/// post-publish state, never a torn mix.
pub struct SnapshotSlot {
    current: Mutex<Arc<ServingCache>>,
}

impl SnapshotSlot {
    pub fn new(cache: ServingCache) -> SnapshotSlot {
        SnapshotSlot {
            current: Mutex::new(Arc::new(cache)),
        }
    }

    /// The currently-published snapshot.
    pub fn get(&self) -> Arc<ServingCache> {
        self.current.lock().unwrap().clone()
    }

    /// Publish a fresh snapshot; readers holding the old `Arc` keep it
    /// alive (and consistent) until they next call [`Self::get`].
    pub fn publish(&self, cache: ServingCache) -> Arc<ServingCache> {
        let next = Arc::new(cache);
        *self.current.lock().unwrap() = next.clone();
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::InMemoryDb;
    use crate::trace::Trace;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn serving_cache_is_send_and_sync() {
        assert_send_sync::<ServingCache>();
        assert_send_sync::<SnapshotSlot>();
    }

    fn rec(workload: usize, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace { insts: vec![] },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 0,
            round: cand,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
        }
    }

    #[test]
    fn lookup_matches_query_top_k_and_separates_targets() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 10, "cpu");
        let a_gpu = db.register_workload("A", 10, "gpu");
        let b = db.register_workload("B", 20, "cpu");
        db.commit_record(rec(a, 1, Some(3.0)));
        db.commit_record(rec(a, 2, Some(1.0)));
        db.commit_record(rec(a, 3, None)); // failure: not served
        db.commit_record(rec(a_gpu, 4, Some(0.5)));
        let _ = b; // registered but empty
        let cache = ServingCache::build(&db, 8);
        assert_eq!(cache.num_workloads(), 3);
        assert_eq!(cache.num_records(), 3);
        assert_eq!(cache.lookup(10, "cpu").unwrap().cand_hash, 2);
        assert_eq!(cache.best_latency(10, "cpu"), Some(1.0));
        assert_eq!(cache.best_latency(10, "gpu"), Some(0.5), "targets must not pool");
        assert_eq!(cache.lookup(20, "cpu"), None, "workload with no success is a miss");
        assert_eq!(cache.lookup(99, "cpu"), None);
        // Same answer the database itself would give.
        assert_eq!(cache.lookup(10, "cpu"), db.query_top_k(a, 1).first());
    }

    #[test]
    fn top_k_truncates_per_workload() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 1, "cpu");
        for i in 0..10u64 {
            db.commit_record(rec(a, i, Some((10 - i) as f64)));
        }
        let cache = ServingCache::build(&db, 3);
        let w = cache.lookup_workload(1, "cpu").unwrap();
        assert_eq!(w.top.len(), 3);
        assert_eq!(w.top[0].cand_hash, 9, "best-first order");
        assert_eq!(cache.num_records(), 3);
    }

    #[test]
    fn snapshot_slot_swaps_whole_snapshots() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 1, "cpu");
        db.commit_record(rec(a, 1, Some(2.0)));
        let slot = SnapshotSlot::new(ServingCache::build(&db, 8));
        let held = slot.get();
        db.commit_record(rec(a, 2, Some(1.0)));
        slot.publish(ServingCache::build(&db, 8));
        // The reader's held snapshot is unchanged; a re-get sees the new one.
        assert_eq!(held.best_latency(1, "cpu"), Some(2.0));
        assert_eq!(slot.get().best_latency(1, "cpu"), Some(1.0));
    }

    #[test]
    fn apply_best_replays_real_traces() {
        use crate::search::{Measurer, SimMeasurer};
        use crate::sim::Target;
        use crate::ctx::TuneContext;
        let target = Target::cpu_avx512();
        let prog = crate::workloads::matmul(1, 64, 64, 64);
        let mut db = InMemoryDb::new();
        let wid = db.register_workload(&prog.name, structural_hash(&prog), target.name);
        let ctx = TuneContext::generic(target.clone());
        let mut measurer = SimMeasurer::new(target.clone());
        let mut committed = 0;
        for (i, d) in ctx.generate(&prog, 1).iter().cycle().take(64).enumerate() {
            if committed >= 4 {
                break;
            }
            let Ok(sch) = crate::trace::replay::replay_fresh(&d.trace, &prog, 500 + i as u64) else {
                continue;
            };
            let lat = measurer.measure(&sch.prog);
            db.commit_record(TuningRecord {
                workload: wid,
                trace: sch.trace.clone(),
                latencies: lat.into_iter().collect(),
                target: target.name.to_string(),
                seed: 1,
                round: i as u64,
                cand_hash: structural_hash(&sch.prog),
                sim_version: crate::sim::SIM_VERSION.to_string(),
                rule_set: String::new(),
            });
            committed += 1;
        }
        let cache = ServingCache::build(&db, 8);
        let best = cache.lookup(structural_hash(&prog), target.name).expect("hit");
        let sch = cache.apply_best(&prog, target.name).expect("best trace must replay");
        assert_eq!(structural_hash(&sch.prog), best.cand_hash);
        // The replayed program reproduces the recorded latency on the
        // deterministic simulator.
        let mut m = SimMeasurer::new(target.clone());
        assert_eq!(m.measure(&sch.prog), best.best_latency());
    }
}
