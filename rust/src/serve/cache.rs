//! The indexed snapshot: everything a serving process needs to answer
//! "best known schedule for (structural hash, target)" in memory, built
//! once, immutable afterwards.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::db::record::TuningRecord;
use crate::db::{Database, WorkloadEntry};
use crate::schedule::Schedule;
use crate::tir::{structural_hash, Program};
use crate::trace::replay;

/// One served workload: its registry entry plus the top records,
/// best-first (ascending best latency, commit order breaking ties —
/// exactly [`Database::query_top_k`] order).
#[derive(Debug, Clone)]
pub struct ServedWorkload {
    pub entry: WorkloadEntry,
    pub top: Vec<TuningRecord>,
}

impl ServedWorkload {
    /// Reconstruct this workload's best schedule by replaying its best
    /// record against `prog` (the workload's base program), falling
    /// through to the next record when a stored trace no longer replays
    /// (schedule-primitive drift) — mirroring the search's warm start.
    pub fn apply(&self, prog: &Program) -> Option<Schedule> {
        self.top.iter().find_map(|rec| replay(&rec.trace, prog, 0).ok())
    }
}

/// Immutable, hash-indexed view of a tuning database. Lookups are a
/// `HashMap` probe on the structural hash plus a scan over the (few)
/// targets sharing it — no file I/O, no JSONL parsing, no allocation,
/// no lock. All data is owned, so the cache is `Send + Sync` and shares
/// across threads as a plain `Arc<ServingCache>`.
///
/// # Examples
///
/// ```
/// use metaschedule::db::{Database, InMemoryDb, TuningRecord};
/// use metaschedule::serve::ServingCache;
/// use metaschedule::trace::Trace;
///
/// let mut db = InMemoryDb::new();
/// let wid = db.register_workload("GMM", 0xab, "cpu");
/// db.commit_record(TuningRecord {
///     workload: wid,
///     trace: Trace { insts: vec![] },
///     latencies: vec![1.5e-5],
///     target: "cpu".into(),
///     seed: 1,
///     round: 0,
///     cand_hash: 7,
///     sim_version: "sim".into(),
///     rule_set: String::new(),
///     objective: String::new(),
/// });
///
/// let cache = ServingCache::build(&db, ServingCache::DEFAULT_TOP_K);
/// assert_eq!(cache.best_latency(0xab, "cpu"), Some(1.5e-5));
/// assert_eq!(cache.lookup(0xab, "gpu"), None); // targets never pool
/// ```
#[derive(Debug, Clone)]
pub struct ServingCache {
    /// Served workloads in registration order.
    slots: Vec<ServedWorkload>,
    /// shash -> indices into `slots` (one per target seen for the hash).
    by_hash: HashMap<u64, Vec<usize>>,
    /// Successful records indexed across all slots.
    records: usize,
}

impl ServingCache {
    /// Records retained per workload by default — matches the search's
    /// warm-start replay depth, so a fall-through on a stale best trace
    /// has the same candidates the search itself would see.
    pub const DEFAULT_TOP_K: usize = 8;

    /// Build a snapshot from any database backend, keeping the `top_k`
    /// best successful records per workload. Workloads with no
    /// successful record are indexed with an empty `top` (a lookup on
    /// them is a miss, but [`Self::num_workloads`] still counts them).
    pub fn build(db: &dyn Database, top_k: usize) -> ServingCache {
        let mut slots = Vec::new();
        let mut by_hash: HashMap<u64, Vec<usize>> = HashMap::new();
        let mut records = 0usize;
        for entry in db.workload_entries() {
            let top = db.query_top_k(entry.id, top_k);
            records += top.len();
            by_hash.entry(entry.shash).or_default().push(slots.len());
            slots.push(ServedWorkload { entry, top });
        }
        ServingCache { slots, by_hash, records }
    }

    /// Load a snapshot read-only from a database path of either layout —
    /// a single JSONL file or a sharded directory
    /// ([`crate::db::ShardedDb`]), auto-detected. The records are parsed
    /// once here (with the same corruption recovery as
    /// [`crate::db::JsonFileDb::open`]) and never touched again — no
    /// append handle is opened, so a serving process can load from a
    /// path it has no write permission on. Returns the cache plus the
    /// number of corrupt lines skipped.
    pub fn load(path: impl AsRef<Path>, top_k: usize) -> Result<(ServingCache, usize), String> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(format!("no database at {}", path.display()));
        }
        let (mem, skipped) = crate::db::sharded::load_readonly_any(path)?;
        Ok((ServingCache::build(&mem, top_k), skipped))
    }

    /// The served workload for `(shash, target)`, if registered.
    pub fn lookup_workload(&self, shash: u64, target: &str) -> Option<&ServedWorkload> {
        self.by_hash
            .get(&shash)?
            .iter()
            .map(|&i| &self.slots[i])
            .find(|w| w.entry.target == target)
    }

    /// Best known record for `(shash, target)`. `None` = unknown
    /// workload or no successful measurement on file.
    pub fn lookup(&self, shash: u64, target: &str) -> Option<&TuningRecord> {
        self.lookup_workload(shash, target).and_then(|w| w.top.first())
    }

    /// Best known latency for `(shash, target)`.
    pub fn best_latency(&self, shash: u64, target: &str) -> Option<f64> {
        self.lookup(shash, target).and_then(TuningRecord::best_latency)
    }

    /// Reconstruct the best schedule for `prog` on `target`: one lookup,
    /// then [`ServedWorkload::apply`]. Callers that already hold the
    /// [`ServedWorkload`] (e.g. after [`Self::lookup_workload`]) should
    /// call `apply` directly and skip the second hash + probe.
    pub fn apply_best(&self, prog: &Program, target: &str) -> Option<Schedule> {
        self.lookup_workload(structural_hash(prog), target)?.apply(prog)
    }

    /// Served workloads in registration order.
    pub fn workloads(&self) -> &[ServedWorkload] {
        &self.slots
    }

    pub fn num_workloads(&self) -> usize {
        self.slots.len()
    }

    /// Successful records indexed across all workloads.
    pub fn num_records(&self) -> usize {
        self.records
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// The swap point between one writer (tuner / compactor, which builds
/// fresh [`ServingCache`]s) and many readers. Readers take a brief lock
/// only to clone the current `Arc`; every lookup after that is lock-free
/// on an immutable snapshot, so a reader mid-batch keeps one consistent
/// view no matter how many publishes happen meanwhile — pre- or
/// post-publish state, never a torn mix.
///
/// # Examples
///
/// ```
/// use metaschedule::db::{Database, InMemoryDb};
/// use metaschedule::serve::{ServingCache, SnapshotSlot};
///
/// let mut db = InMemoryDb::new();
/// db.register_workload("GMM", 1, "cpu");
/// let slot = SnapshotSlot::new(ServingCache::build(&db, 8));
///
/// let held = slot.get(); // a reader pins the current snapshot...
/// db.register_workload("SFM", 2, "cpu");
/// slot.publish(ServingCache::build(&db, 8)); // ...while a writer swaps
///
/// assert_eq!(held.num_workloads(), 1); // the pinned view is unchanged
/// assert_eq!(slot.get().num_workloads(), 2); // a re-get sees the new one
/// ```
pub struct SnapshotSlot {
    current: Mutex<Arc<ServingCache>>,
}

impl SnapshotSlot {
    pub fn new(cache: ServingCache) -> SnapshotSlot {
        SnapshotSlot {
            current: Mutex::new(Arc::new(cache)),
        }
    }

    /// The currently-published snapshot.
    pub fn get(&self) -> Arc<ServingCache> {
        self.current.lock().unwrap().clone()
    }

    /// Publish a fresh snapshot; readers holding the old `Arc` keep it
    /// alive (and consistent) until they next call [`Self::get`].
    pub fn publish(&self, cache: ServingCache) -> Arc<ServingCache> {
        let next = Arc::new(cache);
        *self.current.lock().unwrap() = next.clone();
        next
    }
}

/// One [`SnapshotSlot`] per database shard, routed by the same
/// structural-hash function the shards themselves use
/// ([`crate::db::shard_of`]). This is what keeps the network front's
/// read path lock-free *and* cheap to refresh: a tune-on-miss only
/// rebuilds and republishes the one shard it wrote to
/// ([`Self::refresh`]), while readers of every other shard keep their
/// snapshots without ever touching the writer mutex. A single-file
/// database degenerates to one slot — same code path, shard count 1.
///
/// Each per-shard [`ServingCache`] is built from that shard's standalone
/// [`crate::db::JsonFileDb`], so the workload ids inside it are
/// shard-local; serving lookups are by `(shash, target)` and never see
/// an id, which is why that is harmless.
pub struct ShardedSnapshots {
    slots: Vec<SnapshotSlot>,
}

impl ShardedSnapshots {
    /// Build one published snapshot per shard of `db`.
    pub fn build(db: &crate::db::AnyDb, top_k: usize) -> ShardedSnapshots {
        use crate::db::AnyDb;
        let slots = match db {
            AnyDb::Single(f) => vec![SnapshotSlot::new(ServingCache::build(f, top_k))],
            AnyDb::Sharded(s) => (0..s.num_shards())
                .map(|i| SnapshotSlot::new(ServingCache::build(s.shard(i), top_k)))
                .collect(),
        };
        ShardedSnapshots { slots }
    }

    /// Number of slots (the database's shard count; 1 for single-file).
    pub fn num_shards(&self) -> usize {
        self.slots.len()
    }

    /// The slot index a structural hash routes to.
    pub fn shard_for(&self, shash: u64) -> usize {
        crate::db::shard_of(shash, self.slots.len())
    }

    /// The currently-published snapshot covering `shash` — a clone of
    /// one `Arc`, after which every lookup is lock-free.
    pub fn get(&self, shash: u64) -> Arc<ServingCache> {
        self.slots[self.shard_for(shash)].get()
    }

    /// Rebuild and republish only the shard that `shash` routes to —
    /// the after-a-tune refresh. `db` must be the database these
    /// snapshots were built from (same shard count).
    pub fn refresh(&self, db: &crate::db::AnyDb, shash: u64, top_k: usize) {
        use crate::db::AnyDb;
        match db {
            AnyDb::Single(f) => {
                self.slots[0].publish(ServingCache::build(f, top_k));
            }
            AnyDb::Sharded(s) => {
                let i = crate::db::shard_of(shash, s.num_shards());
                self.slots[i].publish(ServingCache::build(s.shard(i), top_k));
            }
        }
    }

    /// Workloads indexed across all shards (sums a `get` per slot).
    pub fn num_workloads(&self) -> usize {
        self.slots.iter().map(|s| s.get().num_workloads()).sum()
    }

    /// Successful records indexed across all shards.
    pub fn num_records(&self) -> usize {
        self.slots.iter().map(|s| s.get().num_records()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::InMemoryDb;
    use crate::trace::Trace;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn serving_cache_is_send_and_sync() {
        assert_send_sync::<ServingCache>();
        assert_send_sync::<SnapshotSlot>();
    }

    fn rec(workload: usize, cand: u64, lat: Option<f64>) -> TuningRecord {
        TuningRecord {
            workload,
            trace: Trace { insts: vec![] },
            latencies: lat.into_iter().collect(),
            target: "cpu".into(),
            seed: 0,
            round: cand,
            cand_hash: cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        }
    }

    #[test]
    fn lookup_matches_query_top_k_and_separates_targets() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 10, "cpu");
        let a_gpu = db.register_workload("A", 10, "gpu");
        let b = db.register_workload("B", 20, "cpu");
        db.commit_record(rec(a, 1, Some(3.0)));
        db.commit_record(rec(a, 2, Some(1.0)));
        db.commit_record(rec(a, 3, None)); // failure: not served
        db.commit_record(rec(a_gpu, 4, Some(0.5)));
        let _ = b; // registered but empty
        let cache = ServingCache::build(&db, 8);
        assert_eq!(cache.num_workloads(), 3);
        assert_eq!(cache.num_records(), 3);
        assert_eq!(cache.lookup(10, "cpu").unwrap().cand_hash, 2);
        assert_eq!(cache.best_latency(10, "cpu"), Some(1.0));
        assert_eq!(cache.best_latency(10, "gpu"), Some(0.5), "targets must not pool");
        assert_eq!(cache.lookup(20, "cpu"), None, "workload with no success is a miss");
        assert_eq!(cache.lookup(99, "cpu"), None);
        // Same answer the database itself would give.
        assert_eq!(cache.lookup(10, "cpu"), db.query_top_k(a, 1).first());
    }

    #[test]
    fn top_k_truncates_per_workload() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 1, "cpu");
        for i in 0..10u64 {
            db.commit_record(rec(a, i, Some((10 - i) as f64)));
        }
        let cache = ServingCache::build(&db, 3);
        let w = cache.lookup_workload(1, "cpu").unwrap();
        assert_eq!(w.top.len(), 3);
        assert_eq!(w.top[0].cand_hash, 9, "best-first order");
        assert_eq!(cache.num_records(), 3);
    }

    #[test]
    fn snapshot_slot_swaps_whole_snapshots() {
        let mut db = InMemoryDb::new();
        let a = db.register_workload("A", 1, "cpu");
        db.commit_record(rec(a, 1, Some(2.0)));
        let slot = SnapshotSlot::new(ServingCache::build(&db, 8));
        let held = slot.get();
        db.commit_record(rec(a, 2, Some(1.0)));
        slot.publish(ServingCache::build(&db, 8));
        // The reader's held snapshot is unchanged; a re-get sees the new one.
        assert_eq!(held.best_latency(1, "cpu"), Some(2.0));
        assert_eq!(slot.get().best_latency(1, "cpu"), Some(1.0));
    }

    #[test]
    fn sharded_snapshots_refresh_only_the_touched_shard() {
        use crate::db::{AnyDb, ShardedDb};
        struct DirGuard(std::path::PathBuf);
        impl Drop for DirGuard {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
        let dir = std::env::temp_dir().join(format!("ms-snapshard-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let _g = DirGuard(dir.clone());
        let mut db = AnyDb::Sharded(ShardedDb::create(&dir, 4).unwrap());
        let a = db.register_workload("A", 5, "cpu"); // 5 % 4 == shard 1
        let b = db.register_workload("B", 6, "cpu"); // 6 % 4 == shard 2
        db.commit_record(rec(a, 1, Some(2.0)));
        db.commit_record(rec(b, 2, Some(3.0)));
        let snaps = ShardedSnapshots::build(&db, 8);
        assert_eq!(snaps.num_shards(), 4);
        assert_eq!(snaps.num_workloads(), 2);
        assert_eq!(snaps.get(5).best_latency(5, "cpu"), Some(2.0));
        assert_eq!(snaps.get(6).best_latency(6, "cpu"), Some(3.0));
        // A write to workload A only republishes shard 1: shard 2's
        // published Arc must be pointer-identical afterwards.
        let shard2_before = snaps.get(6);
        db.commit_record(rec(a, 3, Some(1.0)));
        snaps.refresh(&db, 5, 8);
        assert_eq!(snaps.get(5).best_latency(5, "cpu"), Some(1.0));
        assert!(
            Arc::ptr_eq(&shard2_before, &snaps.get(6)),
            "untouched shard must keep its published snapshot"
        );
        // Single-file databases get the same interface with one slot.
        let single = std::env::temp_dir()
            .join(format!("ms-snapshard-{}-one.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&single);
        let mut one = AnyDb::open(&single).unwrap();
        let w = one.register_workload("A", 5, "cpu");
        one.commit_record(rec(w, 1, Some(4.0)));
        let snaps = ShardedSnapshots::build(&one, 8);
        assert_eq!(snaps.num_shards(), 1);
        assert_eq!(snaps.get(5).best_latency(5, "cpu"), Some(4.0));
        let _ = std::fs::remove_file(&single);
    }

    #[test]
    fn apply_best_replays_real_traces() {
        use crate::search::{Measurer, SimMeasurer};
        use crate::sim::Target;
        use crate::ctx::TuneContext;
        let target = Target::cpu_avx512();
        let prog = crate::workloads::matmul(1, 64, 64, 64);
        let mut db = InMemoryDb::new();
        let wid = db.register_workload(&prog.name, structural_hash(&prog), target.name);
        let ctx = TuneContext::generic(target.clone());
        let mut measurer = SimMeasurer::new(target.clone());
        let mut committed = 0;
        for (i, d) in ctx.generate(&prog, 1).iter().cycle().take(64).enumerate() {
            if committed >= 4 {
                break;
            }
            let Ok(sch) = crate::trace::replay::replay_fresh(&d.trace, &prog, 500 + i as u64) else {
                continue;
            };
            let lat = measurer.measure(&sch.prog);
            db.commit_record(TuningRecord {
                workload: wid,
                trace: sch.trace.clone(),
                latencies: lat.into_iter().collect(),
                target: target.name.to_string(),
                seed: 1,
                round: i as u64,
                cand_hash: structural_hash(&sch.prog),
                sim_version: crate::sim::SIM_VERSION.to_string(),
                rule_set: String::new(),
                objective: String::new(),
            });
            committed += 1;
        }
        let cache = ServingCache::build(&db, 8);
        let best = cache.lookup(structural_hash(&prog), target.name).expect("hit");
        let sch = cache.apply_best(&prog, target.name).expect("best trace must replay");
        assert_eq!(structural_hash(&sch.prog), best.cand_hash);
        // The replayed program reproduces the recorded latency on the
        // deterministic simulator.
        let mut m = SimMeasurer::new(target.clone());
        assert_eq!(m.measure(&sch.prog), best.best_latency());
    }
}
