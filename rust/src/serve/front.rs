//! Batch serving front-end: resolve workload names against a snapshot,
//! report hit/miss with the replayed best latency, and (optionally)
//! tune-on-miss with a bounded budget, committing the new records and
//! refreshing the snapshot so later requests in the batch hit.

use std::path::PathBuf;

use crate::cost_model::GbtCostModel;
use crate::ctx::TuneContext;
use crate::db::{probe_db, Database, FileSignature};
use crate::search::{EvolutionarySearch, Measurer, SearchConfig, SimMeasurer};
use crate::serve::cache::ServingCache;
use crate::sim::Target;
use crate::tir::structural_hash;
use crate::workloads;

/// Serving knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Trial budget for the tune-on-miss fallback; `0` = report-only
    /// (misses are reported but nothing is tuned or committed).
    pub miss_trials: usize,
    /// OS threads for the fallback search (0 = auto); wall-clock only.
    pub threads: usize,
    /// Seed for the fallback search.
    pub seed: u64,
    /// Records kept per workload in the snapshot.
    pub top_k: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            miss_trials: 16,
            threads: 0,
            seed: 42,
            top_k: ServingCache::DEFAULT_TOP_K,
        }
    }
}

/// One served request.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub workload: String,
    /// Snapshot hit (served from records, no search ran).
    pub hit: bool,
    /// Replayed best latency (hit) or tuned best latency (miss with
    /// fallback); `None` for a report-only miss.
    pub latency_s: Option<f64>,
    /// Records backing the hit (0 on miss).
    pub records: usize,
    /// Trials spent by the tune-on-miss fallback (0 on hit).
    pub trials: usize,
}

/// Validate a whole batch of names up front: an unknown name must fail
/// fast, not after expensive tune-on-miss work was already spent (and
/// committed) on the names before it.
fn resolve(names: &[String]) -> Result<Vec<workloads::Workload>, String> {
    names
        .iter()
        .map(|name| {
            workloads::by_name(name)
                .ok_or_else(|| format!("unknown workload {name}; see `metaschedule list`"))
        })
        .collect()
}

/// Serve one workload from the snapshot: a hit replays the best record
/// and re-measures it on the deterministic simulator (the "replayed
/// best latency"); anything else is reported as a miss.
fn serve_one(cache: &ServingCache, w: &workloads::Workload, target: &Target) -> ServeOutcome {
    let prog = (w.build)();
    if let Some(served) = cache.lookup_workload(structural_hash(&prog), target.name) {
        if let Some(sch) = served.apply(&prog) {
            let mut measurer = SimMeasurer::new(target.clone());
            return ServeOutcome {
                workload: w.name.to_string(),
                hit: true,
                latency_s: measurer.measure(&sch.prog),
                records: served.top.len(),
                trials: 0,
            };
        }
    }
    ServeOutcome {
        workload: w.name.to_string(),
        hit: false,
        latency_s: None,
        records: 0,
        trials: 0,
    }
}

/// Report-only batch serving from an already-built snapshot: nothing is
/// tuned or committed, so this works on a [`ServingCache::load`]ed
/// snapshot of a file the process cannot write (read-only mounts).
pub fn serve_snapshot(
    names: &[String],
    target: &Target,
    cache: &ServingCache,
) -> Result<Vec<ServeOutcome>, String> {
    let resolved = resolve(names)?;
    Ok(resolved.iter().map(|w| serve_one(cache, w, target)).collect())
}

/// Serve a batch of workload names from `db` on `target`. Hits come
/// from the snapshot ([`serve_one`] semantics); misses fall back to a
/// bounded [`EvolutionarySearch::tune_db`] whose records commit to
/// `db`, after which the snapshot is rebuilt — a batch naming the same
/// cold workload twice tunes once and hits the second time. With
/// `miss_trials == 0` this degrades to [`serve_snapshot`] over a fresh
/// build (use `serve_snapshot` directly when the file is read-only).
pub fn serve_batch(
    names: &[String],
    target: &Target,
    db: &mut dyn Database,
    cfg: &ServeConfig,
) -> Result<Vec<ServeOutcome>, String> {
    let resolved = resolve(names)?;
    let mut cache = ServingCache::build(&*db, cfg.top_k);
    let mut out = Vec::with_capacity(names.len());
    for w in &resolved {
        let outcome = serve_one(&cache, w, target);
        if outcome.hit || cfg.miss_trials == 0 {
            out.push(outcome);
            continue;
        }
        // Tune-on-miss: bounded search, records committed to the db.
        // Pre-register under the display name so the record lands under
        // the name a later `db top --workload` query will look for.
        let prog = (w.build)();
        db.register_workload(w.name, structural_hash(&prog), target.name);
        let search = EvolutionarySearch::new(SearchConfig {
            num_trials: cfg.miss_trials,
            threads: cfg.threads,
            ..SearchConfig::default()
        });
        let ctx = TuneContext::generic(target.clone());
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        // The search panics when not one candidate in the budget was
        // valid on the target ("no valid schedule found") — with a tiny
        // `miss_trials` that is a legitimate outcome, and it must cost
        // this entry its tune, not the whole batch. Unwinding here is
        // safe to recover from: the db commits record-by-record (the
        // failure records already persisted stay valid and are exactly
        // what the next attempt's dedup wants), and the model/measurer
        // are this iteration's locals.
        let tuned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            search.tune_db(&prog, &ctx, &mut model, &mut measurer, db, cfg.seed)
        }));
        match tuned {
            Ok(r) => out.push(ServeOutcome {
                workload: w.name.to_string(),
                hit: false,
                latency_s: Some(r.best_latency_s),
                records: 0,
                trials: r.trials,
            }),
            Err(payload) => {
                // Only the no-valid-schedule outcome is recoverable;
                // anything else (e.g. the db's fatal append-failure
                // panic) must stay fatal.
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_default();
                if !msg.contains("no valid schedule") {
                    std::panic::resume_unwind(payload);
                }
                crate::log_warn!(
                    "serve: tune-on-miss found no valid schedule for {} in {} trials",
                    w.name,
                    cfg.miss_trials
                );
                out.push(ServeOutcome {
                    workload: w.name.to_string(),
                    hit: false,
                    latency_s: None,
                    records: 0,
                    trials: 0,
                });
            }
        }
        // Refresh the snapshot so the rest of the batch sees the insert.
        cache = ServingCache::build(&*db, cfg.top_k);
    }
    Ok(out)
}

/// Change watcher over a database path of either layout: remembers the
/// last signature set it saw ([`crate::db::probe_db`] — one
/// [`FileSignature`] per constituent file) and reports whether a fresh
/// probe differs. Each per-file probe is one `stat` plus three bounded
/// reads — cheap enough to poll at serving frequency — and the content
/// fingerprint catches even a same-length compaction rewrite landing in
/// the same mtime tick. For a sharded db every shard is probed, so a
/// write to `shard-07.jsonl` registers as a change even when
/// `shard-00.jsonl` is untouched; "signature changed" is a reliable
/// "there is something new to index" signal (the in-process equivalent
/// is [`crate::db::JsonFileDb::commit_counter`]).
pub struct DbWatcher {
    path: PathBuf,
    last: Option<Vec<Option<FileSignature>>>,
}

impl DbWatcher {
    /// Start watching `path`, treating its current state as seen.
    pub fn new(path: impl Into<PathBuf>) -> DbWatcher {
        let path = path.into();
        let last = probe_db(&path);
        DbWatcher { path, last }
    }

    /// Whether any constituent file changed since the last call (or
    /// construction); updates the remembered signatures.
    pub fn changed(&mut self) -> bool {
        let now = probe_db(&self.path);
        if now != self.last {
            self.last = now;
            true
        } else {
            false
        }
    }
}

/// Serve `names` read-only from `path`, then keep watching: whenever the
/// file's signature changes, reload the snapshot and re-serve, invoking
/// `on_serve(refresh_count, outcomes)` each time (round 0 is the initial
/// serve). `max_refreshes = None` runs until the process is killed (the
/// CLI `serve --watch` mode); tests bound it. Returns the number of
/// refreshes performed.
///
/// This is refresh-on-change for the read path (ROADMAP "serving cache
/// invalidation push"): a long-running server no longer rebuilds on a
/// timer — it pays one `stat` per poll and a snapshot rebuild only when
/// a tuner actually committed.
pub fn serve_watch(
    names: &[String],
    target: &Target,
    path: &str,
    top_k: usize,
    poll_ms: u64,
    max_refreshes: Option<usize>,
    on_serve: &mut dyn FnMut(usize, &[ServeOutcome]),
) -> Result<usize, String> {
    let serve_now = |names: &[String]| -> Result<Vec<ServeOutcome>, String> {
        let (cache, _skipped) = ServingCache::load(path, top_k)?;
        serve_snapshot(names, target, &cache)
    };
    let mut watcher = DbWatcher::new(path);
    let outcomes = serve_now(names)?;
    on_serve(0, &outcomes);
    let mut refreshes = 0usize;
    loop {
        if let Some(max) = max_refreshes {
            if refreshes >= max {
                return Ok(refreshes);
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(poll_ms.max(1)));
        if watcher.changed() {
            let outcomes = serve_now(names)?;
            refreshes += 1;
            on_serve(refreshes, &outcomes);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::InMemoryDb;

    #[test]
    fn report_only_miss_commits_nothing() {
        let mut db = InMemoryDb::new();
        let cfg = ServeConfig { miss_trials: 0, ..ServeConfig::default() };
        let out = serve_batch(&["GMM".to_string()], &Target::cpu_avx512(), &mut db, &cfg).unwrap();
        assert_eq!(out.len(), 1);
        assert!(!out[0].hit);
        assert_eq!(out[0].latency_s, None);
        assert_eq!(db.num_records(), 0);
        assert_eq!(db.workload_entries().len(), 0);
    }

    #[test]
    fn miss_tunes_then_same_batch_hits() {
        let mut db = InMemoryDb::new();
        let cfg = ServeConfig { miss_trials: 16, seed: 3, ..ServeConfig::default() };
        let names = vec!["GMM".to_string(), "GMM".to_string()];
        let out = serve_batch(&names, &Target::cpu_avx512(), &mut db, &cfg).unwrap();
        assert!(!out[0].hit, "cold db must miss");
        assert!(out[0].trials > 0);
        assert!(out[1].hit, "second request must hit the refreshed snapshot");
        assert_eq!(out[1].trials, 0);
        // The hit's replayed latency equals the tuned best (deterministic
        // simulator, same program).
        assert_eq!(out[1].latency_s, out[0].latency_s);
        assert!(db.num_records() > 0, "miss fallback must commit its records");
    }

    #[test]
    fn watcher_sees_same_length_rewrite() {
        // The serve --watch staleness bug: a rewrite that preserves the
        // byte length (and, on coarse-mtime filesystems, the mtime tick)
        // must still register as a change via the content fingerprint.
        let path = std::env::temp_dir()
            .join(format!("ms-watcher-rewrite-{}.jsonl", std::process::id()));
        std::fs::write(&path, "abcdef\n").unwrap();
        let mut w = DbWatcher::new(&path);
        assert!(!w.changed(), "no write, no change");
        std::fs::write(&path, "fedcba\n").unwrap();
        assert!(w.changed(), "same-length rewrite not detected");
        assert!(!w.changed(), "change must latch");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn watcher_covers_every_shard() {
        use crate::db::{ShardedDb, TuningRecord};
        use crate::trace::Trace;
        struct DirGuard(std::path::PathBuf);
        impl Drop for DirGuard {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
        let dir = std::env::temp_dir().join(format!("ms-watcher-shards-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let _g = DirGuard(dir.clone());
        let mut db = ShardedDb::create(&dir, 8).unwrap();
        let mut w = DbWatcher::new(&dir);
        assert!(!w.changed(), "no write, no change");
        // Write to the LAST shard only (7 % 8 == 7): the watcher must
        // still see it even though shard 0 is untouched.
        let id = db.register_workload("late", 7, "cpu");
        db.commit_record(TuningRecord {
            workload: id,
            trace: Trace { insts: vec![] },
            latencies: vec![1.0],
            target: "cpu".into(),
            seed: 0,
            round: 0,
            cand_hash: 1,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        });
        assert!(w.changed(), "a write to shard 7 must invalidate the watcher");
        assert!(!w.changed(), "change must latch");
    }

    #[test]
    fn unknown_workload_fails_fast_before_any_tuning() {
        let mut db = InMemoryDb::new();
        // The bad name comes AFTER a tunable one: validation must reject
        // the whole batch before any tune-on-miss work is spent.
        let names = vec!["GMM".to_string(), "NOPE".to_string()];
        let err =
            serve_batch(&names, &Target::cpu_avx512(), &mut db, &ServeConfig::default()).unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
        assert_eq!(db.num_records(), 0, "no tuning may run when the batch is invalid");
        assert_eq!(db.workload_entries().len(), 0);
    }
}
