//! Zero-dependency HTTP/1.1 serving front (`serve --listen <addr>`).
//!
//! A thin network skin over the same serving pipeline the CLI batch mode
//! uses: the read path answers from per-shard lock-free snapshots
//! ([`crate::serve::ShardedSnapshots`] — a hit never takes the writer
//! mutex), and a miss falls back to bounded tune-on-miss through
//! [`crate::serve::serve_batch`] behind admission control, committing to
//! the shared database and republishing only the shard it wrote.
//!
//! Everything is `std::net` + scoped threads — no async runtime, no
//! HTTP library. Requests are parsed line-by-line (request line, then
//! headers until the blank line); responses are `Connection: close` with
//! an explicit `Content-Length`, and every body — including every error
//! — is a single JSON line, so a scripted client can always read exactly
//! one line and move on. A malformed request earns a `400` error line
//! and costs that connection only; the server keeps serving.
//!
//! # Protocol
//!
//! ```text
//! GET /lookup?workload=NAME[&target=NAME]   one workload: hit from the
//!                                           snapshot, else tune-on-miss
//!                                           (429 when over the inflight
//!                                           budget; "tune":"disabled"
//!                                           when miss_trials == 0)
//! POST /batch                               body = one workload name per
//!                                           line; report-only lookups,
//!                                           one JSON line each
//! GET /healthz                              liveness probe
//! GET /stats                                counters + snapshot sizes
//! GET /shutdown                             graceful shutdown: stop
//!                                           accepting, drain, exit
//! ```
//!
//! # Concurrency shape
//!
//! The accept loop is nonblocking and pushes connections into a bounded
//! [`crate::search::parallel::BoundedQueue`] drained by a fixed pool of
//! worker threads — the queue is both the request batching buffer and
//! the backpressure valve (a full queue blocks accepting, it never grows
//! an unbounded backlog). Tune-on-miss admission is a single atomic
//! inflight counter checked before the (serialized) tuning section, so
//! at most [`HttpConfig::max_inflight_tunes`] requests can be paying for
//! search at once; everyone else gets an immediate `429` instead of
//! queueing behind a long tune.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::db::AnyDb;
use crate::search::parallel::BoundedQueue;
use crate::serve::cache::{ServingCache, ShardedSnapshots};
use crate::serve::front::{serve_batch, ServeConfig};
use crate::sim::Target;
use crate::telemetry::{self, Counter, Gauge, Histogram};
use crate::tir::structural_hash;
use crate::util::json::Json;
use crate::workloads;

/// Network-front knobs (wrapping the serving knobs in
/// [`ServeConfig`]).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Listen address, e.g. `127.0.0.1:8080` (port 0 picks a free port;
    /// see [`HttpServer::local_addr`]).
    pub addr: String,
    /// Worker threads draining the connection queue.
    pub workers: usize,
    /// Connection-queue capacity — the request batching window and the
    /// backpressure bound (accepting blocks when full).
    pub max_pending: usize,
    /// Tune-on-miss admission budget: misses beyond this many concurrent
    /// tunes are answered `429` instead of queueing behind a search.
    pub max_inflight_tunes: usize,
    /// Opt-in structured access log: one JSON line per request
    /// (`method`, `path`, `status`, `hit`, `micros`) appended to this
    /// file. `None` (the default) logs nothing.
    pub access_log: Option<String>,
    /// The serving knobs shared with the CLI front (trial budget, seed,
    /// snapshot top-k).
    pub serve: ServeConfig,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            max_pending: 64,
            max_inflight_tunes: 1,
            access_log: None,
            serve: ServeConfig::default(),
        }
    }
}

/// What a finished [`HttpServer::run`] saw, for the CLI summary line and
/// the integration tests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HttpReport {
    /// Requests that parsed well enough to be routed.
    pub requests: usize,
    /// `/lookup`s answered from a snapshot.
    pub hits: usize,
    /// `/lookup`s that missed every snapshot record.
    pub misses: usize,
    /// Misses that ran the tune-on-miss fallback.
    pub tuned: usize,
    /// Misses bounced by admission control (`429`).
    pub tune_rejected: usize,
    /// Connections dropped with a `4xx` error line (malformed request,
    /// unknown route/workload).
    pub bad_requests: usize,
}

/// Live counters shared across workers; folded into an [`HttpReport`]
/// when the server drains.
#[derive(Default)]
struct Stats {
    requests: AtomicUsize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    tuned: AtomicUsize,
    tune_rejected: AtomicUsize,
    bad_requests: AtomicUsize,
}

impl Stats {
    fn report(&self) -> HttpReport {
        HttpReport {
            requests: self.requests.load(Ordering::SeqCst),
            hits: self.hits.load(Ordering::SeqCst),
            misses: self.misses.load(Ordering::SeqCst),
            tuned: self.tuned.load(Ordering::SeqCst),
            tune_rejected: self.tune_rejected.load(Ordering::SeqCst),
            bad_requests: self.bad_requests.load(Ordering::SeqCst),
        }
    }
}

/// Cached [`telemetry::global`] handles mirroring [`Stats`] for the
/// `/metrics` endpoint. Cumulative across every server run in the
/// process (the registry is process-global), unlike `Stats`, which is
/// this run's report — so tests assert deltas, not absolute values.
struct ServeTelemetry {
    requests: Arc<Counter>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    tuned: Arc<Counter>,
    throttled: Arc<Counter>,
    bad_requests: Arc<Counter>,
    request_micros: Arc<Histogram>,
    inflight: Arc<Gauge>,
}

impl ServeTelemetry {
    fn from_global() -> ServeTelemetry {
        let m = telemetry::global();
        ServeTelemetry {
            requests: m.counter("serve_requests_total", "HTTP requests that parsed and were routed"),
            hits: m.counter("serve_hits_total", "lookups answered from a serving snapshot"),
            misses: m.counter("serve_misses_total", "lookups that missed every snapshot record"),
            tuned: m.counter("serve_tuned_total", "misses that ran the tune-on-miss fallback"),
            throttled: m
                .counter("serve_throttled_total", "misses bounced by admission control (HTTP 429)"),
            bad_requests: m
                .counter("serve_bad_requests_total", "connections answered with a 4xx error line"),
            request_micros: m
                .histogram("serve_request_micros", "request handling latency in microseconds"),
            inflight: m.gauge("serve_inflight_tunes", "tune-on-miss searches currently running"),
        }
    }
}

/// A parsed request: method + path + decoded query pairs + body.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Request {
    method: String,
    path: String,
    query: Vec<(String, String)>,
    body: String,
}

impl Request {
    fn query_get(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// One response: status + single-JSON-line body (NDJSON for `/batch`).
struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
    /// Snapshot hit/miss verdict for the access log (`None` on routes
    /// where hit/miss has no meaning — health, stats, batch).
    hit: Option<bool>,
}

impl Response {
    /// A one-JSON-line response; the trailing newline is the line
    /// delimiter scripted clients read to.
    fn json(status: u16, j: Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: format!("{}\n", j.to_string()),
            hit: None,
        }
    }

    fn error(status: u16, msg: &str) -> Response {
        Response::json(status, Json::obj(vec![("error", Json::str(msg))]))
    }

    /// Stamp the hit/miss verdict for the access log.
    fn with_hit(mut self, hit: bool) -> Response {
        self.hit = Some(hit);
        self
    }
}

/// Largest request body `/batch` accepts — a denial-of-service guard,
/// far above any realistic workload list.
const MAX_BODY_BYTES: usize = 1 << 20;

/// Decode `%XX` sequences and `+`-for-space in a query component.
/// Lenient: a malformed escape passes through literally (the workload
/// name lookup will reject it with a clean 404).
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                let hex = bytes.get(i + 1..i + 3).and_then(|h| {
                    std::str::from_utf8(h).ok().and_then(|h| u8::from_str_radix(h, 16).ok())
                });
                match hex {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Split `/path?a=1&b=2` into the path and decoded query pairs.
fn split_query(target: &str) -> (String, Vec<(String, String)>) {
    match target.split_once('?') {
        None => (target.to_string(), Vec::new()),
        Some((path, qs)) => {
            let pairs = qs
                .split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (percent_decode(k), percent_decode(v)),
                    None => (percent_decode(kv), String::new()),
                })
                .collect();
            (path.to_string(), pairs)
        }
    }
}

/// Parse one HTTP/1.1 request line-by-line from `r`: request line,
/// headers until the blank line, then `Content-Length` bytes of body for
/// `POST`. Errors are protocol violations the caller answers with a
/// `400` error line.
fn read_request<R: BufRead>(r: &mut R) -> Result<Request, String> {
    let mut line = String::new();
    if r.read_line(&mut line).map_err(|e| format!("read request line: {e}"))? == 0 {
        return Err("empty request (connection closed before a request line)".into());
    }
    let line = line.trim_end_matches(['\r', '\n']);
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => return Err(format!("malformed request line {line:?}")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(format!("unsupported protocol version {version:?}"));
    }
    if method != "GET" && method != "POST" {
        return Err(format!("unsupported method {method:?}"));
    }
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        if r.read_line(&mut header).map_err(|e| format!("read header: {e}"))? == 0 {
            return Err("connection closed inside the header block".into());
        }
        let header = header.trim_end_matches(['\r', '\n']);
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            return Err(format!("malformed header line {header:?}"));
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("bad Content-Length {:?}", value.trim()))?;
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(format!("body of {content_length} bytes exceeds the {MAX_BODY_BYTES} cap"));
    }
    let mut body = String::new();
    if method == "POST" && content_length > 0 {
        let mut buf = vec![0u8; content_length];
        r.read_exact(&mut buf).map_err(|e| format!("read body: {e}"))?;
        body = String::from_utf8_lossy(&buf).into_owned();
    }
    let (path, query) = split_query(target);
    Ok(Request { method: method.to_string(), path, query, body })
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let reason = match resp.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {} {reason}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

/// Everything a worker needs, borrowed for the scope of one `run`.
struct ServerCtx<'a> {
    cfg: &'a HttpConfig,
    target: &'a Target,
    snapshots: &'a ShardedSnapshots,
    db: &'a Mutex<AnyDb>,
    shutdown: &'a AtomicBool,
    inflight: &'a AtomicUsize,
    stats: &'a Stats,
    tel: &'a ServeTelemetry,
    /// Open access-log file, when `--access-log` asked for one.
    access_log: Option<&'a Mutex<std::fs::File>>,
}

/// The zero-dep HTTP server. [`Self::bind`], then [`Self::run`] with the
/// database to serve; `run` blocks until a `/shutdown` request and
/// returns the traffic report.
pub struct HttpServer {
    listener: TcpListener,
    cfg: HttpConfig,
    target: Target,
}

impl HttpServer {
    /// Bind the listen address (nonblocking, so shutdown can interrupt
    /// the accept loop without signal handling).
    pub fn bind(cfg: HttpConfig, target: Target) -> Result<HttpServer, String> {
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("set_nonblocking on {}: {e}", cfg.addr))?;
        Ok(HttpServer { listener, cfg, target })
    }

    /// The bound address (resolves a `:0` port request).
    pub fn local_addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Serve `db` until a `/shutdown` request: accept loop in the calling
    /// thread, workers on scoped threads, graceful drain on exit. The
    /// accept loop stops first, then the connection queue closes, then
    /// every queued connection is still answered before the workers
    /// join — no request that made it into the queue is dropped.
    pub fn run(self, db: AnyDb) -> HttpReport {
        let snapshots = ShardedSnapshots::build(&db, self.cfg.serve.top_k);
        let db = Mutex::new(db);
        let shutdown = AtomicBool::new(false);
        let inflight = AtomicUsize::new(0);
        let stats = Stats::default();
        let tel = ServeTelemetry::from_global();
        let access_log = self.cfg.access_log.as_ref().map(|path| {
            let f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .unwrap_or_else(|e| panic!("open access log {path}: {e}"));
            Mutex::new(f)
        });
        let queue: BoundedQueue<TcpStream> = BoundedQueue::new(self.cfg.max_pending.max(1));
        let ctx = ServerCtx {
            cfg: &self.cfg,
            target: &self.target,
            snapshots: &snapshots,
            db: &db,
            shutdown: &shutdown,
            inflight: &inflight,
            stats: &stats,
            tel: &tel,
            access_log: access_log.as_ref(),
        };
        std::thread::scope(|s| {
            for _ in 0..self.cfg.workers.max(1) {
                let ctx = &ctx;
                let queue = &queue;
                s.spawn(move || {
                    while let Some(stream) = queue.pop() {
                        handle_conn(stream, ctx);
                    }
                });
            }
            loop {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        // Workers read blocking with a timeout, so a
                        // stalled client cannot pin a worker forever.
                        let _ = stream.set_nonblocking(false);
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
                        if !queue.push(stream) {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(2)),
                }
            }
            queue.close();
        });
        stats.report()
    }
}

/// Serve one connection: parse, route, answer, close. Every failure mode
/// becomes an error line on this connection; nothing here can take the
/// server down.
fn handle_conn(mut stream: TcpStream, ctx: &ServerCtx) {
    let t0 = Instant::now();
    let parsed = {
        let mut reader = BufReader::new(&mut stream);
        read_request(&mut reader)
    };
    let (method, path) = match &parsed {
        Ok(req) => (req.method.clone(), req.path.clone()),
        Err(_) => ("-".to_string(), "-".to_string()),
    };
    let response = match parsed {
        Ok(req) => {
            ctx.stats.requests.fetch_add(1, Ordering::SeqCst);
            ctx.tel.requests.inc();
            route(ctx, &req)
        }
        Err(e) => Response::error(400, &e),
    };
    if response.status >= 400 && response.status != 429 {
        ctx.stats.bad_requests.fetch_add(1, Ordering::SeqCst);
        ctx.tel.bad_requests.inc();
    }
    let _ = write_response(&mut stream, &response);
    let micros = t0.elapsed().as_micros() as u64;
    ctx.tel.request_micros.observe(micros);
    if let Some(log) = ctx.access_log {
        let line = Json::obj(vec![
            ("method", Json::str(&method)),
            ("path", Json::str(&path)),
            ("status", Json::num(response.status as f64)),
            ("hit", response.hit.map_or(Json::Null, Json::Bool)),
            ("micros", Json::num(micros as f64)),
        ]);
        let mut f = log.lock().unwrap();
        let _ = writeln!(f, "{}", line.to_string());
    }
}

fn route(ctx: &ServerCtx, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(200, Json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/stats") => {
            let r = ctx.stats.report();
            Response::json(
                200,
                Json::obj(vec![
                    ("requests", Json::num(r.requests as f64)),
                    ("hits", Json::num(r.hits as f64)),
                    ("misses", Json::num(r.misses as f64)),
                    ("tuned", Json::num(r.tuned as f64)),
                    ("tune_rejected", Json::num(r.tune_rejected as f64)),
                    ("bad_requests", Json::num(r.bad_requests as f64)),
                    ("shards", Json::num(ctx.snapshots.num_shards() as f64)),
                    ("workloads", Json::num(ctx.snapshots.num_workloads() as f64)),
                    ("records", Json::num(ctx.snapshots.num_records() as f64)),
                ]),
            )
        }
        ("GET", "/metrics") => Response {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            body: telemetry::global().render(),
            hit: None,
        },
        ("GET", "/shutdown") => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            Response::json(
                200,
                Json::obj(vec![("ok", Json::Bool(true)), ("shutdown", Json::Bool(true))]),
            )
        }
        ("GET", "/lookup") => lookup(ctx, req),
        ("POST", "/batch") => batch(ctx, req),
        (m, p) => Response::error(404, &format!("no route {m} {p}")),
    }
}

/// The hit path of `/lookup`: snapshot probe only, no locks. Returns
/// `None` when the snapshot has nothing served for this workload.
fn snapshot_hit(cache: &ServingCache, name: &str, shash: u64, target: &Target) -> Option<Response> {
    let served = cache.lookup_workload(shash, target.name)?;
    let best = served.top.first()?;
    Some(Response::json(
        200,
        Json::obj(vec![
            ("workload", Json::str(name)),
            ("target", Json::str(target.name)),
            ("hit", Json::Bool(true)),
            ("latency_s", best.best_latency().map_or(Json::Null, Json::num)),
            ("records", Json::num(served.top.len() as f64)),
        ]),
    ))
}

fn lookup(ctx: &ServerCtx, req: &Request) -> Response {
    let Some(name) = req.query_get("workload") else {
        return Response::error(400, "missing ?workload= parameter");
    };
    let target = match req.query_get("target") {
        None => ctx.target.clone(),
        Some(t) => match Target::by_name(t) {
            Some(t) => t,
            None => return Response::error(400, &format!("unknown target {t}")),
        },
    };
    let Some(w) = workloads::by_name(name) else {
        return Response::error(404, &format!("unknown workload {name}"));
    };
    let prog = (w.build)();
    let shash = structural_hash(&prog);
    if let Some(hit) = snapshot_hit(&ctx.snapshots.get(shash), name, shash, &target) {
        ctx.stats.hits.fetch_add(1, Ordering::SeqCst);
        ctx.tel.hits.inc();
        return hit.with_hit(true);
    }
    ctx.stats.misses.fetch_add(1, Ordering::SeqCst);
    ctx.tel.misses.inc();
    if ctx.cfg.serve.miss_trials == 0 {
        return Response::json(
            200,
            Json::obj(vec![
                ("workload", Json::str(name)),
                ("target", Json::str(target.name)),
                ("hit", Json::Bool(false)),
                ("tune", Json::str("disabled")),
            ]),
        )
        .with_hit(false);
    }
    // Admission control: reserve an inflight slot or bounce. The
    // fetch_add/check/fetch_sub dance is race-free because every path
    // out of this function releases exactly the slot it took.
    let slot = ctx.inflight.fetch_add(1, Ordering::SeqCst);
    ctx.tel.inflight.add(1);
    if slot >= ctx.cfg.max_inflight_tunes {
        ctx.inflight.fetch_sub(1, Ordering::SeqCst);
        ctx.tel.inflight.add(-1);
        ctx.stats.tune_rejected.fetch_add(1, Ordering::SeqCst);
        ctx.tel.throttled.inc();
        return Response::json(
            429,
            Json::obj(vec![
                ("workload", Json::str(name)),
                ("target", Json::str(target.name)),
                ("hit", Json::Bool(false)),
                ("error", Json::str("tune-on-miss budget exhausted, retry later")),
            ]),
        )
        .with_hit(false);
    }
    let tuned = {
        let mut db = ctx.db.lock().unwrap();
        let result = serve_batch(&[name.to_string()], &target, &mut *db, &ctx.cfg.serve);
        if result.is_ok() {
            // Republish only the shard this tune wrote, while we still
            // hold the writer lock (readers of other shards are
            // untouched either way).
            ctx.snapshots.refresh(&db, shash, ctx.cfg.serve.top_k);
        }
        result
    };
    ctx.inflight.fetch_sub(1, Ordering::SeqCst);
    ctx.tel.inflight.add(-1);
    match tuned {
        Err(e) => Response::error(400, &e),
        Ok(outcomes) => {
            ctx.stats.tuned.fetch_add(1, Ordering::SeqCst);
            ctx.tel.tuned.inc();
            let o = outcomes.into_iter().next();
            Response::json(
                200,
                Json::obj(vec![
                    ("workload", Json::str(name)),
                    ("target", Json::str(target.name)),
                    ("hit", Json::Bool(false)),
                    ("tuned", Json::Bool(true)),
                    (
                        "latency_s",
                        o.as_ref().and_then(|o| o.latency_s).map_or(Json::Null, Json::num),
                    ),
                    ("trials", Json::num(o.map_or(0, |o| o.trials) as f64)),
                ]),
            )
            .with_hit(false)
        }
    }
}

/// `POST /batch`: one workload name per body line, answered report-only
/// (no tuning) with one JSON line per name — the batched read path for
/// scripted clients replaying traffic.
fn batch(ctx: &ServerCtx, req: &Request) -> Response {
    let mut lines = Vec::new();
    for name in req.body.lines().map(str::trim).filter(|l| !l.is_empty()) {
        let line = match workloads::by_name(name) {
            None => Json::obj(vec![
                ("workload", Json::str(name)),
                ("error", Json::str("unknown workload")),
            ]),
            Some(w) => {
                let prog = (w.build)();
                let shash = structural_hash(&prog);
                let cache = ctx.snapshots.get(shash);
                match cache.lookup(shash, ctx.target.name).and_then(|r| r.best_latency()) {
                    Some(lat) => {
                        ctx.stats.hits.fetch_add(1, Ordering::SeqCst);
                        ctx.tel.hits.inc();
                        Json::obj(vec![
                            ("workload", Json::str(name)),
                            ("hit", Json::Bool(true)),
                            ("latency_s", Json::num(lat)),
                        ])
                    }
                    None => {
                        ctx.stats.misses.fetch_add(1, Ordering::SeqCst);
                        ctx.tel.misses.inc();
                        Json::obj(vec![("workload", Json::str(name)), ("hit", Json::Bool(false))])
                    }
                }
            }
        };
        lines.push(line.to_string());
    }
    let mut body = lines.join("\n");
    body.push('\n');
    Response { status: 200, content_type: "application/x-ndjson", body, hit: None }
}

/// Blocking one-shot HTTP client for tests and the traffic bench: send
/// `request_bytes` to `addr`, return the raw response. Deliberately dumb
/// — it writes whatever it is given, which is how the malformed-request
/// tests speak raw bytes.
pub fn http_roundtrip(addr: &str, request_bytes: &[u8]) -> Result<String, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| format!("timeout: {e}"))?;
    stream.write_all(request_bytes).map_err(|e| format!("send: {e}"))?;
    let mut out = String::new();
    stream.read_to_string(&mut out).map_err(|e| format!("recv: {e}"))?;
    Ok(out)
}

/// Build a plain `GET` request for [`http_roundtrip`].
pub fn get_request(path_and_query: &str) -> Vec<u8> {
    format!("GET {path_and_query} HTTP/1.1\r\nHost: metaschedule\r\nConnection: close\r\n\r\n")
        .into_bytes()
}

/// The body of a response returned by [`http_roundtrip`] (everything
/// after the header block), plus the status code.
pub fn split_response(raw: &str) -> Result<(u16, &str), String> {
    let status = raw
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split_whitespace().next())
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| format!("unparseable status line in {raw:?}"))?;
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .ok_or_else(|| format!("no header/body separator in {raw:?}"))?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &str) -> Request {
        let mut r = std::io::Cursor::new(raw.as_bytes());
        read_request(&mut r).unwrap()
    }

    #[test]
    fn parses_request_line_query_and_headers() {
        let req = parse(
            "GET /lookup?workload=GMM&target=cpu-avx512 HTTP/1.1\r\nHost: x\r\nX-Extra: 1\r\n\r\n",
        );
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/lookup");
        assert_eq!(req.query_get("workload"), Some("GMM"));
        assert_eq!(req.query_get("target"), Some("cpu-avx512"));
        assert_eq!(req.query_get("absent"), None);
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let req = parse(
            "POST /batch HTTP/1.1\r\nContent-Length: 8\r\n\r\nGMM\nC1D\n",
        );
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, "GMM\nC1D\n");
    }

    #[test]
    fn percent_decoding_handles_escapes_and_plus() {
        assert_eq!(percent_decode("a+b"), "a b");
        assert_eq!(percent_decode("a%2Fb"), "a/b");
        assert_eq!(percent_decode("a%2fb"), "a/b");
        assert_eq!(percent_decode("a%zzb"), "a%zzb", "bad escape passes through");
        assert_eq!(percent_decode("trailing%2"), "trailing%2");
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        let cases: &[&str] = &[
            "",                                        // closed before a request line
            "BOGUS\r\n\r\n",                           // not a request line
            "GET /x\r\n\r\n",                          // missing version
            "GET /x SPDY/3\r\n\r\n",                   // wrong protocol
            "PUT /x HTTP/1.1\r\n\r\n",                 // unsupported method
            "GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n", // malformed header
            "POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n", // bad length
        ];
        for raw in cases {
            let mut r = std::io::Cursor::new(raw.as_bytes());
            assert!(read_request(&mut r).is_err(), "{raw:?} must not parse");
        }
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let mut r = std::io::Cursor::new(huge.as_bytes());
        assert!(read_request(&mut r).unwrap_err().contains("cap"));
    }

    #[test]
    fn response_helpers_frame_one_json_line() {
        let resp = Response::error(404, "nope");
        assert!(resp.body.ends_with('\n'));
        assert_eq!(resp.body.lines().count(), 1);
        let j = Json::parse(resp.body.trim()).unwrap();
        assert_eq!(j.get("error").and_then(Json::as_str), Some("nope"));
        let (status, body) =
            split_response("HTTP/1.1 429 Too Many Requests\r\nContent-Length: 3\r\n\r\nabc")
                .unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, "abc");
    }
}
