//! Read-optimized serving layer over the tuning database (the ROADMAP's
//! "serve heavy traffic" path, and the payoff of the paper's
//! learning-driven framework: tuned schedules are *reused*, not
//! re-searched, when a known workload arrives).
//!
//! The write path ([`crate::db`]) is an append-only JSONL log — perfect
//! for crash-safe tuning, wrong for serving: answering "best schedule
//! for this workload hash" from the log means replaying the whole file.
//! This module is the read path:
//!
//! - [`ServingCache`] — an immutable, hash-indexed snapshot built once
//!   from a [`crate::db::Database`] (or loaded read-only from a JSONL
//!   file). `lookup` is a `HashMap` probe + a short target scan: no file
//!   I/O, no JSONL parsing, no locking. Share it across threads as a
//!   plain `Arc<ServingCache>`.
//! - [`SnapshotSlot`] — the swap point between the write and read paths:
//!   a publisher (tuner, compactor) builds a fresh snapshot and
//!   [`SnapshotSlot::publish`]es it; readers [`SnapshotSlot::get`] an
//!   `Arc` and do every subsequent lookup lock-free on a consistent
//!   snapshot. Readers see either the pre- or post-publish cache in its
//!   entirety, never a torn mix.
//! - [`ShardedSnapshots`] — one [`SnapshotSlot`] per shard of a
//!   [`crate::db::ShardedDb`], routed by the same structural-hash
//!   function as the shards themselves: a tune-on-miss republishes only
//!   the shard it wrote, readers of every other shard are untouched.
//! - [`serve_batch`] — the batch front-end behind the `serve` CLI
//!   subcommand: resolve workload names, report hit/miss + the replayed
//!   best latency, and fall back to a bounded tune-on-miss (reusing
//!   [`crate::search::EvolutionarySearch`]'s database path) that commits
//!   its records and refreshes the snapshot.
//! - [`HttpServer`] — the zero-dependency HTTP/1.1 network front
//!   (`serve --listen <addr>`) over the same pieces: lock-free snapshot
//!   hits, admission-controlled tune-on-miss, request batching through a
//!   bounded connection queue, graceful shutdown. See [`net`] for the
//!   wire protocol.
//!
//! Snapshot lifecycle: tune into a JSONL db -> (optionally) `db compact`
//! it -> build/load a [`ServingCache`] -> serve lookups -> on db growth,
//! build a fresh cache and publish it through the [`SnapshotSlot`].
//! *When* to rebuild is no longer timer-guesswork: [`DbWatcher`] probes
//! every constituent file's signature ([`crate::db::probe_db`] — for a
//! sharded db that covers each shard, so a write to `shard-07.jsonl`
//! invalidates even when `shard-00.jsonl` is untouched) and
//! [`serve_watch`] reloads on change (`serve --watch`); an in-process
//! publisher can compare [`crate::db::JsonFileDb::commit_counter`]
//! against the value captured at its last snapshot build.

pub mod cache;
pub mod front;
pub mod net;

pub use cache::{ServedWorkload, ServingCache, ShardedSnapshots, SnapshotSlot};
pub use front::{serve_batch, serve_snapshot, serve_watch, DbWatcher, ServeConfig, ServeOutcome};
pub use net::{HttpConfig, HttpReport, HttpServer};
