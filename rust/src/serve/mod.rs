//! Read-optimized serving layer over the tuning database (the ROADMAP's
//! "serve heavy traffic" path, and the payoff of the paper's
//! learning-driven framework: tuned schedules are *reused*, not
//! re-searched, when a known workload arrives).
//!
//! The write path ([`crate::db`]) is an append-only JSONL log — perfect
//! for crash-safe tuning, wrong for serving: answering "best schedule
//! for this workload hash" from the log means replaying the whole file.
//! This module is the read path:
//!
//! - [`ServingCache`] — an immutable, hash-indexed snapshot built once
//!   from a [`crate::db::Database`] (or loaded read-only from a JSONL
//!   file). `lookup` is a `HashMap` probe + a short target scan: no file
//!   I/O, no JSONL parsing, no locking. Share it across threads as a
//!   plain `Arc<ServingCache>`.
//! - [`SnapshotSlot`] — the swap point between the write and read paths:
//!   a publisher (tuner, compactor) builds a fresh snapshot and
//!   [`SnapshotSlot::publish`]es it; readers [`SnapshotSlot::get`] an
//!   `Arc` and do every subsequent lookup lock-free on a consistent
//!   snapshot. Readers see either the pre- or post-publish cache in its
//!   entirety, never a torn mix.
//! - [`serve_batch`] — the batch front-end behind the `serve` CLI
//!   subcommand: resolve workload names, report hit/miss + the replayed
//!   best latency, and fall back to a bounded tune-on-miss (reusing
//!   [`crate::search::EvolutionarySearch`]'s database path) that commits
//!   its records and refreshes the snapshot.
//!
//! Snapshot lifecycle: tune into a JSONL db -> (optionally) `db compact`
//! it -> build/load a [`ServingCache`] -> serve lookups -> on db growth,
//! build a fresh cache and publish it through the [`SnapshotSlot`].
//! *When* to rebuild is no longer timer-guesswork: [`DbWatcher`] probes
//! the file's `(len, mtime)` signature ([`crate::db::probe`]) and
//! [`serve_watch`] reloads on change (`serve --watch`); an in-process
//! publisher can compare [`crate::db::JsonFileDb::commit_counter`]
//! against the value captured at its last snapshot build.

pub mod cache;
pub mod front;

pub use cache::{ServedWorkload, ServingCache, SnapshotSlot};
pub use front::{serve_batch, serve_snapshot, serve_watch, DbWatcher, ServeConfig, ServeOutcome};
