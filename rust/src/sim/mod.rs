//! Deterministic analytical hardware simulator.
//!
//! Stands in for the paper's measurement testbeds (AWS C5.9xlarge CPU and
//! RTX 3070 GPU — see DESIGN.md §3 for the substitution argument). Exposes
//! `f(e)`: scheduled tensor program -> estimated latency on a [`Target`].

pub mod model;
pub mod target;

pub use model::{simulate, LatencyReport, SimError};
pub use target::{CacheLevel, Target, TargetKind};
