//! Deterministic analytical hardware simulator.
//!
//! Stands in for the paper's measurement testbeds (AWS C5.9xlarge CPU and
//! RTX 3070 GPU — see DESIGN.md §3 for the substitution argument). Exposes
//! `f(e)`: scheduled tensor program -> estimated latency on a [`Target`].

pub mod model;
pub mod target;

pub use model::{simulate, LatencyReport, SimError};
pub use target::{CacheLevel, Target, TargetKind};

/// Version stamp of the analytical latency model, written into every
/// [`crate::db::TuningRecord`] at commit time. Bump this when the cost
/// formulas change in a way that invalidates previously-recorded
/// latencies: `db stats` reports the version mix, so stale generations
/// are visible (and can be compacted away) instead of silently polluting
/// warm starts. Records from before stamping parse back as `"v0"`.
pub const SIM_VERSION: &str = "sim-v1";
