//! Hardware target descriptions for the analytical latency simulator.
//!
//! Substitution record (DESIGN.md §3): the paper measures on an AWS
//! C5.9xlarge (Intel Xeon Platinum 8124M, AVX-512) and an NVIDIA RTX 3070.
//! Neither is available here, so targets parameterize an analytical model
//! with the published characteristics of those parts. What matters for
//! reproducing the paper's *shape* claims is the relative reward structure
//! (locality, vectorization, parallelism, tensor intrinsics), which these
//! parameters encode.

/// One level of the (per-core or shared) cache hierarchy.
#[derive(Debug, Clone)]
pub struct CacheLevel {
    pub name: &'static str,
    /// Capacity in bytes.
    pub size: i64,
    /// Sustained bandwidth into the level above it, bytes/s.
    pub bandwidth: f64,
    /// Whether the level is private per core (true) or chip-shared.
    pub per_core: bool,
}

/// Kind of execution model the simulator applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetKind {
    /// Multicore CPU: `parallel` loops spread across cores, `vectorize`
    /// maps to SIMD lanes.
    Cpu,
    /// GPU-style accelerator: `bind` maps loops onto a grid of thread
    /// blocks; `shared`-scope buffers live in per-block scratchpad.
    Gpu,
}

/// A simulated hardware target.
#[derive(Debug, Clone)]
pub struct Target {
    pub name: &'static str,
    pub kind: TargetKind,
    /// CPU cores or GPU SMs.
    pub num_cores: usize,
    /// f32 SIMD lanes per vector instruction (CPU) / per-thread ILP unit (GPU).
    pub vector_lanes: i64,
    /// Peak f32 FLOP/s of one core/SM assuming full vector + FMA issue.
    pub peak_flops_per_core: f64,
    /// Cache hierarchy, innermost (fastest/smallest) first.
    pub cache: Vec<CacheLevel>,
    /// Off-chip bandwidth, bytes/s.
    pub dram_bandwidth: f64,
    /// Per-block scratchpad capacity in bytes (GPU shared mem / TPU VMEM slice).
    pub shared_mem_bytes: i64,
    /// Scratchpad bandwidth per SM, bytes/s.
    pub shared_bandwidth: f64,
    /// Max resident threads per block.
    pub max_threads_per_block: i64,
    /// Seconds to spawn/join one parallel region.
    pub parallel_overhead: f64,
    /// Seconds of issue overhead per executed loop iteration.
    pub loop_overhead: f64,
    /// Tensor intrinsics the target supports (names in the intrin registry).
    pub tensor_intrins: Vec<&'static str>,
}

impl Target {
    /// AWS C5.9xlarge-class CPU: 18 physical cores, AVX-512 (16 f32 lanes),
    /// 2 FMA ports at ~3.0 GHz -> 192 GFLOP/s per core.
    pub fn cpu_avx512() -> Target {
        Target {
            name: "cpu-avx512",
            kind: TargetKind::Cpu,
            num_cores: 18,
            vector_lanes: 16,
            peak_flops_per_core: 192e9,
            cache: vec![
                CacheLevel {
                    name: "L1",
                    size: 32 * 1024,
                    bandwidth: 400e9,
                    per_core: true,
                },
                CacheLevel {
                    name: "L2",
                    size: 1024 * 1024,
                    bandwidth: 150e9,
                    per_core: true,
                },
                CacheLevel {
                    name: "L3",
                    size: 24 * 1024 * 1024,
                    // Aggregate (chip-shared) sustained L3 bandwidth.
                    bandwidth: 300e9,
                    per_core: false,
                },
            ],
            dram_bandwidth: 90e9,
            shared_mem_bytes: 0,
            shared_bandwidth: 0.0,
            max_threads_per_block: 0,
            // Warm-pool OpenMP-class fork/join barrier on ~18 cores.
            parallel_overhead: 3e-6,
            loop_overhead: 0.8e-9,
            tensor_intrins: vec!["dot_4x4"],
        }
    }

    /// RTX 3070-class GPU: 46 SMs, ~20 TFLOP/s f32, TensorCore WMMA
    /// fragments, 100 KB shared memory per SM, 448 GB/s HBM.
    pub fn gpu() -> Target {
        Target {
            name: "gpu-rtx3070",
            kind: TargetKind::Gpu,
            num_cores: 46,
            vector_lanes: 32, // warp width
            peak_flops_per_core: 440e9,
            cache: vec![CacheLevel {
                name: "L2",
                size: 4 * 1024 * 1024,
                bandwidth: 1500e9,
                per_core: false,
            }],
            dram_bandwidth: 448e9,
            shared_mem_bytes: 100 * 1024,
            shared_bandwidth: 1200e9,
            max_threads_per_block: 1024,
            parallel_overhead: 5e-6,
            loop_overhead: 0.25e-9,
            tensor_intrins: vec!["wmma_16x16x16"],
        }
    }

    /// TPU-flavoured target for the Pallas hardware-adaptation notes:
    /// VMEM-sized scratchpad (16 MB) and the 128x128 MXU systolic intrinsic.
    pub fn tpu_like() -> Target {
        Target {
            name: "tpu-like",
            kind: TargetKind::Gpu,
            num_cores: 2, // tensor cores per chip
            vector_lanes: 8,
            peak_flops_per_core: 8e12,
            cache: vec![],
            dram_bandwidth: 600e9,
            shared_mem_bytes: 16 * 1024 * 1024,
            shared_bandwidth: 3000e9,
            max_threads_per_block: 1024,
            parallel_overhead: 2e-6,
            loop_overhead: 0.3e-9,
            tensor_intrins: vec!["mxu_128x128"],
        }
    }

    /// Total peak FLOP/s of the whole chip.
    pub fn peak_flops(&self) -> f64 {
        self.peak_flops_per_core * self.num_cores as f64
    }

    /// Parse a target by name ("cpu", "gpu", "tpu").
    pub fn by_name(name: &str) -> Option<Target> {
        match name {
            "cpu" | "cpu-avx512" => Some(Target::cpu_avx512()),
            "gpu" | "gpu-rtx3070" => Some(Target::gpu()),
            "tpu" | "tpu-like" => Some(Target::tpu_like()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_peak_reasonable() {
        let t = Target::cpu_avx512();
        let pf = t.peak_flops();
        assert!(pf > 1e12 && pf < 10e12, "peak {pf}");
    }

    #[test]
    fn by_name_resolves() {
        assert_eq!(Target::by_name("cpu").unwrap().kind, TargetKind::Cpu);
        assert_eq!(Target::by_name("gpu").unwrap().kind, TargetKind::Gpu);
        assert!(Target::by_name("vax").is_none());
    }

    #[test]
    fn cache_sizes_increase_outward() {
        let t = Target::cpu_avx512();
        for w in t.cache.windows(2) {
            assert!(w[0].size < w[1].size);
            // Effective chip-wide bandwidth decreases outward (per-core
            // levels multiply by the core count).
            let eff = |c: &CacheLevel| {
                c.bandwidth * if c.per_core { t.num_cores as f64 } else { 1.0 }
            };
            assert!(eff(&w[0]) > eff(&w[1]));
        }
    }
}
