//! The analytical latency simulator: deterministic `f(e)` for a scheduled
//! tensor program on a [`Target`].
//!
//! Model per block, combined roofline-style:
//!   * compute time: weighted flops over peak, scaled by vectorization
//!     efficiency (SIMD width + access contiguity), parallel/occupancy
//!     utilization, and tensor-intrinsic speedup;
//!   * memory time: per cache level, the classic blocked-working-set model —
//!     find the outermost loop depth whose swept footprint fits the level,
//!     misses = outer trips x footprint; the level's service bandwidth
//!     bounds the time; the max over levels is the memory term;
//!   * overheads: loop issue, parallel-region spawn / kernel launch,
//!     cross-thread reduction synchronization.
//!
//! Schedules that violate hard constraints (scratchpad overflow, too many
//! threads per block, unsupported tensor intrinsics) return [`SimError`] —
//! during search these act exactly like the paper's trace-validation
//! rejections for hardware-limit violations.

use std::collections::HashMap;

use crate::sim::target::{Target, TargetKind};
use crate::tir::analysis::{classify_loop, region_footprint_elems, LoopClass};
use crate::tir::{ItemId, LoopKind, Program, Scope, VarId};

/// Why a schedule is infeasible on the target.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    SharedMemOverflow { need: i64, have: i64 },
    TooManyThreads { threads: i64, max: i64 },
    UnsupportedIntrin(String),
    NoComputeBlocks,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::SharedMemOverflow { need, have } => {
                write!(f, "shared memory overflow: need {need} B, have {have} B")
            }
            SimError::TooManyThreads { threads, max } => {
                write!(f, "too many threads per block: {threads} > {max}")
            }
            SimError::UnsupportedIntrin(s) => write!(f, "unsupported tensor intrinsic {s}"),
            SimError::NoComputeBlocks => write!(f, "program has no compute blocks"),
        }
    }
}

/// Detailed latency breakdown (useful for EXPERIMENTS.md and debugging).
#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub total_s: f64,
    pub compute_s: f64,
    pub memory_s: f64,
    pub overhead_s: f64,
    pub dram_bytes: f64,
    pub flops: f64,
    pub per_block: Vec<(String, f64)>,
}

impl LatencyReport {
    /// Achieved fraction of target peak FLOP/s.
    pub fn efficiency(&self, target: &Target) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        (self.flops / self.total_s) / target.peak_flops()
    }
}

/// Estimate the latency of `prog` on `target`.
pub fn simulate(prog: &Program, target: &Target) -> Result<LatencyReport, SimError> {
    let blocks = prog.blocks();
    if blocks.is_empty() {
        return Err(SimError::NoComputeBlocks);
    }
    if target.kind == TargetKind::Gpu {
        check_shared_mem(prog, target)?;
    }
    let mut report = LatencyReport::default();
    for &b in &blocks {
        let bl = simulate_block(prog, target, b)?;
        report.total_s += bl.total;
        report.compute_s += bl.compute;
        report.memory_s += bl.memory;
        report.overhead_s += bl.overhead;
        report.dram_bytes += bl.dram_bytes;
        report.flops += bl.flops;
        report
            .per_block
            .push((prog.block_data(b).name.clone(), bl.total));
    }
    // Kernel-launch / program-start overhead per root nest.
    let launches = prog.roots.len() as f64;
    let launch_cost = match target.kind {
        TargetKind::Gpu => 3e-6 * launches,
        TargetKind::Cpu => 0.2e-6 * launches,
    };
    report.overhead_s += launch_cost;
    report.total_s += launch_cost;
    Ok(report)
}

struct BlockLatency {
    total: f64,
    compute: f64,
    memory: f64,
    overhead: f64,
    dram_bytes: f64,
    flops: f64,
}

/// Per-level capacities + service bandwidths applicable to global buffers.
/// A synthetic register-file level sits innermost: operands reused within
/// the innermost tile (register blocking, the "S3/R1" tiles of the
/// multi-level structure) are effectively free, so the first cache level
/// only serves the *register misses* — without this, well-tiled GEMMs
/// would be bounded by per-instance L1 traffic they do not actually emit.
fn memory_levels(target: &Target) -> Vec<(i64, f64, bool)> {
    let mut levels: Vec<(i64, f64, bool)> = vec![(2 * 1024, 1e14, true)];
    levels.extend(
        target
            .cache
            .iter()
            .map(|c| (c.size, c.bandwidth, c.per_core)),
    );
    // DRAM: infinite capacity backstop.
    levels.push((i64::MAX / 4, target.dram_bandwidth, false));
    levels
}

fn thread_tag(kind: &LoopKind) -> Option<&str> {
    match kind {
        LoopKind::ThreadBinding(t) => Some(t.as_str()),
        _ => None,
    }
}

fn simulate_block(prog: &Program, target: &Target, block: ItemId) -> Result<BlockLatency, SimError> {
    let bd = prog.block_data(block);
    let loops = prog.loops_above(block);
    let extents: Vec<i64> = loops.iter().map(|&l| prog.loop_data(l).extent).collect();
    let instances: f64 = extents.iter().map(|&e| e as f64).product();
    let flops = instances * bd.body.flops();

    // ---- execution resources ------------------------------------------------
    let mut active_units = 1.0f64; // cores (CPU) / resident parallel threads (GPU)
    let mut util = 1.0f64;
    let mut sync_cost = 0.0f64;
    let mut spawn_cost = 0.0f64;
    match target.kind {
        TargetKind::Cpu => {
            let mut parallel_extent = 1i64;
            let mut outside_trips = 1i64;
            let mut seen_parallel = false;
            for (&l, &e) in loops.iter().zip(&extents) {
                match prog.loop_data(l).kind {
                    LoopKind::Parallel => {
                        parallel_extent *= e;
                        seen_parallel = true;
                    }
                    _ => {
                        if !seen_parallel {
                            outside_trips *= e;
                        }
                    }
                }
            }
            if seen_parallel {
                // Spawning inside outer serial loops costs per outer trip.
                spawn_cost = target.parallel_overhead * outside_trips as f64;
                let cores = target.num_cores as f64;
                active_units = (parallel_extent as f64).min(cores);
                // Load imbalance when the extent doesn't divide the cores.
                let chunks = (parallel_extent as f64 / cores).ceil();
                util = parallel_extent as f64 / (chunks * cores.min(parallel_extent as f64));
            }
        }
        TargetKind::Gpu => {
            let mut grid = 1i64;
            let mut threads = 1i64;
            let mut reduce_thread_extent = 1i64;
            for &l in &loops {
                let ld = prog.loop_data(l);
                if let Some(tag) = thread_tag(&ld.kind) {
                    if tag.starts_with("blockIdx") {
                        grid *= ld.extent;
                    } else if tag.starts_with("threadIdx") {
                        threads *= ld.extent;
                        if classify_loop(prog, l) == LoopClass::Reduce {
                            reduce_thread_extent *= ld.extent;
                        }
                    }
                }
            }
            if threads > target.max_threads_per_block {
                return Err(SimError::TooManyThreads {
                    threads,
                    max: target.max_threads_per_block,
                });
            }
            let total_threads = (grid * threads) as f64;
            let chip_lanes = (target.num_cores as f64) * 256.0;
            active_units = total_threads.min(chip_lanes);
            // Warp efficiency: blocks narrower than a warp waste lanes.
            let warp_eff = ((threads as f64) / 32.0).min(1.0);
            let occupancy = (total_threads / chip_lanes).min(1.0);
            util = warp_eff * occupancy.max(1.0 / target.num_cores as f64);
            if reduce_thread_extent > 1 {
                // Cross-thread tree reduction: log2 rounds of syncthreads.
                let rounds = (reduce_thread_extent as f64).log2().ceil();
                sync_cost = rounds * 50e-9 * (instances / total_threads.max(1.0));
            }
            spawn_cost = 0.0; // accounted once per root as kernel launch
        }
    }

    // ---- vectorization (CPU) / coalescing proxy ------------------------------
    let mut vec_eff = match target.kind {
        // Unvectorized scalar code runs at 1/lanes of peak.
        TargetKind::Cpu => 1.0 / target.vector_lanes as f64,
        TargetKind::Gpu => 1.0,
    };
    if target.kind == TargetKind::Cpu {
        // Judge the innermost *non-unit* loop: unit loops compile away.
        let inner_nonunit = loops
            .iter()
            .rev()
            .find(|&&l| prog.loop_data(l).extent > 1)
            .copied();
        if let Some(inner) = inner_nonunit {
            let ld = prog.loop_data(inner);
            if ld.kind == LoopKind::Vectorized {
                let lanes = target.vector_lanes as f64;
                let e = ld.extent as f64;
                let fill = if ld.extent >= target.vector_lanes {
                    // Efficiency of covering e with full vectors.
                    e / (lanes * (e / lanes).ceil())
                } else {
                    e / lanes
                };
                let contig = contiguity_fraction(prog, block, ld.var);
                vec_eff = fill * (0.25 + 0.75 * contig);
            }
        }
    }

    // ---- tensor intrinsic -----------------------------------------------------
    let mut intrin_boost = 1.0;
    if let Some(name) = bd.annotations.get("tensor_intrin") {
        if !target.tensor_intrins.iter().any(|i| i == name) {
            return Err(SimError::UnsupportedIntrin(name.clone()));
        }
        let intrin = crate::schedule::blockize::find_intrin(name)
            .ok_or_else(|| SimError::UnsupportedIntrin(name.clone()))?;
        intrin_boost = intrin.speedup;
        vec_eff = 1.0; // the intrinsic subsumes vectorization
    }

    let peak = target.peak_flops_per_core * (active_units / per_unit_divisor(target));
    let compute_time = flops / (peak * vec_eff * util * intrin_boost).max(1.0);

    // ---- memory -----------------------------------------------------------------
    let (memory_time, dram_bytes) = memory_time(prog, target, block, &loops, active_units);

    // ---- loop issue overhead ------------------------------------------------------
    // Two terms: (a) loop *entries* pay a real setup cost (~several
    // cycles: counter init, branch mispredict at exit); (b) per-iteration
    // bookkeeping is mostly hidden by superscalar issue next to the body,
    // so it costs only a small fraction of a cycle. Extent-1 loops are
    // eliminated by any real compiler and charge nothing. Vectorization
    // divides the innermost trip count by the lane width; unrolling
    // amortizes both terms.
    let mut entries = 0.0f64;
    let mut trips = 1.0f64;
    for &l in &loops {
        let ld = prog.loop_data(l);
        if ld.extent <= 1 {
            continue;
        }
        let mut this = ld.extent as f64;
        match ld.kind {
            LoopKind::Unrolled => this *= 0.15, // unrolled bodies amortize issue
            LoopKind::Vectorized => this /= target.vector_lanes as f64,
            _ => {}
        }
        entries += trips;
        trips *= this.max(1.0);
    }
    // Weights: entry ~ 2.5x the per-"cycle" target constant, hidden
    // per-iteration bookkeeping ~ 6% of it.
    let iters = entries * 2.5 + trips * 0.06;
    // Explicit unroll pragmas (annotation) shave issue overhead further.
    let unroll_credit = if loops.iter().any(|&l| {
        prog.loop_data(l)
            .annotations
            .get("pragma_auto_unroll_max_step")
            .map(|v| v != "0")
            .unwrap_or(false)
    }) {
        0.6
    } else {
        1.0
    };
    let overhead = iters * target.loop_overhead * unroll_credit / active_units
        + spawn_cost
        + sync_cost;

    let total = compute_time.max(memory_time) + overhead;
    Ok(BlockLatency {
        total,
        compute: compute_time,
        memory: memory_time,
        overhead,
        dram_bytes,
        flops,
    })
}

fn per_unit_divisor(_target: &Target) -> f64 {
    1.0
}

/// Fraction of the block's accesses whose linearized row-major address
/// moves with stride <= 1 per step of the (vectorized) loop variable
/// (stride-0 broadcast also counts as vector-friendly).
fn contiguity_fraction(prog: &Program, block: ItemId, loop_var: VarId) -> f64 {
    let bd = prog.block_data(block);
    let bindings: HashMap<VarId, crate::tir::AExpr> = bd
        .iters
        .iter()
        .map(|iv| (iv.var, iv.binding.clone()))
        .collect();
    let mut total = 0usize;
    let mut contig = 0usize;
    for r in bd.reads.iter().chain(bd.writes.iter()) {
        total += 1;
        if crate::tir::analysis::linear_stride(prog, r, &bindings, loop_var).abs() <= 1 {
            contig += 1;
        }
    }
    if total == 0 {
        1.0
    } else {
        contig as f64 / total as f64
    }
}

/// Memory-hierarchy time for one block + the DRAM bytes it moves.
fn memory_time(
    prog: &Program,
    target: &Target,
    block: ItemId,
    loops: &[ItemId],
    active_units: f64,
) -> (f64, f64) {
    let bd = prog.block_data(block);
    // Split regions by scope.
    let mut global_regions = Vec::new();
    let mut shared_bytes_per_instance = 0.0f64;
    let mut l1ish_bytes_per_instance = 0.0f64;
    for r in bd.reads.iter().chain(bd.writes.iter()) {
        let buf = &prog.buffers[r.buffer];
        let elem = buf.dtype.bytes() as f64;
        match buf.scope {
            Scope::Global => global_regions.push(r),
            Scope::Shared => shared_bytes_per_instance += r.extent_numel() as f64 * elem,
            Scope::Local | Scope::Wmma(_) => {
                l1ish_bytes_per_instance += r.extent_numel() as f64 * elem
            }
        }
    }
    let instances: f64 = loops
        .iter()
        .map(|&l| prog.loop_data(l).extent as f64)
        .product();

    let mut max_time = 0.0f64;
    // Scratchpad traffic (GPU shared / CPU near-L1).
    if shared_bytes_per_instance > 0.0 {
        let bw = if target.kind == TargetKind::Gpu {
            target.shared_bandwidth * (active_units / 256.0).max(1.0)
        } else {
            target.cache.first().map(|c| c.bandwidth).unwrap_or(400e9) * active_units
        };
        max_time = max_time.max(instances * shared_bytes_per_instance / bw);
    }
    if l1ish_bytes_per_instance > 0.0 {
        // Registers / fragments: effectively free, tiny charge for realism.
        max_time = max_time.max(instances * l1ish_bytes_per_instance / (5e12 * active_units));
    }
    if global_regions.is_empty() {
        return (max_time, 0.0);
    }

    // Footprint (bytes) of each region when loops[d..] sweep, precomputed
    // for every depth ONCE and reused across cache levels (§Perf: the
    // env construction + interval analysis dominated simulate()).
    // Per-region fitting matters: an output tile invariant under the
    // reduction sweep stays register/cache resident even while the operand
    // tiles stream — an all-regions-combined working set would wrongly
    // charge it per reduction step.
    let depths = loops.len() + 1;
    let mut fp_table: Vec<Vec<f64>> = vec![vec![0.0; depths]; global_regions.len()];
    for d in 0..depths {
        let sweep = crate::tir::analysis::sweep_env(prog, &loops[d..]);
        // Env over iter vars (bindings' intervals) + raw loop vars for
        // opaque blocks whose regions reference loop vars directly.
        let mut env = crate::tir::analysis::iter_env(prog, block, &sweep);
        for (k, v) in &sweep {
            env.insert(*k, *v);
        }
        for (ri, r) in global_regions.iter().enumerate() {
            fp_table[ri][d] = region_footprint_elems(&r.ranges, &env) as f64
                * prog.buffers[r.buffer].dtype.bytes() as f64;
        }
    }
    // Cumulative outer-trip products by depth.
    let mut outer_trips_at: Vec<f64> = vec![1.0; depths];
    for d in 1..depths {
        outer_trips_at[d] = outer_trips_at[d - 1] * prog.loop_data(loops[d - 1]).extent as f64;
    }

    let mut dram_bytes = 0.0;
    let levels = memory_levels(target);
    // Level w's misses: per region, find the outermost loop depth whose
    // swept footprint fits, then misses = outer trips x fitted footprint.
    // The level above (or DRAM) serves those misses.
    for w in 0..levels.len() {
        let (cap, _, _) = levels[w];
        // Contention: a single region may keep at most ~60% of a level
        // resident (the rest streams the other regions through).
        let cap_share = cap as f64 * 0.6;
        let mut misses = 0.0f64;
        for fps in &fp_table {
            let mut d_fit = loops.len();
            let mut fitted = fps[loops.len()];
            for d in (0..depths).rev() {
                if fps[d] <= cap_share {
                    d_fit = d;
                    fitted = fps[d];
                } else {
                    break;
                }
            }
            misses += outer_trips_at[d_fit] * fitted;
        }
        // Serve from the level above (or DRAM for the last level).
        let (bw, per_core) = if w + 1 < levels.len() {
            (levels[w + 1].1, levels[w + 1].2)
        } else {
            (target.dram_bandwidth, false)
        };
        let eff_bw = if per_core { bw * active_units } else { bw };
        max_time = max_time.max(misses / eff_bw);
        // DRAM traffic = misses of the last *finite* cache level (the
        // backstop level only records compulsory traffic).
        if w + 2 == levels.len() || levels.len() == 1 {
            dram_bytes = misses;
        }
    }
    (max_time, dram_bytes)
}

/// Check that shared-scope allocations fit the per-block scratchpad. The
/// allocation of a shared buffer is the footprint its writer stages per
/// iteration of the grid (blockIdx) loops.
fn check_shared_mem(prog: &Program, target: &Target) -> Result<(), SimError> {
    let mut need = 0i64;
    for (buf_id, buf) in prog.buffers.iter().enumerate() {
        if buf.inlined || buf.scope != Scope::Shared {
            continue;
        }
        let writers = prog.writers_of(buf_id);
        let mut alloc = 0i64;
        for w in writers {
            let loops = prog.loops_above(w);
            // Sweep the loops *not* bound to blockIdx.
            let sweep_loops: Vec<ItemId> = loops
                .iter()
                .copied()
                .filter(|&l| {
                    !matches!(&prog.loop_data(l).kind,
                        LoopKind::ThreadBinding(t) if t.starts_with("blockIdx"))
                })
                .collect();
            let sweep = crate::tir::analysis::sweep_env(prog, &sweep_loops);
            let mut env = crate::tir::analysis::iter_env(prog, w, &sweep);
            for (k, v) in &sweep {
                env.insert(*k, *v);
            }
            for r in &prog.block_data(w).writes {
                if r.buffer == buf_id {
                    alloc = alloc.max(region_footprint_elems(&r.ranges, &env) * buf.dtype.bytes());
                }
            }
        }
        if alloc == 0 {
            alloc = buf.bytes(); // conservatively whole buffer if never written
        }
        need += alloc;
    }
    if need > target.shared_mem_bytes {
        return Err(SimError::SharedMemOverflow {
            need,
            have: target.shared_mem_bytes,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::matmul_prog;
    use crate::schedule::Schedule;
    use crate::trace::FactorArg;

    #[test]
    fn naive_matmul_has_positive_latency() {
        let p = matmul_prog(128, 128);
        let t = Target::cpu_avx512();
        let r = simulate(&p, &t).unwrap();
        assert!(r.total_s > 0.0);
        assert_eq!(r.flops, 128.0 * 128.0 * 128.0 * 2.0);
    }

    #[test]
    fn parallel_and_vectorize_speed_up() {
        let t = Target::cpu_avx512();
        let p = matmul_prog(256, 256);
        let base = simulate(&p, &t).unwrap().total_s;

        let mut s = Schedule::new(p, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.parallel(loops[0]).unwrap();
        let par = simulate(&s.prog, &t).unwrap().total_s;
        assert!(par < base * 0.5, "parallel {par} vs base {base}");

        // Reorder j innermost (stride-1 for B and C) and vectorize it.
        let mut s2 = s.clone();
        let l2 = s2.get_loops(b).unwrap();
        s2.reorder(&[l2[0], l2[2], l2[1]]).unwrap();
        let l3 = s2.get_loops(b).unwrap();
        s2.vectorize(l3[2]).unwrap();
        let vec = simulate(&s2.prog, &t).unwrap().total_s;
        assert!(vec < par * 0.5, "vectorized {vec} vs parallel {par}");
    }

    #[test]
    fn tiling_reduces_dram_traffic() {
        let t = Target::cpu_avx512();
        // 2048^3 matmul: the working set (48 MB) exceeds L3, so untiled
        // j-k streaming re-reads B once per i row.
        let p = matmul_prog(2048, 2048);
        let base = simulate(&p, &t).unwrap();
        // Tile i and j by 64, k by 64: classic cache blocking.
        let mut s = Schedule::new(p, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let i = s
            .split(loops[0], &[FactorArg::Lit(32), FactorArg::Lit(64)])
            .unwrap();
        let j = s
            .split(loops[1], &[FactorArg::Lit(32), FactorArg::Lit(64)])
            .unwrap();
        let k = s
            .split(loops[2], &[FactorArg::Lit(32), FactorArg::Lit(64)])
            .unwrap();
        s.reorder(&[i[0], j[0], k[0], i[1], j[1], k[1]]).unwrap();
        let tiled = simulate(&s.prog, &t).unwrap();
        assert!(
            tiled.dram_bytes < base.dram_bytes * 0.5,
            "tiled {} vs base {}",
            tiled.dram_bytes,
            base.dram_bytes
        );
        // Both runs are compute-bound scalar, so compare the memory term.
        assert!(
            tiled.memory_s < base.memory_s,
            "tiled {} vs base {}",
            tiled.memory_s,
            base.memory_s
        );
        // Totals stay within noise of each other (scalar compute-bound both
        // ways; tiling pays a little extra loop-issue overhead until
        // vectorization/parallelism are applied on top).
        assert!(tiled.total_s <= base.total_s * 1.2);
    }

    #[test]
    fn gpu_requires_binding_for_speed() {
        let t = Target::gpu();
        let p = matmul_prog(256, 256);
        let base = simulate(&p, &t).unwrap().total_s;
        let mut s = Schedule::new(p, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let i = s
            .split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(64)])
            .unwrap();
        s.bind(i[0], "blockIdx.x").unwrap();
        s.bind(i[1], "threadIdx.x").unwrap();
        let bound = simulate(&s.prog, &t).unwrap().total_s;
        assert!(bound < base * 0.01, "bound {bound} vs base {base}");
    }

    #[test]
    fn too_many_threads_invalid() {
        let t = Target::gpu();
        let p = matmul_prog(4096, 16);
        let mut s = Schedule::new(p, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.bind(loops[0], "threadIdx.x").unwrap(); // 4096 threads
        assert!(matches!(
            simulate(&s.prog, &t),
            Err(SimError::TooManyThreads { .. })
        ));
    }

    #[test]
    fn shared_overflow_invalid() {
        let t = Target::gpu();
        // Stage a 4 MB buffer into 100 KB shared memory: must fail.
        let p = matmul_prog(1024, 1024);
        let mut s = Schedule::new(p, 0);
        let b = s.get_block("matmul").unwrap();
        s.cache_read(b, 0, "shared").unwrap();
        assert!(matches!(
            simulate(&s.prog, &t),
            Err(SimError::SharedMemOverflow { .. })
        ));
    }

    #[test]
    fn tensorize_speeds_up_on_supporting_target() {
        let t = Target::gpu();
        let p = matmul_prog(256, 256);
        let mut s = Schedule::new(p.clone(), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let i = s
            .split(loops[0], &[FactorArg::Lit(16), FactorArg::Lit(16)])
            .unwrap();
        let j = s
            .split(loops[1], &[FactorArg::Lit(16), FactorArg::Lit(16)])
            .unwrap();
        let k = s
            .split(loops[2], &[FactorArg::Lit(16), FactorArg::Lit(16)])
            .unwrap();
        s.reorder(&[i[0], j[0], k[0], i[1], j[1], k[1]]).unwrap();
        s.bind(i[0], "blockIdx.x").unwrap();
        s.bind(j[0], "threadIdx.y").unwrap();
        let base = simulate(&s.prog, &t).unwrap().total_s;
        s.tensorize(i[1], "wmma_16x16x16").unwrap();
        let tc = simulate(&s.prog, &t).unwrap().total_s;
        assert!(tc < base, "tensorized {tc} vs {base}");
        // And the same intrinsic is invalid on CPU.
        assert!(matches!(
            simulate(&s.prog, &Target::cpu_avx512()),
            Err(SimError::UnsupportedIntrin(_))
        ));
    }
}
