//! Figure 10: (a) search-space composition ablation on the fused-dense
//! BERT subgraph — progressively composing more schedule rules must
//! progressively improve the optimized program; (b) the 82-line
//! hardware-specific Use-Tensor-Core rule composed into the generic
//! space delivers a large speedup over the AutoTVM-style baseline on
//! BERT-large (paper: 48%).
//!
//! Both experiments dogfood the rule-registry API: every arm is a
//! `--rules`-style spec resolved through [`TuneContext::from_specs`], so
//! the ablation is literally "the same CLI flag with more names in it".

use crate::baselines::AutoTvm;
use crate::ctx::TuneContext;
use crate::exp::{tune_with_ctx, ExpConfig, Report};
use crate::graph::{self, extract_tasks};
use crate::search::{SearchConfig, SimMeasurer, TaskScheduler};
use crate::sim::Target;
use crate::workloads;

/// The progressive compositions of Figure 10a (GPU target), as rule
/// specs for the registry.
pub fn compositions() -> Vec<(&'static str, &'static str)> {
    vec![
        ("thread-bind", "thread-bind"),
        ("+auto-inline", "auto-inline,thread-bind"),
        (
            "+multi-level-tiling",
            "auto-inline,multi-level-tiling,cross-thread-reduction,thread-bind",
        ),
        (
            "+compute-location",
            "auto-inline,multi-level-tiling,cross-thread-reduction,random-compute-location,thread-bind",
        ),
        (
            "+use-tensor-core",
            "auto-inline,use-tensor-core,multi-level-tiling,cross-thread-reduction,random-compute-location,thread-bind",
        ),
        (
            "+layout-rewrite",
            "auto-inline,use-tensor-core,layout-rewrite,multi-level-tiling,cross-thread-reduction,random-compute-location,thread-bind",
        ),
    ]
}

/// Figure 10a: fused-dense under progressively richer spaces.
pub fn run_10a(cfg: &ExpConfig) -> Report {
    let target = Target::gpu();
    let prog = workloads::fused_dense(128, 3072, 768);
    let mut report = Report::new(
        "fig10a",
        "Figure 10a: search-space composition on fused-dense (GPU)",
    );
    let mut prev = f64::INFINITY;
    let mut monotone = true;
    // The ablation arms share one base program, and workload identity is
    // (program hash, target) — a shared tuning db would let each richer
    // space warm-start from the previous arm's records and void the
    // comparison. The arms therefore always run cold. A custom --rules
    // spec is likewise ignored: the arms ARE the rule specs.
    let cold = ExpConfig { db_path: None, rules: None, ..cfg.clone() };
    if cfg.db_path.is_some() {
        report.notes.push("--db ignored: ablation arms share one workload and must run cold".into());
    }
    if cfg.rules.is_some() {
        report.notes.push("--rules ignored: the ablation arms ARE the rule specs".into());
    }
    if cfg.mutators.is_some() || cfg.postprocs.is_some() {
        report.notes.push("--mutators/--postprocs ignored: ablation arms use the default policy".into());
    }
    for (name, spec) in compositions() {
        let ctx = TuneContext::from_specs(target.clone(), spec, "default", "default")
            .expect("fig10a rule specs are built-in names");
        let r = tune_with_ctx(&prog, &ctx, &cold);
        report.push(name, "MetaSchedule", r.best_latency_s);
        // Allow small search noise in the monotonicity note.
        if r.best_latency_s > prev * 1.15 {
            monotone = false;
        }
        prev = prev.min(r.best_latency_s);
    }
    report.notes.push(format!(
        "progressive composition monotone (within search noise): {monotone}"
    ));
    report
}

/// Figure 10b: BERT-large end-to-end, AutoTVM-style baseline vs
/// MetaSchedule generic vs MetaSchedule + Use-Tensor-Core (GPU).
pub fn run_10b(cfg: &ExpConfig) -> Report {
    let target = Target::gpu();
    let ops = graph::bert_large();
    let tasks = extract_tasks(&ops);
    let mut report = Report::new("fig10b", "Figure 10b: BERT-large (GPU)");
    // Generic and +TC arms tune the same task programs, and workload
    // identity is (program hash, target) — a shared db would let the TC
    // arm inherit the generic arm's records. Deliberately cold.
    if cfg.db_path.is_some() {
        report.notes.push("--db ignored: composition arms share workloads and must run cold".into());
    }
    if cfg.rules.is_some() {
        report.notes.push("--rules ignored: fig10b compares the generic and +TC rule sets".into());
    }
    if cfg.mutators.is_some() || cfg.postprocs.is_some() {
        report.notes.push("--mutators/--postprocs ignored: both arms use the default policy".into());
    }

    // AutoTVM-style baseline (the paper's "TVM (AutoTVM)" bar; Ansor does
    // not support TensorCore — Appendix A.4).
    let mut autotvm_total = 0.0;
    for t in &tasks {
        let mut m = SimMeasurer::new(target.clone());
        let r = AutoTvm { num_trials: cfg.trials }.tune(&t.prog, &target, &mut m, cfg.seed);
        autotvm_total += r.best_latency_s * t.weight as f64;
    }
    report.push("BERT-large", "TVM(AutoTVM)", autotvm_total);

    // MetaSchedule with the generic space.
    let e2e = |ctx: &TuneContext, seed: u64| {
        let mut measurer = SimMeasurer::new(target.clone());
        let ts = TaskScheduler::new(SearchConfig {
            threads: cfg.threads,
            ..SearchConfig::default()
        });
        let results = ts.tune_tasks(&tasks, ctx, &mut measurer, cfg.trials * tasks.len(), seed);
        TaskScheduler::e2e_latency(&tasks, &results)
    };
    let generic = e2e(&TuneContext::generic(target.clone()), cfg.seed);
    report.push("BERT-large", "MetaSchedule", generic);

    // MetaSchedule + Use-Tensor-Core.
    let tc = e2e(&TuneContext::with_tensor_core(target.clone()), cfg.seed);
    report.push("BERT-large", "MetaSchedule+TC", tc);

    report.notes.push(format!(
        "Use-Tensor-Core speedup over AutoTVM: {:.2}x (paper: 1.48x); over generic: {:.2}x",
        autotvm_total / tc,
        generic / tc
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10a_tensor_core_wins_and_composition_helps() {
        let cfg = ExpConfig { trials: 40, seed: 11, ..ExpConfig::default() };
        let r = run_10a(&cfg);
        let ws = r.workloads();
        assert_eq!(ws.len(), 6);
        let first = r.latency(&ws[0], "MetaSchedule").unwrap();
        let tiled = r.latency("+multi-level-tiling", "MetaSchedule").unwrap();
        let tc = r.latency("+use-tensor-core", "MetaSchedule").unwrap();
        assert!(tiled <= first * 1.05, "tiling {tiled} vs bind-only {first}");
        assert!(tc < tiled, "tc {tc} vs tiled {tiled}");
        assert!(tc < first, "tc {tc} vs first {first}");
    }

    #[test]
    fn fig10b_tc_beats_autotvm_substantially() {
        let cfg = ExpConfig { trials: 16, seed: 5, ..ExpConfig::default() };
        let r = run_10b(&cfg);
        let autotvm = r.latency("BERT-large", "TVM(AutoTVM)").unwrap();
        let tc = r.latency("BERT-large", "MetaSchedule+TC").unwrap();
        assert!(
            tc < autotvm / 1.2,
            "tc {tc} should be >=1.2x faster than autotvm {autotvm}"
        );
    }

    #[test]
    fn fig10a_specs_match_the_legacy_hardcoded_arms() {
        // The ablation arms used to be hand-built Vec<Box<dyn ...>>
        // lists; as registry specs they must resolve to the same rule
        // names in the same order (the +use-tensor-core arm is the old
        // `with_tensor_core` insertion point).
        let target = Target::gpu();
        let (_, tc_spec) = compositions()
            .into_iter()
            .find(|(name, _)| *name == "+use-tensor-core")
            .unwrap();
        let ctx = TuneContext::from_specs(target.clone(), tc_spec, "default", "default").unwrap();
        assert_eq!(ctx.rule_set(), TuneContext::with_tensor_core(target).rule_set());
    }

    #[test]
    fn fig10a_layout_rewrite_arm_resolves_and_extends_tc() {
        let target = Target::gpu();
        let (name, spec) = compositions().pop().unwrap();
        assert_eq!(name, "+layout-rewrite");
        let ctx = TuneContext::from_specs(target, spec, "default", "default").unwrap();
        assert!(ctx.rule_set().contains("layout-rewrite"), "{}", ctx.rule_set());
    }
}
