//! Figure 10: (a) search-space composition ablation on the fused-dense
//! BERT subgraph — progressively composing more transformation modules
//! must progressively improve the optimized program; (b) the 82-line
//! hardware-specific Use-Tensor-Core module composed into the generic
//! space delivers a large speedup over the AutoTVM-style baseline on
//! BERT-large (paper: 48%).

use crate::baselines::AutoTvm;
use crate::exp::{tune_with_composer, ExpConfig, Report};
use crate::graph::{self, extract_tasks};
use crate::search::{SearchConfig, SimMeasurer, TaskScheduler};
use crate::sim::Target;
use crate::space::{
    AutoInline, CrossThreadReduction, MultiLevelTiling, RandomComputeLocation, SpaceComposer,
    ThreadBind, TransformModule, UseTensorCore,
};
use crate::workloads;

/// The progressive compositions of Figure 10a (GPU target).
pub fn compositions() -> Vec<(&'static str, Vec<Box<dyn TransformModule>>)> {
    vec![
        ("thread-bind", vec![Box::new(ThreadBind::new()) as Box<dyn TransformModule>]),
        (
            "+auto-inline",
            vec![Box::new(AutoInline::new()), Box::new(ThreadBind::new())],
        ),
        (
            "+multi-level-tiling",
            vec![
                Box::new(AutoInline::new()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(ThreadBind::new()),
            ],
        ),
        (
            "+compute-location",
            vec![
                Box::new(AutoInline::new()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(RandomComputeLocation::new()),
                Box::new(ThreadBind::new()),
            ],
        ),
        (
            "+use-tensor-core",
            vec![
                Box::new(AutoInline::new()),
                Box::new(UseTensorCore::wmma()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(RandomComputeLocation::new()),
                Box::new(ThreadBind::new()),
            ],
        ),
    ]
}

/// Figure 10a: fused-dense under progressively richer spaces.
pub fn run_10a(cfg: &ExpConfig) -> Report {
    let target = Target::gpu();
    let prog = workloads::fused_dense(128, 3072, 768);
    let mut report = Report::new(
        "fig10a",
        "Figure 10a: search-space composition on fused-dense (GPU)",
    );
    let mut prev = f64::INFINITY;
    let mut monotone = true;
    // The ablation arms share one base program, and workload identity is
    // (program hash, target) — a shared tuning db would let each richer
    // space warm-start from the previous arm's records and void the
    // comparison. The arms therefore always run cold.
    let cold = ExpConfig { db_path: None, ..cfg.clone() };
    if cfg.db_path.is_some() {
        report.notes.push("--db ignored: ablation arms share one workload and must run cold".into());
    }
    for (name, modules) in compositions() {
        let composer = SpaceComposer::new(modules, target.clone());
        let r = tune_with_composer(&prog, &target, &composer, &cold);
        report.push(name, "MetaSchedule", r.best_latency_s);
        // Allow small search noise in the monotonicity note.
        if r.best_latency_s > prev * 1.15 {
            monotone = false;
        }
        prev = prev.min(r.best_latency_s);
    }
    report.notes.push(format!(
        "progressive composition monotone (within search noise): {monotone}"
    ));
    report
}

/// Figure 10b: BERT-large end-to-end, AutoTVM-style baseline vs
/// MetaSchedule generic vs MetaSchedule + Use-Tensor-Core (GPU).
pub fn run_10b(cfg: &ExpConfig) -> Report {
    let target = Target::gpu();
    let ops = graph::bert_large();
    let tasks = extract_tasks(&ops);
    let mut report = Report::new("fig10b", "Figure 10b: BERT-large (GPU)");
    // Generic and +TC arms tune the same task programs, and workload
    // identity is (program hash, target) — a shared db would let the TC
    // arm inherit the generic arm's records. Deliberately cold.
    if cfg.db_path.is_some() {
        report.notes.push("--db ignored: composition arms share workloads and must run cold".into());
    }

    // AutoTVM-style baseline (the paper's "TVM (AutoTVM)" bar; Ansor does
    // not support TensorCore — Appendix A.4).
    let mut autotvm_total = 0.0;
    for t in &tasks {
        let mut m = SimMeasurer::new(target.clone());
        let r = AutoTvm { num_trials: cfg.trials }.tune(&t.prog, &target, &mut m, cfg.seed);
        autotvm_total += r.best_latency_s * t.weight as f64;
    }
    report.push("BERT-large", "TVM(AutoTVM)", autotvm_total);

    // MetaSchedule with the generic space.
    let e2e = |composer: &SpaceComposer, seed: u64| {
        let mut measurer = SimMeasurer::new(target.clone());
        let ts = TaskScheduler::new(SearchConfig {
            threads: cfg.threads,
            ..SearchConfig::default()
        });
        let results = ts.tune_tasks(&tasks, composer, &mut measurer, cfg.trials * tasks.len(), seed);
        TaskScheduler::e2e_latency(&tasks, &results)
    };
    let generic = e2e(&SpaceComposer::generic(target.clone()), cfg.seed);
    report.push("BERT-large", "MetaSchedule", generic);

    // MetaSchedule + Use-Tensor-Core.
    let tc = e2e(&SpaceComposer::with_tensor_core(target.clone()), cfg.seed);
    report.push("BERT-large", "MetaSchedule+TC", tc);

    report.notes.push(format!(
        "Use-Tensor-Core speedup over AutoTVM: {:.2}x (paper: 1.48x); over generic: {:.2}x",
        autotvm_total / tc,
        generic / tc
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10a_tensor_core_wins_and_composition_helps() {
        let cfg = ExpConfig { trials: 40, seed: 11, ..ExpConfig::default() };
        let r = run_10a(&cfg);
        let ws = r.workloads();
        assert_eq!(ws.len(), 5);
        let first = r.latency(&ws[0], "MetaSchedule").unwrap();
        let tiled = r.latency("+multi-level-tiling", "MetaSchedule").unwrap();
        let tc = r.latency("+use-tensor-core", "MetaSchedule").unwrap();
        assert!(tiled <= first * 1.05, "tiling {tiled} vs bind-only {first}");
        assert!(tc < tiled, "tc {tc} vs tiled {tiled}");
        assert!(tc < first, "tc {tc} vs first {first}");
    }

    #[test]
    fn fig10b_tc_beats_autotvm_substantially() {
        let cfg = ExpConfig { trials: 16, seed: 5, ..ExpConfig::default() };
        let r = run_10b(&cfg);
        let autotvm = r.latency("BERT-large", "TVM(AutoTVM)").unwrap();
        let tc = r.latency("BERT-large", "MetaSchedule+TC").unwrap();
        assert!(
            tc < autotvm / 1.2,
            "tc {tc} should be >=1.2x faster than autotvm {autotvm}"
        );
    }
}
