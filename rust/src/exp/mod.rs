//! Experiment harness: one module per paper figure/table (§6). Each
//! regenerates the paper's rows/series on the simulated testbed and
//! returns structured results for the report writer.
//!
//! | id      | paper artifact                                  |
//! |---------|--------------------------------------------------|
//! | fig8    | operator/subgraph perf, 12 workloads x 3 systems |
//! | fig9    | end-to-end models x 3 systems                    |
//! | fig10a  | search-space composition ablation (fused-dense)  |
//! | fig10b  | BERT-large + Use-Tensor-Core vs AutoTVM          |
//! | table1  | tuning time, 5 models, Ansor vs MetaSchedule     |

pub mod fig10;
pub mod fig8;
pub mod fig9;
pub mod table1;

use crate::cost_model::{GbtCostModel, Objective};
use crate::ctx::TuneContext;
use crate::db::{Database, InMemoryDb};
use crate::search::{Allocation, EvolutionarySearch, SearchConfig, SimMeasurer, TuneResult};
use crate::sim::Target;
use crate::tir::{structural_hash, Program};
use crate::transfer::{TransferConfig, TransferPool};
use crate::util::json::Json;

/// Shared experiment knobs.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Measurement trials per (workload, system).
    pub trials: usize,
    pub seed: u64,
    /// OS threads for the search pipeline (0 = auto). Never changes
    /// results — see the determinism notes in [`crate::search`].
    pub threads: usize,
    /// Optional JSONL tuning-database path (`--db`). When set, every
    /// MetaSchedule tuning call warm-starts from (and commits to) this
    /// file, making `tune`/`tune-model`/`exp` runs resumable across
    /// sessions. Baseline tuners stay cold by design — records would
    /// contaminate the comparison.
    pub db_path: Option<String>,
    /// `--rules` spec (None = `default`); resolved per target against
    /// the built-in registry by [`ExpConfig::context`].
    pub rules: Option<String>,
    /// `--mutators` spec (None = `default`).
    pub mutators: Option<String>,
    /// `--postprocs` spec (None = `default`).
    pub postprocs: Option<String>,
    /// `--transfer-from` source target name: inject that target's
    /// records for the same workload as cross-target priors (elite
    /// seeding re-measured on the destination + discounted cost-model
    /// samples; see [`crate::transfer`]). `None` (the default, and what
    /// `--no-transfer` forces) reproduces the cold-start behaviour
    /// byte for byte.
    pub transfer_from: Option<String>,
    /// `--alloc` budget-allocation policy for multi-task scheduler runs
    /// (`tune-model`, fig9/table1). [`Allocation::Greedy`] is the
    /// byte-compat default; single-task tunes ignore it.
    pub alloc: Allocation,
    /// `--objective` cost-model training objective.
    /// [`Objective::Regression`] (`mse`) is the byte-compat default.
    pub objective: Objective,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            trials: 64,
            seed: 42,
            threads: 0,
            db_path: None,
            rules: None,
            mutators: None,
            postprocs: None,
            transfer_from: None,
            alloc: Allocation::Greedy,
            objective: Objective::Regression,
        }
    }
}

impl ExpConfig {
    /// Build the tuning context for `target` from the configured specs
    /// (all-default = the generic context). Panics on an invalid spec —
    /// the CLI validates specs up front, so a panic here means a caller
    /// bypassed validation, and silently falling back to a different
    /// space would corrupt the experiment.
    pub fn context(&self, target: &Target) -> TuneContext {
        let rules = self.rules.as_deref().unwrap_or("default");
        let mutators = self.mutators.as_deref().unwrap_or("default");
        let postprocs = self.postprocs.as_deref().unwrap_or("default");
        TuneContext::from_specs(target.clone(), rules, mutators, postprocs)
            .unwrap_or_else(|e| panic!("invalid tuning-context spec: {e}"))
    }
}

/// Open the configured tuning database: the path when `--db` was given
/// (layout auto-detected — a single JSONL file or a sharded directory,
/// see [`crate::db::AnyDb`]), a run-local in-memory store otherwise.
/// Corrupt lines are recovered over with a warning (see
/// [`crate::db::JsonFileDb::skipped_lines`]); only an unreadable or entirely
/// unrecognizable path panics — silently ignoring recorded history would
/// be worse.
pub fn open_db(cfg: &ExpConfig) -> Box<dyn Database> {
    match &cfg.db_path {
        Some(path) => match crate::db::AnyDb::open(path) {
            Ok(db) => {
                if db.skipped_lines() > 0 {
                    crate::log_warn!(
                        "tuning db {path}: recovered over {} corrupt line(s); `db compact` will drop them",
                        db.skipped_lines()
                    );
                }
                Box::new(db)
            }
            Err(e) => panic!("cannot open tuning db: {e}"),
        },
        None => Box::new(InMemoryDb::new()),
    }
}

/// Tune one program with MetaSchedule's configured space on the
/// simulator (the context comes from [`ExpConfig::context`]).
pub fn tune_metaschedule(prog: &Program, target: &Target, cfg: &ExpConfig) -> TuneResult {
    tune_with_ctx(prog, &cfg.context(target), cfg)
}

/// Tune with an explicit tuning context (the fig10 ablations build
/// theirs from registry specs).
pub fn tune_with_ctx(prog: &Program, ctx: &TuneContext, cfg: &ExpConfig) -> TuneResult {
    let mut db = open_db(cfg);
    tune_with_ctx_db(prog, ctx, cfg, db.as_mut())
}

/// Tune against an explicit database handle (shared across calls when
/// the caller batches many workloads into one open). When
/// `cfg.transfer_from` names a source target, that target's records for
/// this workload *in the same database* become the transfer pool; use
/// [`tune_with_ctx_db_pool`] to supply a pool from elsewhere (e.g. a
/// read-only donor archive).
pub fn tune_with_ctx_db(
    prog: &Program,
    ctx: &TuneContext,
    cfg: &ExpConfig,
    db: &mut dyn Database,
) -> TuneResult {
    let pool = cfg.transfer_from.as_deref().map(|src| {
        let source = Target::by_name(src)
            .unwrap_or_else(|| panic!("unknown transfer source target {src} (cpu|gpu|tpu)"));
        TransferPool::collect(
            &*db,
            structural_hash(prog),
            ctx.target().name,
            Some(source.name),
            ctx,
            TransferConfig::default(),
        )
    });
    tune_with_ctx_db_pool(prog, ctx, cfg, db, pool.as_ref())
}

/// Tune with an explicit (possibly externally-sourced) transfer pool;
/// `None` is the plain database-backed search.
pub fn tune_with_ctx_db_pool(
    prog: &Program,
    ctx: &TuneContext,
    cfg: &ExpConfig,
    db: &mut dyn Database,
    pool: Option<&TransferPool>,
) -> TuneResult {
    let search = EvolutionarySearch::new(SearchConfig {
        num_trials: cfg.trials,
        threads: cfg.threads,
        ..SearchConfig::default()
    });
    let mut model = GbtCostModel::with_objective(cfg.objective);
    let mut measurer = SimMeasurer::new(ctx.target().clone());
    search.tune_db_transfer(prog, ctx, &mut model, &mut measurer, db, pool, cfg.seed)
}

/// The paper's "TVM" bars pick the best of AutoTVM and Ansor per setup.
pub fn tune_tvm_best(prog: &Program, target: &Target, cfg: &ExpConfig) -> f64 {
    let mut m1 = SimMeasurer::new(target.clone());
    let autotvm = crate::baselines::AutoTvm { num_trials: cfg.trials }
        .tune(prog, target, &mut m1, cfg.seed)
        .best_latency_s;
    let mut m2 = SimMeasurer::new(target.clone());
    let ansor = crate::baselines::Ansor { num_trials: cfg.trials, threads: cfg.threads }
        .tune(prog, target, &mut m2, cfg.seed)
        .best_latency_s;
    autotvm.min(ansor)
}

/// One result row: workload x system -> latency.
#[derive(Debug, Clone)]
pub struct Row {
    pub workload: String,
    pub system: String,
    pub latency_s: f64,
}

/// A complete experiment output.
#[derive(Debug, Clone)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub rows: Vec<Row>,
    /// Free-form notes (e.g. speedup summaries) included in the JSON.
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.into(),
            title: title.into(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn push(&mut self, workload: &str, system: &str, latency_s: f64) {
        self.rows.push(Row {
            workload: workload.into(),
            system: system.into(),
            latency_s,
        });
    }

    pub fn latency(&self, workload: &str, system: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.workload == workload && r.system == system)
            .map(|r| r.latency_s)
    }

    /// Distinct systems in insertion order.
    pub fn systems(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for r in &self.rows {
            if !out.contains(&r.system) {
                out.push(r.system.clone());
            }
        }
        out
    }

    /// Distinct workloads in insertion order.
    pub fn workloads(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for r in &self.rows {
            if !out.contains(&r.workload) {
                out.push(r.workload.clone());
            }
        }
        out
    }

    /// Print the paper-shaped table: one row per workload, one column per
    /// system, in µs plus the speedup of the last system over the first.
    pub fn print(&self) {
        let systems = self.systems();
        let mut headers: Vec<String> = vec!["workload".into()];
        headers.extend(systems.iter().map(|s| format!("{s} (us)")));
        if systems.len() >= 2 {
            headers.push(format!("{} vs {}", systems[systems.len() - 1], systems[0]));
        }
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut rows = Vec::new();
        for w in self.workloads() {
            let mut row = vec![w.clone()];
            for s in &systems {
                match self.latency(&w, s) {
                    Some(l) => row.push(format!("{:.2}", l * 1e6)),
                    None => row.push("-".into()),
                }
            }
            if systems.len() >= 2 {
                if let (Some(a), Some(b)) = (
                    self.latency(&w, &systems[0]),
                    self.latency(&w, &systems[systems.len() - 1]),
                ) {
                    row.push(format!("{:.2}x", a / b));
                }
            }
            rows.push(row);
        }
        crate::util::bench::print_table(&self.title, &hdr_refs, &rows);
        for n in &self.notes {
            println!("  note: {n}");
        }
    }

    /// JSON for EXPERIMENTS.md / downstream plotting.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("title", Json::str(self.title.clone())),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("workload", Json::str(r.workload.clone())),
                        ("system", Json::str(r.system.clone())),
                        ("latency_s", Json::num(r.latency_s)),
                    ])
                })),
            ),
            (
                "notes",
                Json::arr(self.notes.iter().map(|n| Json::str(n.clone()))),
            ),
        ])
    }

    /// Append to the results file consumed by EXPERIMENTS.md.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        writeln!(f, "{}", self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_table_and_json_roundtrip() {
        let mut r = Report::new("figX", "test");
        r.push("GMM", "PyTorch", 10e-6);
        r.push("GMM", "MetaSchedule", 5e-6);
        r.push("SFM", "PyTorch", 2e-6);
        assert_eq!(r.systems(), vec!["PyTorch", "MetaSchedule"]);
        assert_eq!(r.workloads(), vec!["GMM", "SFM"]);
        assert_eq!(r.latency("GMM", "MetaSchedule"), Some(5e-6));
        let j = r.to_json().to_string();
        assert!(j.contains("\"latency_s\""));
        assert!(j.contains("figX"));
        r.print(); // must not panic
    }
}
