//! Table 1: tuning time. Ansor's search space was reproduced in the
//! MetaSchedule language (Appendix A.5), so both systems tune the same
//! five models. We report wall-clock seconds *normalized to the nominal
//! trial budget* (time/measurement x budget): the MetaSchedule task
//! scheduler keeps spending until the budget is exhausted while the
//! Ansor-style per-task loop can exit early when its candidate pool
//! dries up, so raw wall-clock would compare different amounts of work.
//! Shape claim: MetaSchedule tuning time <= Ansor per measurement.

use std::time::Instant;

use crate::baselines::Ansor;
use crate::ctx::TuneContext;
use crate::exp::{ExpConfig, Report};
use crate::graph::{self, extract_tasks};
use crate::search::{Measurer, SearchConfig, SimMeasurer, TaskScheduler};
use crate::sim::Target;

pub const TABLE1_MODELS: [&str; 5] = [
    "resnet50",
    "bert-base",
    "mobilenet-v2",
    "gpt2",
    "inception-v1",
];

/// Run Table 1 on one target; "latency" columns are normalized tuning
/// seconds for `cfg.trials x tasks` measurements.
pub fn run(target: &Target, cfg: &ExpConfig, models: Option<&[&str]>) -> Report {
    let models: Vec<&str> = models.map(|m| m.to_vec()).unwrap_or(TABLE1_MODELS.to_vec());
    let mut report = Report::new(
        "table1",
        &format!("Table 1: tuning time (s, budget-normalized) on {}", target.name),
    );
    // Table 1 measures tuning *time*; a warm database would let the
    // MetaSchedule arm skip measurements and fake a speedup, so this
    // experiment deliberately ignores --db.
    if cfg.db_path.is_some() {
        report.notes.push("--db ignored: tuning-time comparison must run cold".into());
    }
    if cfg.rules.is_some() {
        report.notes.push("--rules ignored: both systems must tune the same fixed space".into());
    }
    if cfg.mutators.is_some() || cfg.postprocs.is_some() {
        report.notes.push("--mutators/--postprocs ignored: both systems use the default policy".into());
    }
    for m in models {
        let ops = graph::by_name(m).expect("unknown model");
        let tasks = extract_tasks(&ops);
        let nominal = (cfg.trials * tasks.len()) as f64;

        // Ansor-style: frozen sketches, one tune per task.
        let t0 = Instant::now();
        let mut ansor_measurements = 0usize;
        for t in &tasks {
            let mut meas = SimMeasurer::new(target.clone());
            let _ = Ansor { num_trials: cfg.trials, threads: cfg.threads }.tune(&t.prog, target, &mut meas, cfg.seed);
            ansor_measurements += meas.count();
        }
        let ansor_s = t0.elapsed().as_secs_f64() / ansor_measurements.max(1) as f64 * nominal;

        // MetaSchedule: traces + task scheduler over the generic space
        // (always generic — a custom --rules spec would change the work
        // measured and void the tuning-time comparison).
        let ctx = TuneContext::generic(target.clone());
        let t1 = Instant::now();
        let mut meas = SimMeasurer::new(target.clone());
        let ts = TaskScheduler::new(SearchConfig {
            threads: cfg.threads,
            ..SearchConfig::default()
        });
        let _ = ts.tune_tasks(&tasks, &ctx, &mut meas, cfg.trials * tasks.len(), cfg.seed);
        let ms_s = t1.elapsed().as_secs_f64() / meas.count().max(1) as f64 * nominal;

        // Same scheduler under gradient allocation + rank objective:
        // shows what the pluggable policies cost/save in tuning time at
        // the identical total budget (quality is compared in the
        // sched-smoke bench, not here).
        let t2 = Instant::now();
        let mut gmeas = SimMeasurer::new(target.clone());
        let mut gts = TaskScheduler::new(SearchConfig {
            threads: cfg.threads,
            ..SearchConfig::default()
        });
        gts.allocation = crate::search::Allocation::Gradient;
        gts.objective = crate::cost_model::Objective::PairwiseRank;
        let _ = gts.tune_tasks(&tasks, &ctx, &mut gmeas, cfg.trials * tasks.len(), cfg.seed);
        let grad_s = t2.elapsed().as_secs_f64() / gmeas.count().max(1) as f64 * nominal;

        report.push(m, "TVM-Ansor", ansor_s);
        report.push(m, "MetaSchedule", ms_s);
        report.push(m, "MetaSchedule-grad-rank", grad_s);
    }
    let faster = report
        .workloads()
        .iter()
        .filter(|w| {
            report.latency(w, "MetaSchedule").unwrap()
                <= report.latency(w, "TVM-Ansor").unwrap() * 1.05
        })
        .count();
    report.notes.push(format!(
        "MetaSchedule tuning time <= Ansor (within 5%) on {faster}/{} models",
        report.workloads().len()
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke_single_model() {
        let cfg = ExpConfig { trials: 8, seed: 1, ..ExpConfig::default() };
        let r = run(&Target::cpu_avx512(), &cfg, Some(&["mobilenet-v2"]));
        assert!(r.latency("mobilenet-v2", "TVM-Ansor").unwrap() > 0.0);
        assert!(r.latency("mobilenet-v2", "MetaSchedule").unwrap() > 0.0);
        assert!(r.latency("mobilenet-v2", "MetaSchedule-grad-rank").unwrap() > 0.0);
    }
}
