//! Figure 8: operator- and subgraph-level performance. 12 workloads
//! (Appendix A.2) x {PyTorch (vendor), TVM (best of AutoTVM/Ansor),
//! MetaSchedule} on the CPU and GPU targets.
//!
//! Paper shape claims this must reproduce: MetaSchedule similar-or-better
//! than TVM everywhere; MetaSchedule beats PyTorch significantly on most
//! workloads *except SFM*, where the vendor's hand-fused softmax wins.

use crate::baselines::vendor_latency;
use crate::db::Database;
use crate::exp::{open_db, tune_tvm_best, tune_with_ctx_db, ExpConfig, Report};
use crate::sim::Target;
use crate::tir::structural_hash;
use crate::workloads;

/// Run Figure 8 for one target; `subset` limits workloads (None = all 12).
pub fn run(target: &Target, cfg: &ExpConfig, subset: Option<&[&str]>) -> Report {
    let mut report = Report::new(
        &format!("fig8-{}", target.name),
        &format!("Figure 8: operator/subgraph latency on {}", target.name),
    );
    // One db open for the whole figure (re-opening per workload would
    // re-parse the JSONL file O(workloads) times), registered under the
    // Figure-8 display names so `db top --workload GMM` finds them.
    let mut db = open_db(cfg);
    let ctx = cfg.context(target);
    for w in workloads::suite() {
        if let Some(names) = subset {
            if !names.contains(&w.name) {
                continue;
            }
        }
        let prog = (w.build)();
        db.register_workload(w.name, structural_hash(&prog), target.name);
        report.push(w.name, "PyTorch", vendor_latency(&prog, target));
        report.push(w.name, "TVM", tune_tvm_best(&prog, target, cfg));
        let ms = tune_with_ctx_db(&prog, &ctx, cfg, db.as_mut());
        report.push(w.name, "MetaSchedule", ms.best_latency_s);
    }
    summarize(&mut report);
    report
}

fn summarize(report: &mut Report) {
    let mut ms_beats_pt = 0;
    let mut ms_close_to_tvm = 0;
    let mut n = 0;
    for w in report.workloads() {
        let (Some(pt), Some(tvm), Some(ms)) = (
            report.latency(&w, "PyTorch"),
            report.latency(&w, "TVM"),
            report.latency(&w, "MetaSchedule"),
        ) else {
            continue;
        };
        n += 1;
        if ms < pt {
            ms_beats_pt += 1;
        }
        // "similar or better": within 10% or faster.
        if ms <= tvm * 1.1 {
            ms_close_to_tvm += 1;
        }
    }
    report.notes.push(format!(
        "MetaSchedule beats PyTorch on {ms_beats_pt}/{n}; similar-or-better than TVM on {ms_close_to_tvm}/{n}"
    ));
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fast smoke over a representative subset; the full 12x2 run is the
    /// `fig8_operators` bench / `metaschedule exp fig8`.
    #[test]
    fn fig8_subset_shape_claims_hold_on_cpu() {
        let cfg = ExpConfig { trials: 48, seed: 7, ..ExpConfig::default() };
        let r = run(
            &Target::cpu_avx512(),
            &cfg,
            Some(&["GMM", "SFM", "DEP"]),
        );
        // MetaSchedule beats the vendor on GMM and DEP...
        let gmm_ms = r.latency("GMM", "MetaSchedule").unwrap();
        let gmm_pt = r.latency("GMM", "PyTorch").unwrap();
        assert!(gmm_ms < gmm_pt, "GMM: ms {gmm_ms} vs pt {gmm_pt}");
        let dep_ms = r.latency("DEP", "MetaSchedule").unwrap();
        let dep_pt = r.latency("DEP", "PyTorch").unwrap();
        assert!(dep_ms < dep_pt, "DEP: ms {dep_ms} vs pt {dep_pt}");
        // ...but the hand-fused vendor softmax wins SFM (paper Figure 8).
        let sfm_ms = r.latency("SFM", "MetaSchedule").unwrap();
        let sfm_pt = r.latency("SFM", "PyTorch").unwrap();
        assert!(sfm_pt < sfm_ms, "SFM: pt {sfm_pt} vs ms {sfm_ms}");
    }
}
