//! Figure 9: end-to-end model optimization. {BERT-base, ResNet-50,
//! MobileNet-v2} x {PyTorch, TVM (Ansor), MetaSchedule} on CPU and GPU.
//!
//! Shape claim: MetaSchedule reaches parity-or-better with TVM on every
//! model and beats PyTorch on all of them.

use crate::baselines::Ansor;
use crate::exp::{ExpConfig, Report};
use crate::graph::{self, extract_fused_tasks, extract_tasks};
use crate::search::{AllocationReport, SearchConfig, SimMeasurer, TaskScheduler};
use crate::sim::Target;

pub const FIG9_MODELS: [&str; 3] = ["bert-base", "resnet50", "mobilenet-v2"];

/// End-to-end latency with the MetaSchedule task scheduler. With
/// `cfg.db_path` set the whole model tune reads/commits one shared
/// database, so a killed run resumes from the tasks it already tuned.
pub fn metaschedule_e2e(model: &str, target: &Target, cfg: &ExpConfig) -> f64 {
    metaschedule_e2e_report(model, target, cfg).0
}

/// Like [`metaschedule_e2e`], also returning the scheduler's
/// [`AllocationReport`] (per-task budget shares + time-to-quality
/// curve) for the CLI and the sched-smoke bench.
pub fn metaschedule_e2e_report(
    model: &str,
    target: &Target,
    cfg: &ExpConfig,
) -> (f64, AllocationReport) {
    let ops = graph::by_name(model).expect("unknown model");
    let tasks = extract_tasks(&ops);
    tune_tasks_e2e_report(&tasks, target, cfg)
}

/// End-to-end latency with graph-level fusion: tasks are extracted from
/// the fused operator DAG (fewer, larger tasks; interior buffers never
/// round-trip through memory between ops) and tuned with the same
/// scheduler and the same *total* trial budget convention (trials/task).
pub fn metaschedule_fused_e2e(model: &str, target: &Target, cfg: &ExpConfig) -> f64 {
    metaschedule_fused_e2e_report(model, target, cfg).0
}

/// Report-returning variant of [`metaschedule_fused_e2e`].
pub fn metaschedule_fused_e2e_report(
    model: &str,
    target: &Target,
    cfg: &ExpConfig,
) -> (f64, AllocationReport) {
    let g = graph::graph_by_name(model).expect("unknown model");
    let tasks = extract_fused_tasks(&g);
    tune_tasks_e2e_report(&tasks, target, cfg)
}

fn tune_tasks_e2e_report(
    tasks: &[crate::search::Task],
    target: &Target,
    cfg: &ExpConfig,
) -> (f64, AllocationReport) {
    let ctx = cfg.context(target);
    let mut measurer = SimMeasurer::new(target.clone());
    let mut db = crate::exp::open_db(cfg);
    let mut ts = TaskScheduler::new(SearchConfig {
        threads: cfg.threads,
        ..SearchConfig::default()
    });
    ts.allocation = cfg.alloc;
    ts.objective = cfg.objective;
    let total = cfg.trials * tasks.len();
    let (results, report) =
        ts.tune_tasks_report(tasks, &ctx, &mut measurer, db.as_mut(), total, cfg.seed);
    (TaskScheduler::e2e_latency(tasks, &results), report)
}

/// End-to-end latency with the Ansor baseline: per-task tuning with the
/// frozen sketch rules, same trial budget per task.
pub fn ansor_e2e(model: &str, target: &Target, cfg: &ExpConfig) -> f64 {
    let ops = graph::by_name(model).expect("unknown model");
    let tasks = extract_tasks(&ops);
    let mut total = 0.0;
    for t in &tasks {
        let mut measurer = SimMeasurer::new(target.clone());
        let r = Ansor { num_trials: cfg.trials, threads: cfg.threads }.tune(&t.prog, target, &mut measurer, cfg.seed);
        total += r.best_latency_s * t.weight as f64;
    }
    total
}

/// Run Figure 9 for one target over `models` (default FIG9_MODELS).
/// Tuned systems report the median of three independent tuning runs —
/// evolutionary search at these (paper-scale-shrunk) budgets has real
/// seed variance, and the median is the standard robust summary.
pub fn run(target: &Target, cfg: &ExpConfig, models: Option<&[&str]>) -> Report {
    let models: Vec<&str> = models.map(|m| m.to_vec()).unwrap_or(FIG9_MODELS.to_vec());
    let mut report = Report::new(
        &format!("fig9-{}", target.name),
        &format!("Figure 9: end-to-end model latency on {}", target.name),
    );
    let median3 = |f: &dyn Fn(u64) -> f64| {
        let mut v = [f(cfg.seed), f(cfg.seed ^ 0x5bd1e995), f(cfg.seed ^ 0x2545f491)];
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[1]
    };
    // The three seed runs must stay statistically independent — one
    // shared db would make them cold/warm/warmer and bias the median —
    // so each seed resumes its own per-seed file.
    let seed_cfg = |s: u64| ExpConfig {
        seed: s,
        db_path: cfg.db_path.as_ref().map(|p| format!("{p}.seed{s}")),
        ..cfg.clone()
    };
    for m in models {
        let ops = graph::by_name(m).expect("unknown model");
        report.push(m, "PyTorch", graph::vendor_e2e(&ops, target));
        report.push(m, "TVM", median3(&|s| ansor_e2e(m, target, &seed_cfg(s))));
        report.push(
            m,
            "MetaSchedule",
            median3(&|s| metaschedule_e2e(m, target, &seed_cfg(s))),
        );
        // Extension arm: gradient allocation + rank objective at the
        // same total budget as the plain MetaSchedule arm. Per-seed db
        // suffix keeps its records out of the greedy+mse arm's files.
        let grad_cfg = |s: u64| ExpConfig {
            alloc: crate::search::Allocation::Gradient,
            objective: crate::cost_model::Objective::PairwiseRank,
            db_path: cfg.db_path.as_ref().map(|p| format!("{p}.grad.seed{s}")),
            ..seed_cfg(s)
        };
        report.push(
            m,
            "MetaSchedule-grad-rank",
            median3(&|s| metaschedule_e2e(m, target, &grad_cfg(s))),
        );
        // The fused arm is this repo's extension beyond the paper's
        // figure: same scheduler over the graph-fused task set. Per-seed
        // db suffix keeps fused and per-op task records separate.
        let fused_cfg = |s: u64| ExpConfig {
            db_path: cfg.db_path.as_ref().map(|p| format!("{p}.fused.seed{s}")),
            ..seed_cfg(s)
        };
        report.push(
            m,
            "MetaSchedule-fused",
            median3(&|s| metaschedule_fused_e2e(m, target, &fused_cfg(s))),
        );
    }
    let mut parity = 0;
    let mut beats_pt = 0;
    let ws = report.workloads();
    for w in &ws {
        let (pt, tvm, ms) = (
            report.latency(w, "PyTorch").unwrap(),
            report.latency(w, "TVM").unwrap(),
            report.latency(w, "MetaSchedule").unwrap(),
        );
        if ms <= tvm * 1.1 {
            parity += 1;
        }
        if ms < pt {
            beats_pt += 1;
        }
    }
    report.notes.push(format!(
        "parity-or-better with TVM on {parity}/{}; beats PyTorch on {beats_pt}/{}",
        ws.len(),
        ws.len()
    ));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_mobilenet_cpu_smoke() {
        // Small budget smoke: MetaSchedule must beat the vendor e2e.
        let cfg = ExpConfig { trials: 32, seed: 3, ..ExpConfig::default() };
        let r = run(&Target::cpu_avx512(), &cfg, Some(&["mobilenet-v2"]));
        let pt = r.latency("mobilenet-v2", "PyTorch").unwrap();
        let ms = r.latency("mobilenet-v2", "MetaSchedule").unwrap();
        assert!(ms > 0.0 && pt > 0.0);
        assert!(ms < pt, "ms {ms} vs pt {pt}");
        // The fused arm tunes fewer, larger tasks and must also beat the
        // vendor number (the fused <= per-op check runs at CI budgets).
        let fused = r.latency("mobilenet-v2", "MetaSchedule-fused").unwrap();
        assert!(fused > 0.0 && fused < pt, "fused {fused} vs pt {pt}");
        // The gradient+rank arm runs at the same budget; its quality gate
        // (<= greedy+mse on at least one model) lives in the sched-smoke
        // bench where budgets are big enough to leave the warmup phase.
        let grad = r.latency("mobilenet-v2", "MetaSchedule-grad-rank").unwrap();
        assert!(grad > 0.0 && grad.is_finite(), "grad-rank arm produced {grad}");
    }
}
