//! Vendor-library baseline (the paper's "PyTorch" bars): a stand-in for
//! cuDNN/MKL-backed framework execution.
//!
//! Substitution record (DESIGN.md §3): we model a vendor library as a
//! fixed, expert-written kernel per operator *class* running at a
//! class-specific fraction of the target's roofline, with one kernel
//! launch per operator and single-pass memory traffic. The efficiency
//! fractions encode the well-known profile of vendor libraries: superbly
//! tuned elementwise/softmax/normalization kernels (hand-fused single
//! pass — this is why the paper's SFM bar favors PyTorch), solid but
//! shape-sensitive GEMM/conv, and weak exotic convolutions (depthwise,
//! grouped, dilated, transposed — the cases the paper's intro motivates).

use crate::sim::Target;
use crate::space::analysis::is_matmul_like;
use crate::tir::analysis::program_flops;
use crate::tir::{ItemId, Program};

/// Operator classes a vendor library dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Gemm,
    Conv,
    ExoticConv,
    Elementwise,
    ReduceFused,
}

/// Vendor efficiency (fraction of roofline) per class and target kind.
/// CPU numbers reflect MKL/oneDNN on 18 cores; GPU numbers reflect
/// cuBLAS/cuDNN *f32, batch-1* on a consumer part — skinny seq-128 GEMMs
/// and NCHW convs occupy a fraction of the 46 SMs and run well below the
/// large-batch roofline the libraries are tuned for.
pub fn efficiency(class: OpClass, kind: crate::sim::TargetKind) -> f64 {
    use crate::sim::TargetKind::*;
    match (class, kind) {
        // GEMM on an arbitrary (small) shape: good, not perfect.
        (OpClass::Gemm, Cpu) => 0.55,
        (OpClass::Gemm, Gpu) => 0.35,
        // Dense convolution at batch 1 / odd shapes: vendor conv kernels
        // are tuned for large-batch common configs; the batch-1 path runs
        // at a small fraction of roofline (the paper's motivation).
        (OpClass::Conv, Cpu) => 0.20,
        (OpClass::Conv, Gpu) => 0.15,
        // Depthwise / grouped / dilated / transposed: vendor weak spot.
        (OpClass::ExoticConv, _) => 0.07,
        // memcpy-class kernels.
        (OpClass::Elementwise, _) => 0.85,
        // Hand-fused softmax/layernorm single-pass kernels.
        (OpClass::ReduceFused, _) => 0.95,
    }
}

/// Classify one block.
fn classify_block(p: &Program, b: ItemId) -> OpClass {
    let bd = p.block_data(b);
    if !bd.is_reduction() {
        return OpClass::Elementwise;
    }
    if is_matmul_like(p, b) {
        // Conv vs plain GEMM: convs read with strided/offset indices
        // (multiple loop vars per index dim).
        let conv_like = bd.reads.iter().any(|r| {
            r.ranges.iter().any(|(s, _)| {
                let mut vars = Vec::new();
                s.collect_vars(&mut vars);
                vars.sort_unstable();
                vars.dedup();
                vars.len() >= 2
            })
        });
        if conv_like {
            // Exotic if reuse is low: depthwise/grouped convs have fewer
            // input channels contributing per output.
            let reduce_extent: i64 = bd.reduce_iters().map(|iv| iv.extent).product();
            if reduce_extent < 64 {
                return OpClass::ExoticConv;
            }
            return OpClass::Conv;
        }
        return OpClass::Gemm;
    }
    // Reduction that is not a MAC: row-sum/max etc. — vendor fuses the
    // whole softmax/norm pattern.
    OpClass::ReduceFused
}

/// Classify a whole program by its dominant (most-flops) block, with the
/// multi-block reduce patterns (softmax, norm) treated as one fused op.
pub fn classify(p: &Program) -> OpClass {
    let blocks = p.blocks();
    let mut best = (0.0f64, OpClass::Elementwise);
    let mut saw_reduce_fused = false;
    for &b in &blocks {
        let bd = p.block_data(b);
        let fl = crate::tir::analysis::block_trip_count(p, b) as f64 * bd.body.flops().max(0.5);
        let c = classify_block(p, b);
        if c == OpClass::ReduceFused {
            saw_reduce_fused = true;
        }
        if fl > best.0 {
            best = (fl, c);
        }
    }
    // A softmax/norm pattern (non-MAC reductions + elementwise) dispatches
    // to the vendor's fused kernel even if an elementwise block dominates.
    if saw_reduce_fused && matches!(best.1, OpClass::Elementwise | OpClass::ReduceFused) {
        return OpClass::ReduceFused;
    }
    best.1
}

/// Vendor-library latency estimate for `prog` on `target`.
///
/// latency = max(flops / (eff * peak), unique_bytes / (eff_mem * dram_bw))
///           + one kernel launch per fused op.
pub fn latency(prog: &Program, target: &Target) -> f64 {
    let class = classify(prog);
    let eff = efficiency(class, target.kind);
    let flops = program_flops(prog);
    // Single-pass traffic: every parameter buffer moves once. (Vendor
    // kernels keep intermediates fused in registers/smem.)
    let bytes: f64 = prog
        .params
        .iter()
        .map(|&b| prog.buffers[b].bytes() as f64)
        .sum();
    let compute = flops / (eff * target.peak_flops());
    // Memory efficiency: vendor kernels stream near peak bandwidth.
    let mem = bytes / (0.85 * target.dram_bandwidth);
    // Framework eager-dispatch overhead: the well-documented 5-15us
    // PyTorch pays per operator call (tensor wrapping, dispatcher,
    // autograd bookkeeping) — a first-order effect for the paper's
    // batch-1, odd-shape workloads and a key reason tuned code wins small
    // ops. Fused patterns dispatch once.
    let dispatch = match target.kind {
        crate::sim::TargetKind::Gpu => 12e-6,
        crate::sim::TargetKind::Cpu => 8e-6,
    };
    let dispatches = match class {
        OpClass::ReduceFused | OpClass::Elementwise => 1.0,
        _ => prog.roots.len() as f64,
    };
    compute.max(mem) + dispatches * dispatch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Target;
    use crate::workloads;

    #[test]
    fn classes_match_expectations() {
        let get = |n: &str| (workloads::by_name(n).unwrap().build)();
        assert_eq!(classify(&get("GMM")), OpClass::Gemm);
        assert_eq!(classify(&get("TBG")), OpClass::Gemm);
        assert_eq!(classify(&get("C2D")), OpClass::Conv);
        assert_eq!(classify(&get("DEP")), OpClass::ExoticConv);
        assert_eq!(classify(&get("SFM")), OpClass::ReduceFused);
        assert_eq!(classify(&get("NRM")), OpClass::ReduceFused);
    }

    #[test]
    fn vendor_latencies_positive_and_plausible() {
        let cpu = Target::cpu_avx512();
        for w in workloads::suite() {
            let p = (w.build)();
            let l = latency(&p, &cpu);
            assert!(l > 0.0 && l < 1.0, "{}: {l}", w.name);
        }
    }

    #[test]
    fn softmax_vendor_is_fast_single_pass() {
        // Vendor softmax ~ memory roofline of one pass over in+out.
        let cpu = Target::cpu_avx512();
        let p = workloads::softmax(1, 256, 256);
        let l = latency(&p, &cpu);
        let one_pass = (2.0 * 256.0 * 256.0 * 4.0) / cpu.dram_bandwidth;
        assert!(l < one_pass * 10.0 && l >= one_pass, "{l} vs {one_pass}");
    }

    #[test]
    fn depthwise_vendor_is_weak() {
        let cpu = Target::cpu_avx512();
        let dep = (workloads::by_name("DEP").unwrap().build)();
        let e = efficiency(classify(&dep), crate::sim::TargetKind::Cpu);
        assert!(e < 0.25);
    }
}
