//! Ansor-style auto-scheduler (paper §3.3, "Auto-scheduling").
//!
//! Ansor generates search spaces from *hard-coded, workload-agnostic
//! sketch rules* baked into the system. Functionally its space matches
//! MetaSchedule's generic module composition (the paper reports
//! performance parity in Figures 8/9); the difference the paper stresses
//! is architectural — the rule list here is a frozen constant, not a
//! user-composable module set, and cannot accept hardware-specific
//! extensions like Use-Tensor-Core without a system revamp (Appendix A.4).

use crate::cost_model::GbtCostModel;
use crate::ctx::TuneContext;
use crate::search::{EvolutionarySearch, Measurer, SearchConfig, TuneResult};
use crate::sim::{Target, TargetKind};
use crate::space::{
    AutoInline, CrossThreadReduction, MultiLevelTiling, ParallelVectorizeUnroll,
    RandomComputeLocation, ScheduleRule, ThreadBind,
};
use crate::tir::Program;

/// The frozen sketch-rule list. Deliberately *not* configurable: this is
/// the "surgical changes required" property the paper contrasts against.
fn frozen_sketch_rules(target: &Target) -> Vec<Box<dyn ScheduleRule>> {
    match target.kind {
        TargetKind::Cpu => vec![
            Box::new(AutoInline::new()),
            Box::new(MultiLevelTiling::cpu()),
            Box::new(RandomComputeLocation::new()),
            Box::new(ParallelVectorizeUnroll::new()),
        ],
        TargetKind::Gpu => vec![
            Box::new(AutoInline::new()),
            Box::new(MultiLevelTiling::gpu()),
            Box::new(CrossThreadReduction::new()),
            Box::new(RandomComputeLocation::new()),
            Box::new(ThreadBind::new()),
        ],
    }
}

/// Ansor-style tuner: frozen sketches + evolutionary fine-tuning with a
/// learned cost model (same learner class as ours, per [43]).
pub struct Ansor {
    pub num_trials: usize,
    /// OS threads for the inner evolutionary search (0 = auto);
    /// plumbed so baseline timing comparisons share the cap.
    pub threads: usize,
}

impl Ansor {
    pub fn tune(
        &self,
        prog: &Program,
        target: &Target,
        measurer: &mut dyn Measurer,
        seed: u64,
    ) -> TuneResult {
        // Deliberately bypasses the rule registry: Ansor's rule list is a
        // frozen constant, which is exactly the architectural contrast the
        // paper draws against MetaSchedule's named, user-extensible sets.
        let ctx = TuneContext::from_rules(frozen_sketch_rules(target), target.clone());
        let cfg = SearchConfig {
            num_trials: self.num_trials,
            threads: self.threads,
            ..SearchConfig::default()
        };
        // Ansor re-runs sketch generation every search round; MetaSchedule
        // instead re-executes recorded traces (the paper's §4 "execution
        // tracing" motivation: avoid repeated re-execution of the host
        // program). Model that per-round regeneration cost here — it is
        // what Table 1's tuning-time gap measures.
        let rounds = self.num_trials.div_ceil(cfg.measure_batch);
        for r in 1..rounds {
            let _ = ctx.generate(prog, seed.wrapping_add(r as u64));
        }
        let mut model = GbtCostModel::new();
        EvolutionarySearch::new(cfg).tune(prog, &ctx, &mut model, measurer, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::SimMeasurer;
    use crate::sim::simulate;
    use crate::workloads;

    #[test]
    fn ansor_tunes_cpu_and_gpu() {
        for target in [Target::cpu_avx512(), Target::gpu()] {
            let prog = workloads::matmul(1, 128, 128, 128);
            let naive = simulate(&prog, &target).unwrap().total_s;
            let mut m = SimMeasurer::new(target.clone());
            let r = Ansor { num_trials: 32, threads: 0 }.tune(&prog, &target, &mut m, 0);
            assert!(
                r.best_latency_s < naive * 0.5,
                "{}: {} vs {naive}",
                target.name,
                r.best_latency_s
            );
        }
    }

    #[test]
    fn ansor_has_no_tensor_core_rule() {
        // The frozen rule list must not contain use-tensor-core — that is
        // the paper's Figure 10b premise.
        let rules = frozen_sketch_rules(&Target::gpu());
        assert!(rules.iter().all(|r| r.name() != "use-tensor-core"));
    }
}
