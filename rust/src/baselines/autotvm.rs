//! AutoTVM-style template-guided tuner (paper §3.3, "Template-guided
//! auto-tuning").
//!
//! The defining property, per the paper: *all random variables are decided
//! ahead of the transformations* — the template enumerates a rigid grid
//! (power-of-two tile sizes, fixed 3-level structure, fixed thread
//! palettes) with no sampling conditioned on intermediate program state.
//! Configurations that do not divide the loop extents are simply invalid
//! points of the grid, exactly like real AutoTVM configs that fail to
//! build. Search is the classic measure-everything random walk over the
//! grid (no trace mutation, no learned proposals).

use crate::schedule::{SchResult, Schedule};
use crate::search::{Measurer, TuneResult};
use crate::sim::{Target, TargetKind};
use crate::space::analysis::needs_multi_level_tiling;
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::{LoopKind, Program};
use crate::trace::FactorArg;
use crate::util::rng::Rng;

/// One grid point: every knob fixed before any transformation runs.
#[derive(Debug, Clone)]
struct Config {
    /// Seed for the per-slot knob draws (knob domains are static divisor
    /// grids of the *initial* program's loop extents — AutoTVM's
    /// `define_split` — so drawing them lazily by slot is equivalent to
    /// materializing the whole grid point up front).
    knob_rng: Rng,
    /// GPU threads per block.
    threads: i64,
    /// Unroll pragma.
    unroll: i64,
}

const THREADS: [i64; 4] = [64, 128, 256, 512];
const UNROLL: [i64; 3] = [0, 64, 512];

fn draw_config(rng: &mut Rng) -> Config {
    Config {
        knob_rng: rng.split(),
        threads: THREADS[rng.gen_range(THREADS.len())],
        unroll: UNROLL[rng.gen_range(UNROLL.len())],
    }
}

fn divisors(x: i64) -> Vec<i64> {
    let mut out = Vec::new();
    let mut d = 1;
    while d * d <= x {
        if x % d == 0 {
            out.push(d);
            if d != x / d {
                out.push(x / d);
            }
        }
        d += 1;
    }
    out.sort_unstable();
    out
}

/// Knob: a 2-level split of `extent` from its static divisor grid.
fn draw_split2(rng: &mut Rng, extent: i64) -> (i64, i64) {
    let d2 = divisors(extent);
    let t2 = d2[rng.gen_range(d2.len())];
    let d1 = divisors(extent / t2);
    let t1 = d1[rng.gen_range(d1.len())];
    (t1, t2)
}

/// Apply the fixed template with a fully-decided config. Errors mean the
/// grid point is invalid (non-dividing factors etc.).
fn apply_template(prog: &Program, target: &Target, cfg: &Config) -> SchResult<Schedule> {
    let mut cfg = cfg.clone();
    let mut s = Schedule::new(prog.clone(), 0);
    // Deterministic inline pass (templates hard-code operator fusion).
    let names: Vec<String> = s
        .prog
        .blocks()
        .iter()
        .map(|&b| s.prog.block_data(b).name.clone())
        .collect();
    for n in &names {
        if s.prog.find_block(n).is_some() {
            let before = s.clone();
            let r = (|| -> SchResult<()> {
                let b = s.get_block(n)?;
                s.compute_inline(b)
            })();
            if r.is_err() {
                s = before;
            }
        }
    }
    // Per remaining block: fixed 3-level tiling for compute blocks.
    let names: Vec<String> = s
        .prog
        .blocks()
        .iter()
        .map(|&b| s.prog.block_data(b).name.clone())
        .collect();
    for n in &names {
        let Some(item) = s.prog.find_block(n) else { continue };
        let tile = needs_multi_level_tiling(&s.prog, item);
        let b = s.get_block(n)?;
        let loops = s.get_loops(b)?;
        let mut spatial = Vec::new();
        let mut reduce = Vec::new();
        for &l in &loops {
            let li = s.loop_item(l)?;
            if s.prog.loop_data(li).kind != LoopKind::Serial {
                continue;
            }
            let e = s.prog.loop_data(li).extent;
            match classify_loop(&s.prog, li) {
                LoopClass::Spatial if e > 1 => spatial.push(l),
                LoopClass::Reduce if e > 1 => reduce.push(l),
                _ => {}
            }
        }
        if tile && !spatial.is_empty() && !reduce.is_empty() {
            // 3-level spatial x 2-level reduce, factors from the static
            // divisor grid of each loop extent.
            let mut s_tiles = Vec::new();
            for &l in &spatial {
                let e = s.prog.loop_data(s.loop_item(l)?).extent;
                let (t1, t2) = draw_split2(&mut cfg.knob_rng, e);
                s_tiles.push(s.split(
                    l,
                    &[FactorArg::Lit(e / (t1 * t2)), FactorArg::Lit(t1), FactorArg::Lit(t2)],
                )?);
            }
            let mut r_tiles = Vec::new();
            for &l in &reduce {
                let e = s.prog.loop_data(s.loop_item(l)?).extent;
                let d = divisors(e);
                let t = d[cfg.knob_rng.gen_range(d.len())];
                r_tiles.push(s.split(l, &[FactorArg::Lit(e / t), FactorArg::Lit(t)])?);
            }
            // Order: S0 S1 R0 S2 R1 (classic template order, 3-level).
            let mut order = Vec::new();
            for k in 0..2 {
                order.extend(s_tiles.iter().map(|t: &Vec<_>| t[k]));
                order.extend(r_tiles.iter().map(|t: &Vec<_>| t[k]));
            }
            order.extend(s_tiles.iter().map(|t| t[2]));
            s.reorder(&order)?;
            match target.kind {
                TargetKind::Cpu => {
                    let outer: Vec<_> = s_tiles.iter().map(|t| t[0]).collect();
                    let fused = if outer.len() > 1 { s.fuse(&outer)? } else { outer[0] };
                    s.parallel(fused)?;
                    let last = *s_tiles.last().unwrap().last().unwrap();
                    let li = s.loop_item(last)?;
                    if s.prog.loop_data(li).extent > 1 {
                        s.vectorize(last)?;
                    }
                }
                TargetKind::Gpu => {
                    let outer: Vec<_> = s_tiles.iter().map(|t| t[0]).collect();
                    let grid = if outer.len() > 1 { s.fuse(&outer)? } else { outer[0] };
                    s.bind(grid, "blockIdx.x")?;
                    let mid: Vec<_> = s_tiles.iter().map(|t| t[1]).collect();
                    let tb = if mid.len() > 1 { s.fuse(&mid)? } else { mid[0] };
                    s.bind(tb, "threadIdx.x")?;
                }
            }
            if cfg.unroll > 0 {
                let outer = s.get_loops(b)?[0];
                s.annotate_loop(outer, "pragma_auto_unroll_max_step", &cfg.unroll.to_string())?;
            }
        } else {
            // Non-tiled blocks: flat parallel/bind template.
            match target.kind {
                TargetKind::Cpu => {
                    if let Some(&first) = spatial.first() {
                        s.parallel(first)?;
                    }
                    if spatial.len() >= 2 {
                        let last = *spatial.last().unwrap();
                        let li = s.loop_item(last)?;
                        if s.prog.loops_above(s.block(b)?).last() == Some(&li)
                            && s.prog.loop_data(li).extent > 1
                        {
                            s.vectorize(last)?;
                        }
                    }
                }
                TargetKind::Gpu => {
                    if spatial.is_empty() {
                        continue;
                    }
                    let fused = if spatial.len() > 1 { s.fuse(&spatial)? } else { spatial[0] };
                    let e = s.prog.loop_data(s.loop_item(fused)?).extent;
                    let t = cfg.threads;
                    if e % t == 0 && e / t >= 1 {
                        let parts = s.split(fused, &[FactorArg::Lit(e / t), FactorArg::Lit(t)])?;
                        s.bind(parts[0], "blockIdx.x")?;
                        s.bind(parts[1], "threadIdx.x")?;
                    } else {
                        s.bind(fused, "threadIdx.x")?;
                    }
                }
            }
        }
    }
    Ok(s)
}

/// The AutoTVM-style tuner: random walk over the config grid.
pub struct AutoTvm {
    pub num_trials: usize,
}

impl AutoTvm {
    pub fn tune(
        &self,
        prog: &Program,
        target: &Target,
        measurer: &mut dyn Measurer,
        seed: u64,
    ) -> TuneResult {
        let t0 = std::time::Instant::now();
        let mut rng = Rng::seed_from_u64(seed);
        let mut best: Option<(f64, Schedule)> = None;
        let mut curve = Vec::new();
        let mut quality = Vec::new();
        let mut trials = 0;
        let mut attempts = 0;
        while trials < self.num_trials && attempts < self.num_trials * 16 {
            attempts += 1;
            let cfg = draw_config(&mut rng);
            let Ok(sch) = apply_template(prog, target, &cfg) else {
                continue; // invalid grid point
            };
            trials += 1;
            let Some(lat) = measurer.measure(&sch.prog) else {
                continue;
            };
            if best.as_ref().map(|(b, _)| lat < *b).unwrap_or(true) {
                best = Some((lat, sch));
            }
            let best_now = best.as_ref().unwrap().0;
            curve.push((trials, best_now));
            quality.push(crate::search::QualityPoint {
                trials,
                best_latency_s: best_now,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }
        let (best_latency_s, best_sch) =
            best.expect("autotvm: no valid config found within budget");
        TuneResult {
            task: prog.name.clone(),
            best_latency_s,
            best_trace: best_sch.trace,
            best_prog: best_sch.prog,
            trials,
            curve,
            quality,
            warm_records: 0,
            transferred_records: 0,
            stale_skipped: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::SimMeasurer;
    use crate::sim::simulate;
    use crate::workloads;

    #[test]
    fn template_tunes_gmm_on_cpu() {
        let t = Target::cpu_avx512();
        let prog = workloads::matmul(1, 128, 128, 128);
        let naive = simulate(&prog, &t).unwrap().total_s;
        let mut m = SimMeasurer::new(t.clone());
        let r = AutoTvm { num_trials: 32 }.tune(&prog, &t, &mut m, 0);
        assert!(r.best_latency_s < naive);
    }

    #[test]
    fn template_tunes_softmax_on_gpu() {
        let t = Target::gpu();
        let prog = workloads::softmax(1, 256, 256);
        let naive = simulate(&prog, &t).unwrap().total_s;
        let mut m = SimMeasurer::new(t.clone());
        let r = AutoTvm { num_trials: 24 }.tune(&prog, &t, &mut m, 1);
        assert!(r.best_latency_s < naive);
    }

    #[test]
    fn invalid_grid_points_are_skipped_not_fatal() {
        // 100 is not divisible by most pow2 products; tuner must survive.
        let t = Target::cpu_avx512();
        let prog = workloads::matmul(1, 100, 100, 100);
        let mut m = SimMeasurer::new(t.clone());
        let r = AutoTvm { num_trials: 16 }.tune(&prog, &t, &mut m, 2);
        assert!(r.best_latency_s.is_finite());
    }

    #[test]
    fn all_suite_workloads_tunable() {
        let t = Target::cpu_avx512();
        for w in workloads::suite() {
            let prog = (w.build)();
            let mut m = SimMeasurer::new(t.clone());
            let r = AutoTvm { num_trials: 8 }.tune(&prog, &t, &mut m, 3);
            assert!(r.best_latency_s > 0.0, "{}", w.name);
        }
    }
}
