//! Baseline tensor-program optimizers the paper compares against
//! (DESIGN.md §3 records the substitutions):
//!
//! * [`vendor`] — "PyTorch" bars: cuDNN/MKL-class fixed expert kernels,
//!   modeled as per-op-class roofline efficiency.
//! * [`autotvm`] — template-guided tuning: rigid grids decided ahead of
//!   all transformations (§3.3).
//! * [`ansor`] — auto-scheduling with frozen sketch rules + evolutionary
//!   fine-tuning (§3.3); performance parity with MetaSchedule's generic
//!   space, but non-extensible.

pub mod ansor;
pub mod autotvm;
pub mod vendor;

pub use ansor::Ansor;
pub use autotvm::AutoTvm;
pub use vendor::{classify, efficiency, latency as vendor_latency, OpClass};
