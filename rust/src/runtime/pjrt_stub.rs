//! Stub PJRT runner for builds without the `pjrt` feature (the offline
//! image vendors no `xla` crate). Mirrors the real runner's API so the
//! rest of the runtime layer — and everything that links against it —
//! compiles identically; constructing it reports the missing feature.

use std::path::PathBuf;

use crate::util::error::{Error, Result};

/// What the error message tells an operator to do.
const DISABLED: &str =
    "PJRT runtime disabled: rebuild with `--features pjrt` and a vendored `xla` crate";

/// Stub stand-in for the XLA-backed PJRT CPU client.
pub struct PjrtRunner {
    /// Wall-clock measurements performed (always zero on the stub).
    pub measurements: usize,
}

impl PjrtRunner {
    pub fn new(dir: impl Into<PathBuf>) -> Result<PjrtRunner> {
        let _ = dir.into();
        Err(Error::msg(DISABLED))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Execute an artifact on two f32 matrices, returning the flat output.
    pub fn run_f32(
        &mut self,
        _artifact: &str,
        _x: (&[f32], &[i64]),
        _y: (&[f32], &[i64]),
    ) -> Result<Vec<f32>> {
        Err(Error::msg(DISABLED))
    }

    /// Time an artifact: median wall clock per execution.
    pub fn time_artifact(
        &mut self,
        _artifact: &str,
        _x: (&[f32], &[i64]),
        _y: (&[f32], &[i64]),
        _warmup: usize,
        _iters: usize,
    ) -> Result<f64> {
        Err(Error::msg(DISABLED))
    }

    /// Correctness gate against a host-side f32 matmul.
    pub fn verify_gmm(
        &mut self,
        _v: super::TileVariant,
        _m: usize,
        _n: usize,
        _k: usize,
    ) -> Result<f64> {
        Err(Error::msg(DISABLED))
    }
}
