//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` — Python never runs on this path) and execute
//! them on the XLA CPU client for *real wall-clock measurement* `f(e)`.
//!
//! The GMM artifact grid realizes one (bm, bn, bk) Pallas tile variant per
//! file; [`PjrtGmmMeasurer`] maps a scheduled TIR program to its tile
//! sizes (via [`tile_of`]) and times the nearest real executable — closing
//! the loop: L3 search decisions -> L1 kernel schedule -> measured
//! hardware latency.
//!
//! The XLA client itself lives behind the `pjrt` cargo feature: the
//! offline CI image vendors no `xla` crate, so the default build gets a
//! stub [`PjrtRunner`] whose constructor reports the situation instead of
//! compiling the FFI path. Everything above the runner (artifact
//! scanning, tile mapping, the Pallas tile space, the measurer's snap
//! logic) compiles and is tested in every configuration.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::schedule::{LoopRv, SchResult, Schedule};
use crate::search::Measurer;
use crate::sim::Target;
use crate::space::{RuleOutcome, ScheduleRule};
use crate::tir::Program;
use crate::trace::FactorArg;
use crate::util::error::{Error, Result};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRunner;

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::PjrtRunner;

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// One compiled GMM tile variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileVariant {
    pub bm: i64,
    pub bn: i64,
    pub bk: i64,
}

impl TileVariant {
    pub fn artifact_name(&self) -> String {
        format!("gmm_bm{}_bn{}_bk{}.hlo.txt", self.bm, self.bn, self.bk)
    }
}

/// Scan the artifact directory for GMM tile variants.
pub fn scan_variants(dir: &Path) -> Vec<TileVariant> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else {
        return out;
    };
    for e in rd.flatten() {
        let name = e.file_name().to_string_lossy().to_string();
        if let Some(rest) = name
            .strip_prefix("gmm_bm")
            .and_then(|r| r.strip_suffix(".hlo.txt"))
        {
            let parts: Vec<&str> = rest.split('_').collect();
            // bm{X} bn{Y} bk{Z}
            if parts.len() == 3 {
                let bm = parts[0].parse().ok();
                let bn = parts[1].strip_prefix("bn").and_then(|s| s.parse().ok());
                let bk = parts[2].strip_prefix("bk").and_then(|s| s.parse().ok());
                if let (Some(bm), Some(bn), Some(bk)) = (bm, bn, bk) {
                    out.push(TileVariant { bm, bn, bk });
                }
            }
        }
    }
    out.sort_by_key(|v| (v.bm, v.bn, v.bk));
    out
}

/// Extract the (bm, bn, bk) tile of a program scheduled by
/// [`PallasTileModule`]: the innermost three loops above the matmul block
/// (the module reorders to `... i0 j0 k0 i1 j1 k1`).
pub fn tile_of(prog: &Program) -> Option<TileVariant> {
    let b = prog.find_block("matmul")?;
    let loops = prog.loops_above(b);
    if loops.len() < 3 {
        return None;
    }
    let e: Vec<i64> = loops[loops.len() - 3..]
        .iter()
        .map(|&l| prog.loop_data(l).extent)
        .collect();
    Some(TileVariant { bm: e[0], bn: e[1], bk: e[2] })
}

/// Transformation module defining the *Pallas tile* search space for the
/// GMM task: `sample_perfect_tile` on (i, j, k) with the inner factors
/// becoming the kernel block sizes. The realized schedule points are the
/// AOT artifact grid.
pub struct PallasTileModule {
    pub max_tile: i64,
}

impl PallasTileModule {
    pub fn new() -> PallasTileModule {
        PallasTileModule { max_tile: 128 }
    }

    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        // Expect (batch) i j k with batch possibly extent-1.
        let mut work: Vec<LoopRv> = Vec::new();
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).extent > 1 {
                work.push(l);
            }
        }
        if work.len() != 3 {
            return Err(crate::schedule::ScheduleError::Unsupported(format!(
                "pallas tile space expects (i, j, k), got {} loops",
                work.len()
            )));
        }
        let mut outers = Vec::new();
        let mut inners = Vec::new();
        for &l in &work {
            let t = s.sample_perfect_tile(l, 2, self.max_tile)?;
            let parts = s.split(l, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
            outers.push(parts[0]);
            inners.push(parts[1]);
        }
        // i0 j0 k0 i1 j1 k1 — tile_of() reads the last three extents.
        let order: Vec<LoopRv> = outers.into_iter().chain(inners).collect();
        s.reorder(&order)?;
        Ok(())
    }
}

impl Default for PallasTileModule {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for PallasTileModule {
    fn name(&self) -> &str {
        "pallas-tile"
    }

    fn describe(&self) -> String {
        "sample (bm, bn, bk) Pallas block sizes realizable as AOT artifact variants".into()
    }

    fn params(&self) -> Vec<(String, String)> {
        vec![("max-tile".into(), self.max_tile.to_string())]
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        match crate::space::attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

/// Per-device workload key for PJRT measurements: the platform/device
/// string folded into the target name (`pjrt:<platform>`), so records
/// from two physical devices never pool into one workload (the database
/// keys workloads by `(structural hash, target name)`). Lowercased and
/// whitespace-collapsed because the name flows into the JSONL workload
/// registry and CLI flags. The stub runner's platform is `"stub"`, so a
/// feature-off build deterministically yields `pjrt:stub`.
pub fn pjrt_target_name(platform: &str) -> String {
    let folded: String = platform
        .trim()
        .chars()
        .map(|c| if c.is_whitespace() { '-' } else { c.to_ascii_lowercase() })
        .collect();
    if folded.is_empty() {
        "pjrt:unknown".to_string()
    } else {
        format!("pjrt:{folded}")
    }
}

/// Real-hardware measurer for the GMM task: snaps the schedule's tile to
/// the nearest AOT variant and times the actual PJRT executable.
pub struct PjrtGmmMeasurer {
    pub runner: PjrtRunner,
    pub variants: Vec<TileVariant>,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    x: Vec<f32>,
    y: Vec<f32>,
    n_measured: usize,
    /// Measurement cache: tile variant -> latency (schedules snapping to
    /// the same artifact share one timing).
    cache: HashMap<TileVariant, f64>,
    /// Per-device target name ([`pjrt_target_name`]), fixed at
    /// construction from the runner's platform string.
    target: String,
}

impl PjrtGmmMeasurer {
    pub fn new(dir: impl Into<PathBuf>, m: usize, n: usize, k: usize) -> Result<PjrtGmmMeasurer> {
        let dir = dir.into();
        let variants = scan_variants(&dir);
        if variants.is_empty() {
            return Err(Error::msg(format!(
                "no gmm artifacts under {} — run `make artifacts`",
                dir.display()
            )));
        }
        let runner = PjrtRunner::new(dir)?;
        let target = pjrt_target_name(&runner.platform());
        let x = (0..m * k).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
        let y = (0..k * n).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
        Ok(PjrtGmmMeasurer {
            runner,
            variants,
            m,
            n,
            k,
            x,
            y,
            n_measured: 0,
            cache: HashMap::new(),
            target,
        })
    }

    /// Nearest artifact variant in log-tile space.
    pub fn snap(&self, t: TileVariant) -> TileVariant {
        *self
            .variants
            .iter()
            .min_by(|a, b| {
                let d = |v: &TileVariant| {
                    let dl = |x: i64, y: i64| ((x as f64).ln() - (y as f64).ln()).abs();
                    dl(v.bm, t.bm) + dl(v.bn, t.bn) + dl(v.bk, t.bk)
                };
                d(a).partial_cmp(&d(b)).unwrap()
            })
            .expect("non-empty variants")
    }

    pub fn time_variant(&mut self, v: TileVariant) -> Result<f64> {
        if let Some(&l) = self.cache.get(&v) {
            return Ok(l);
        }
        let lat = self.runner.time_artifact(
            &v.artifact_name(),
            (&self.x, &[self.m as i64, self.k as i64]),
            (&self.y, &[self.k as i64, self.n as i64]),
            2,
            9,
        )?;
        self.cache.insert(v, lat);
        Ok(lat)
    }
}

impl Measurer for PjrtGmmMeasurer {
    fn measure(&mut self, prog: &Program) -> Option<f64> {
        let t = tile_of(prog)?;
        let v = self.snap(t);
        self.n_measured += 1;
        self.time_variant(v).ok()
    }

    fn count(&self) -> usize {
        self.n_measured
    }

    fn target_name(&self) -> String {
        self.target.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_name_roundtrip() {
        let v = TileVariant { bm: 32, bn: 32, bk: 64 };
        assert_eq!(v.artifact_name(), "gmm_bm32_bn32_bk64.hlo.txt");
    }

    #[test]
    fn scan_parses_filenames() {
        let dir = std::env::temp_dir().join("ms_scan_test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("gmm_bm16_bn16_bk32.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("fused_dense.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("junk.txt"), "x").unwrap();
        let vs = scan_variants(&dir);
        assert_eq!(vs, vec![TileVariant { bm: 16, bn: 16, bk: 32 }]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tile_module_produces_readable_tiles() {
        let prog = crate::workloads::matmul(1, 128, 128, 128);
        let m = PallasTileModule::new();
        let sch = m
            .apply(
                crate::schedule::Schedule::new(prog, 3),
                "matmul",
                &Target::cpu_avx512(),
            )
            .pop()
            .unwrap();
        let t = tile_of(&sch.prog).unwrap();
        assert_eq!(128 % t.bm, 0);
        assert_eq!(128 % t.bn, 0);
        assert_eq!(128 % t.bk, 0);
        assert!(t.bm <= 128 && t.bn <= 128 && t.bk <= 128);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runner_reports_disabled_feature() {
        let err = PjrtRunner::new("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn pjrt_target_names_are_per_device_and_deterministic() {
        // The stub runner's platform string maps to the documented name.
        assert_eq!(pjrt_target_name("stub"), "pjrt:stub");
        // Real platform strings fold whitespace/case into one stable key.
        assert_eq!(pjrt_target_name("Host CPU"), "pjrt:host-cpu");
        assert_eq!(pjrt_target_name("  cuda:0 "), "pjrt:cuda:0");
        assert_eq!(pjrt_target_name(""), "pjrt:unknown");
        // Two distinct devices never share a workload key.
        assert_ne!(pjrt_target_name("cuda:0"), pjrt_target_name("cuda:1"));
    }

    // PJRT-backed tests live in rust/tests/pjrt_integration.rs (they need
    // `make artifacts` to have run, plus the `pjrt` feature).
}
