//! The real XLA-backed PJRT runner (`--features pjrt`). Requires a
//! vendored `xla` crate; the offline CI image builds the stub instead.
//!
//! Note for whoever vendors `xla`: `Measurer: Send` means
//! `PjrtGmmMeasurer` (and therefore `PjRtClient` /
//! `PjRtLoadedExecutable`) must be `Send`. If the vendored bindings are
//! `!Send`, wrap the runner in a dedicated measurement thread and have
//! the measurer hand work over a channel instead of holding the client
//! directly.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::error::{Context, Error, Result};

/// PJRT CPU client with a compile-once executable cache.
pub struct PjrtRunner {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Wall-clock measurements performed.
    pub measurements: usize,
}

impl PjrtRunner {
    pub fn new(dir: impl Into<PathBuf>) -> Result<PjrtRunner> {
        Ok(PjrtRunner {
            client: xla::PjRtClient::cpu().with_context(|| "creating PJRT CPU client".into())?,
            dir: dir.into(),
            cache: HashMap::new(),
            measurements: 0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, artifact: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(artifact) {
            let path = self.dir.join(artifact);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::msg("non-utf8 path"))?,
            )
            .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {artifact}"))?;
            self.cache.insert(artifact.to_string(), exe);
        }
        Ok(&self.cache[artifact])
    }

    /// Execute an artifact on two f32 matrices, returning the flat output.
    pub fn run_f32(
        &mut self,
        artifact: &str,
        x: (&[f32], &[i64]),
        y: (&[f32], &[i64]),
    ) -> Result<Vec<f32>> {
        let exe = self.load(artifact)?;
        let lx = xla::Literal::vec1(x.0)
            .reshape(x.1)
            .with_context(|| "reshaping x".into())?;
        let ly = xla::Literal::vec1(y.0)
            .reshape(y.1)
            .with_context(|| "reshaping y".into())?;
        let result = exe
            .execute::<xla::Literal>(&[lx, ly])
            .with_context(|| format!("executing {artifact}"))?[0][0]
            .to_literal_sync()
            .with_context(|| "syncing output".into())?;
        // aot.py lowers with return_tuple=True -> 1-tuple output.
        Ok(result
            .to_tuple1()
            .with_context(|| "untupling output".into())?
            .to_vec::<f32>()
            .with_context(|| "reading output".into())?)
    }

    /// Time an artifact: median wall clock per execution over `iters`
    /// timed runs after `warmup` untimed ones.
    pub fn time_artifact(
        &mut self,
        artifact: &str,
        x: (&[f32], &[i64]),
        y: (&[f32], &[i64]),
        warmup: usize,
        iters: usize,
    ) -> Result<f64> {
        let exe = self.load(artifact)?;
        let lx = xla::Literal::vec1(x.0)
            .reshape(x.1)
            .with_context(|| "reshaping x".into())?;
        let ly = xla::Literal::vec1(y.0)
            .reshape(y.1)
            .with_context(|| "reshaping y".into())?;
        for _ in 0..warmup {
            let _ = exe
                .execute::<xla::Literal>(&[lx.clone(), ly.clone()])
                .with_context(|| format!("warmup of {artifact}"))?;
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let out = exe
                .execute::<xla::Literal>(&[lx.clone(), ly.clone()])
                .with_context(|| format!("timing {artifact}"))?;
            // Force completion.
            let _ = out[0][0]
                .to_literal_sync()
                .with_context(|| "syncing timed output".into())?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        self.measurements += 1;
        Ok(samples[samples.len() / 2])
    }

    /// Correctness gate: run the GMM variant and compare with a host-side
    /// f32 matmul; returns the max absolute error.
    pub fn verify_gmm(
        &mut self,
        v: super::TileVariant,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<f64> {
        let x: Vec<f32> = (0..m * k).map(|i| ((i % 13) as f32 - 6.0) * 0.25).collect();
        let y: Vec<f32> = (0..k * n).map(|i| ((i % 7) as f32 - 3.0) * 0.5).collect();
        let got = self.run_f32(
            &v.artifact_name(),
            (&x, &[m as i64, k as i64]),
            (&y, &[k as i64, n as i64]),
        )?;
        let mut max_err = 0.0f64;
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += x[i * k + kk] * y[kk * n + j];
                }
                let e = (acc - got[i * n + j]).abs() as f64;
                max_err = max_err.max(e);
            }
        }
        Ok(max_err)
    }
}
