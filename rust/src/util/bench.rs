//! Tiny benchmark harness used by `benches/*.rs` (all declared with
//! `harness = false`; the image has no `criterion`).
//!
//! Provides warmup + repeated timed runs, reports min/median/mean, and a
//! table printer that the figure/table reproduction benches use to emit
//! the same rows the paper reports.

use std::time::Instant;

/// Result of benching one closure.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

fn fmt_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, self-calibrating the iteration count so the measured region
/// lasts at least `min_total_ms` per sample. Returns per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, samples: usize, min_total_ms: f64, mut f: F) -> BenchStats {
    // Warmup + calibration.
    let mut iters = 1usize;
    loop {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        let elapsed_ms = t.elapsed().as_secs_f64() * 1e3;
        if elapsed_ms >= min_total_ms || iters >= 1 << 24 {
            break;
        }
        let scale = (min_total_ms / elapsed_ms.max(1e-6)).ceil().max(2.0);
        iters = (iters as f64 * scale.min(16.0)) as usize;
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: per_iter[per_iter.len() / 2],
        min_ns: per_iter[0],
    };
    println!(
        "bench {:<44} mean {:>12}  median {:>12}  min {:>12}  ({} iters/sample)",
        stats.name,
        fmt_time(stats.mean_ns),
        fmt_time(stats.median_ns),
        fmt_time(stats.min_ns),
        stats.iters
    );
    stats
}

/// Render an aligned table (used to print paper-figure rows).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line: String = headers
        .iter()
        .enumerate()
        .map(|(i, h)| format!("{:<w$}", h, w = widths[i] + 2))
        .collect();
    println!("{line}");
    println!("{}", "-".repeat(line.len()));
    for row in rows {
        let line: String = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect();
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 3, 1.0, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns * 1.5);
        assert!(s.iters >= 1);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(500.0).ends_with("ns"));
        assert!(fmt_time(5_000.0).ends_with("us"));
        assert!(fmt_time(5_000_000.0).ends_with("ms"));
        assert!(fmt_time(5e9).ends_with("s"));
    }
}
