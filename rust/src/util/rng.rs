//! Deterministic pseudo-random number generation.
//!
//! The image vendors no `rand` crate, so we implement a small, fast,
//! well-understood generator: SplitMix64 for seeding and xoshiro256++ for
//! the stream. Determinism matters here — the paper's traces record
//! sampling *decisions*, and reproducible search runs are part of the
//! experiment harness contract.

/// A seedable, splittable PRNG (xoshiro256++ seeded via SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child generator (for per-task / per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }

    /// Derive the generator for a named stream of a root seed, without
    /// consuming any state: `stream` indexes an independent child (chain
    /// index, round number, selection stream, ...). The same `(seed,
    /// stream)` always yields the same generator, so parallel chains can
    /// be seeded deterministically regardless of how many OS threads
    /// execute them. Mixing goes through SplitMix64 twice with the stream
    /// folded in between, which decorrelates even adjacent stream ids.
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let mut sm2 = a ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng::seed_from_u64(splitmix64(&mut sm2))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless method.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)` over i64. `hi > lo`.
    #[inline]
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as usize) as i64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Sample an index according to (unnormalized, non-negative) weights.
    ///
    /// Degenerate vectors — a NaN/infinite/negative weight, or a total
    /// that is not finite and positive — fall back to a uniform draw
    /// instead of panicking or silently biasing toward the last index
    /// (`SampleCategorical` probabilities come straight from database
    /// traces, so hostile values do reach this path). Every fallback is
    /// counted in the process-global `rng_weighted_fallback_total`
    /// telemetry counter. Valid vectors draw exactly one `gen_f64`, the
    /// same sequence as always; the degenerate path draws exactly one
    /// `gen_range`, the same as the old all-zero fallback — so the fix
    /// is RNG-for-RNG compatible in both arms.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let mut total = 0.0;
        let mut degenerate = false;
        for &w in weights {
            if !w.is_finite() || w < 0.0 {
                degenerate = true;
                break;
            }
            total += w;
        }
        if degenerate || !total.is_finite() || total <= 0.0 {
            weighted_fallback_counter().inc();
            crate::log_debug!(
                "sample_weighted: degenerate weight vector (len {}), falling back to uniform",
                weights.len()
            );
            return self.gen_range(weights.len().max(1));
        }
        let mut u = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a uniform random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Process-global count of degenerate weight vectors that fell back to a
/// uniform draw. The handle is cached (`OnceLock`) so the hot sampling
/// path never touches the registry mutex; the counter itself is a relaxed
/// atomic, so counting cannot perturb determinism.
fn weighted_fallback_counter() -> &'static std::sync::Arc<crate::telemetry::Counter> {
    static COUNTER: std::sync::OnceLock<std::sync::Arc<crate::telemetry::Counter>> =
        std::sync::OnceLock::new();
    COUNTER.get_or_init(|| {
        crate::telemetry::global().counter(
            "rng_weighted_fallback_total",
            "degenerate weight vectors (non-finite or non-positive) sampled uniformly instead",
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn streams_are_deterministic_and_independent() {
        let mut a = Rng::for_stream(42, 0);
        let mut b = Rng::for_stream(42, 0);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::for_stream(42, 1);
        let same = (0..64).filter(|_| b.next_u64() == c.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.gen_range(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..1_000 {
            let i = r.sample_weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn weighted_roughly_proportional() {
        let mut r = Rng::seed_from_u64(11);
        let mut counts = [0usize; 2];
        for _ in 0..20_000 {
            counts[r.sample_weighted(&[1.0, 3.0])] += 1;
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn degenerate_weights_fall_back_to_uniform() {
        // NaN, infinite, negative, and all-zero weight vectors must
        // return a valid uniform index (never panic, never silently
        // favor the last index) and bump the fallback counter.
        let before = crate::telemetry::global()
            .counter_value("rng_weighted_fallback_total")
            .unwrap_or(0);
        let mut r = Rng::seed_from_u64(17);
        let vectors: [&[f64]; 5] = [
            &[f64::NAN, 1.0, 1.0],
            &[f64::INFINITY, 1.0],
            &[-1.0, 0.5, 0.5],
            &[0.0, 0.0, 0.0],
            &[1.0, f64::NEG_INFINITY],
        ];
        let mut seen = [false; 3];
        for _ in 0..200 {
            for v in vectors {
                let i = r.sample_weighted(v);
                assert!(i < v.len(), "index {i} out of range for {v:?}");
                if v.len() == 3 {
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "uniform fallback never hit some index: {seen:?}");
        let after = crate::telemetry::global()
            .counter_value("rng_weighted_fallback_total")
            .unwrap_or(0);
        assert!(after >= before + 1000, "fallbacks not counted: {before} -> {after}");
    }

    #[test]
    fn valid_weights_draw_the_same_sequence_as_before() {
        // The degenerate-input fix must not change the draw sequence for
        // valid vectors: one gen_f64 per call, bit-identical results.
        let mut a = Rng::seed_from_u64(23);
        let mut b = Rng::seed_from_u64(23);
        for _ in 0..500 {
            let i = a.sample_weighted(&[0.2, 0.3, 0.5]);
            let mut u = b.gen_f64() * 1.0;
            let mut expect = 2;
            for (j, w) in [0.2, 0.3, 0.5].iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    expect = j;
                    break;
                }
            }
            assert_eq!(i, expect);
        }
        assert_eq!(a.next_u64(), b.next_u64(), "RNG state diverged");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
