//! Minimal JSON value + writer (no serde in the offline image).
//!
//! Used by the experiment harness to persist measurement databases and
//! machine-readable reports next to the human-readable tables.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_nested_structures() {
        let j = Json::obj(vec![
            ("name", Json::str("gmm")),
            ("latency_us", Json::num(12.5)),
            ("tags", Json::arr(vec![Json::str("cpu"), Json::Bool(true)])),
        ]);
        assert_eq!(
            j.to_string(),
            r#"{"latency_us":12.5,"name":"gmm","tags":["cpu",true]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::str("a\"b\n").to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn integral_floats_print_as_ints() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }
}
