//! Minimal JSON value + writer + parser (no serde in the offline image).
//!
//! Used by the experiment harness to persist measurement databases and
//! machine-readable reports next to the human-readable tables, and by the
//! tuning-record database ([`crate::db`]) whose JSONL files must parse
//! back on warm-started runs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Parse a JSON document (the exact grammar this module writes, plus
    /// standard whitespace). Rejects trailing garbage, and nesting
    /// deeper than [`MAX_DEPTH`] — the parser recurses, and a corrupt or
    /// hostile input line must produce a clean error, not a stack
    /// overflow.
    pub fn parse(text: &str) -> Result<Json, String> {
        let chars: Vec<char> = text.chars().collect();
        let mut p = Parser { chars, pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(format!("trailing characters at offset {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Nesting ceiling for [`Json::parse`] — far above anything the record
/// store writes (≤3 levels), far below stack-overflow territory.
const MAX_DEPTH: usize = 128;

/// Recursive-descent JSON parser over a char buffer.
struct Parser {
    chars: Vec<char>,
    pos: usize,
    depth: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<char, String> {
        let c = self.peek().ok_or("unexpected end of input")?;
        self.pos += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), String> {
        let got = self.bump()?;
        if got != c {
            return Err(format!("expected '{c}', got '{got}' at offset {}", self.pos - 1));
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        for c in word.chars() {
            self.expect(c)?;
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        let v = match self.peek().ok_or("unexpected end of input")? {
            '{' => self.object(),
            '[' => self.array(),
            '"' => Ok(Json::Str(self.string()?)),
            't' => self.literal("true", Json::Bool(true)),
            'f' => self.literal("false", Json::Bool(false)),
            'n' => self.literal("null", Json::Null),
            '-' | '0'..='9' => self.number(),
            c => Err(format!("unexpected '{c}' at offset {}", self.pos)),
        };
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect('{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                ',' => continue,
                '}' => return Ok(Json::Obj(map)),
                c => return Err(format!("expected ',' or '}}', got '{c}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                ',' => continue,
                ']' => return Ok(Json::Arr(items)),
                c => return Err(format!("expected ',' or ']', got '{c}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                '"' => return Ok(out),
                '\\' => match self.bump()? {
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    '/' => out.push('/'),
                    'b' => out.push('\u{0008}'),
                    'f' => out.push('\u{000c}'),
                    'n' => out.push('\n'),
                    'r' => out.push('\r'),
                    't' => out.push('\t'),
                    'u' => out.push(self.unicode_escape()?),
                    c => return Err(format!("bad escape '\\{c}'")),
                },
                c => out.push(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = c.to_digit(16).ok_or_else(|| format!("bad hex digit '{c}'"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        // Surrogate pair: a following \uXXXX low surrogate combines.
        if (0xd800..0xdc00).contains(&hi) {
            self.expect('\\')?;
            self.expect('u')?;
            let lo = self.hex4()?;
            if !(0xdc00..0xe000).contains(&lo) {
                return Err(format!("unpaired surrogate {hi:04x}/{lo:04x}"));
            }
            let code = 0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00);
            return char::from_u32(code).ok_or_else(|| format!("bad codepoint {code:x}"));
        }
        char::from_u32(hi).ok_or_else(|| format!("bad codepoint {hi:x}"))
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(self.peek(), Some('-' | '+' | '.' | 'e' | 'E' | '0'..='9')) {
            self.pos += 1;
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{s}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_nested_structures() {
        let j = Json::obj(vec![
            ("name", Json::str("gmm")),
            ("latency_us", Json::num(12.5)),
            ("tags", Json::arr(vec![Json::str("cpu"), Json::Bool(true)])),
        ]);
        assert_eq!(
            j.to_string(),
            r#"{"latency_us":12.5,"name":"gmm","tags":["cpu",true]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::str("a\"b\n").to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn integral_floats_print_as_ints() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj(vec![
            ("name", Json::str("g m\nm\t\"q\"\\x")),
            ("lat", Json::num(1.25e-5)),
            ("n", Json::num(42.0)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            (
                "arr",
                Json::arr(vec![Json::num(-3.5), Json::str(""), Json::Bool(false)]),
            ),
        ]);
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn parse_handles_whitespace_and_nesting() {
        let j = Json::parse(" { \"a\" : [ 1 , { \"b\" : null } ] } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[1].get("b"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn parse_control_char_escapes() {
        // The writer emits \u00XX for control chars; the parser must read
        // them back, including an astral-plane surrogate pair.
        let j = Json::Str("a\u{0001}b".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{1f600}".into())
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parse_deep_nesting_errors_instead_of_overflowing() {
        let hostile = "[".repeat(100_000);
        let err = Json::parse(&hostile).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        // At the ceiling itself, a legal deep document still parses.
        let deep = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep).is_ok());
    }

    #[test]
    fn accessors_return_none_on_type_mismatch() {
        let j = Json::parse("{\"a\":1}").unwrap();
        assert!(j.get("missing").is_none());
        assert!(j.get("a").unwrap().as_str().is_none());
        assert!(j.as_f64().is_none());
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
    }
}
