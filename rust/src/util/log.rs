//! Leveled stderr logging for library code.
//!
//! The CLI's *results* go to stdout (CI smoke jobs grep them); library
//! *diagnostics* go through these macros to stderr, gated by a global
//! level. The level comes from, in priority order: an explicit
//! [`set_level`] call (the CLI's `--verbosity` flag), else the
//! `RUST_PALLAS_LOG` environment variable (`error|warn|info|debug`),
//! else [`Level::Warn`] — so pre-existing warnings keep appearing and
//! everything chattier is opt-in.
//!
//! The enabled-check is one relaxed atomic load; a suppressed
//! `log_debug!` never formats its arguments.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Parse a level name (case-insensitive; also accepts `0..=3`).
pub fn parse_level(s: &str) -> Option<Level> {
    match s.trim().to_ascii_lowercase().as_str() {
        "error" | "0" => Some(Level::Error),
        "warn" | "warning" | "1" => Some(Level::Warn),
        "info" | "2" => Some(Level::Info),
        "debug" | "3" => Some(Level::Debug),
        _ => None,
    }
}

/// Sentinel meaning "not initialized yet — consult the environment".
const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// Set the global level explicitly (the `--verbosity` flag). Wins over
/// the environment.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global level, initializing from `RUST_PALLAS_LOG` on first
/// use.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != UNSET {
        return match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        };
    }
    let from_env = std::env::var("RUST_PALLAS_LOG")
        .ok()
        .as_deref()
        .and_then(parse_level)
        .unwrap_or(Level::Warn);
    // Racing first-uses agree (same env), so a plain store is fine.
    LEVEL.store(from_env as u8, Ordering::Relaxed);
    from_env
}

/// Whether a message at `at` would currently be emitted.
#[inline]
pub fn enabled(at: Level) -> bool {
    at <= level()
}

/// Emit a message at `at` to stderr with a level prefix. Prefer the
/// `log_*!` macros, which skip argument formatting when suppressed.
pub fn log(at: Level, args: std::fmt::Arguments<'_>) {
    if enabled(at) {
        eprintln!("{}: {args}", at.as_str());
    }
}

/// Log at error level (always on unless the impossible happens).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*))
    };
}

/// Log at warn level (the default threshold).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at info level (`--verbosity info` / `RUST_PALLAS_LOG=info`).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*))
        }
    };
}

/// Log at debug level (`--verbosity debug` / `RUST_PALLAS_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_digits() {
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("WARNING"), Some(Level::Warn));
        assert_eq!(parse_level(" debug "), Some(Level::Debug));
        assert_eq!(parse_level("0"), Some(Level::Error));
        assert_eq!(parse_level("3"), Some(Level::Debug));
        assert_eq!(parse_level("verbose"), None);
    }

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_gates_enabled() {
        // Tests share the global; set explicitly rather than relying on
        // the env default, and leave the default (Warn) behind.
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Error);
        assert!(!enabled(Level::Warn));
        set_level(Level::Warn);
        assert!(enabled(Level::Warn));
    }
}
