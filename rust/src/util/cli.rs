//! Minimal command-line parsing (no `clap` in the offline image).
//!
//! Grammar: `metaschedule <command> [subcommand] [--flag value]... [--switch]...`

use std::collections::HashMap;

/// Parsed command line: positional arguments + `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> u64 {
        self.flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = parse("exp fig8 --target cpu --trials 256 --verbose");
        assert_eq!(a.positional, vec!["exp", "fig8"]);
        assert_eq!(a.flag("target"), Some("cpu"));
        assert_eq!(a.flag_usize("trials", 0), 256);
        assert!(a.has_switch("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("tune --seed=99");
        assert_eq!(a.flag_u64("seed", 0), 99);
    }

    #[test]
    fn missing_flag_uses_default() {
        let a = parse("tune");
        assert_eq!(a.flag_or("target", "cpu"), "cpu");
        assert_eq!(a.flag_usize("trials", 64), 64);
    }
}
