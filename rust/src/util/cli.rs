//! Minimal command-line parsing (no `clap` in the offline image).
//!
//! Grammar: `metaschedule <command> [subcommand] [--flag value]...
//! [-f value]... [--switch]...` — short flags are single-dash +
//! alphabetic (`-k 5`); anything else after one dash (e.g. a negative
//! number) stays a positional/value.

use std::collections::HashMap;

/// Parsed command line: positional arguments + `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

/// Whether `arg` introduces a flag (`--name` or alphabetic `-n`) rather
/// than being a positional or a flag value.
fn is_flag(arg: &str) -> bool {
    if arg.starts_with("--") {
        return true;
    }
    match arg.strip_prefix('-') {
        Some(rest) => {
            let name = rest.split_once('=').map(|(k, _)| k).unwrap_or(rest);
            !name.is_empty() && name.chars().all(|c| c.is_ascii_alphabetic())
        }
        None => false,
    }
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if !is_flag(&arg) {
                out.positional.push(arg);
                continue;
            }
            let name = arg.trim_start_matches('-');
            if let Some((k, v)) = name.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if iter.peek().map(|n| !is_flag(n)).unwrap_or(false) {
                let v = iter.next().unwrap();
                out.flags.insert(name.to_string(), v);
            } else {
                out.switches.push(name.to_string());
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> u64 {
        self.flag(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list flag (`--workloads GMM,SFM`). Missing flag or
    /// empty items collapse away, so `--workloads GMM,` is just `[GMM]`.
    pub fn flag_csv(&self, name: &str) -> Vec<String> {
        self.flag(name)
            .map(|s| s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(String::from).collect())
            .unwrap_or_default()
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = parse("exp fig8 --target cpu --trials 256 --verbose");
        assert_eq!(a.positional, vec!["exp", "fig8"]);
        assert_eq!(a.flag("target"), Some("cpu"));
        assert_eq!(a.flag_usize("trials", 0), 256);
        assert!(a.has_switch("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("tune --seed=99");
        assert_eq!(a.flag_u64("seed", 0), 99);
    }

    #[test]
    fn missing_flag_uses_default() {
        let a = parse("tune");
        assert_eq!(a.flag_or("target", "cpu"), "cpu");
        assert_eq!(a.flag_usize("trials", 64), 64);
    }

    #[test]
    fn parses_short_flags() {
        let a = parse("db top --workload GMM -k 5 --db /tmp/t.jsonl");
        assert_eq!(a.positional, vec!["db", "top"]);
        assert_eq!(a.flag("workload"), Some("GMM"));
        assert_eq!(a.flag_usize("k", 0), 5);
        assert_eq!(a.flag("db"), Some("/tmp/t.jsonl"));
    }

    #[test]
    fn parses_csv_flags() {
        let a = parse("serve --workloads GMM,SFM, --db t.jsonl");
        assert_eq!(a.flag_csv("workloads"), vec!["GMM".to_string(), "SFM".to_string()]);
        assert!(a.flag_csv("missing").is_empty());
    }

    #[test]
    fn negative_numbers_stay_values() {
        let a = parse("cmd --offset -5 -v");
        assert_eq!(a.flag("offset"), Some("-5"));
        assert!(a.has_switch("v"));
        let b = parse("cmd -k=3 -7");
        assert_eq!(b.flag("k"), Some("3"));
        assert_eq!(b.positional, vec!["cmd", "-7"]);
    }
}
