//! In-tree utility crates-in-miniature (the offline image vendors only the
//! `xla` dependency tree — see DESIGN.md §Dependency-Substitutions).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
