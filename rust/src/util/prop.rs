//! Minimal property-based testing helpers.
//!
//! The offline image has no `proptest`, so this module provides the same
//! workflow in miniature: generate many random cases from a seedable RNG,
//! run a property, and on failure report the *seed and case index* so the
//! exact failing case replays deterministically. A simple integer/vec
//! shrinker narrows failing cases before reporting.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0x5EED }
    }
}

/// Property outcome: `bool` or `Result<(), String>` both work.
pub trait IntoPropResult {
    fn into_prop(self) -> Result<(), String>;
}

impl IntoPropResult for bool {
    fn into_prop(self) -> Result<(), String> {
        if self {
            Ok(())
        } else {
            Err("property returned false".into())
        }
    }
}

impl IntoPropResult for Result<(), String> {
    fn into_prop(self) -> Result<(), String> {
        self
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the seed and a
/// debug dump of the failing input on the first failure.
pub fn check<T, G, P, R>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> R,
    R: IntoPropResult,
{
    for i in 0..cfg.cases {
        let mut rng = Rng::seed_from_u64(cfg.seed.wrapping_add(i as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input).into_prop() {
            panic!(
                "property failed (seed={}, case={}): {}\ninput: {:?}",
                cfg.seed, i, msg, input
            );
        }
    }
}

/// Shrink a failing integer towards zero while the property still fails.
pub fn shrink_i64<P: FnMut(i64) -> bool>(mut failing: i64, mut still_fails: P) -> i64 {
    loop {
        let candidate = failing / 2;
        if candidate != failing && still_fails(candidate) {
            failing = candidate;
        } else {
            return failing;
        }
    }
}

/// Shrink a failing vector by repeatedly removing elements while the
/// property still fails. Returns a (locally) minimal failing vector.
pub fn shrink_vec<T: Clone, P: FnMut(&[T]) -> bool>(xs: &[T], mut still_fails: P) -> Vec<T> {
    let mut cur: Vec<T> = xs.to_vec();
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..cur.len() {
            let mut cand = cur.clone();
            cand.remove(i);
            if still_fails(&cand) {
                cur = cand;
                changed = true;
                break;
            }
        }
    }
    cur
}

/// Draw a random vector of length `[min_len, max_len]` with elements from `gen`.
pub fn vec_of<T>(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let len = min_len + rng.gen_range(max_len - min_len + 1);
    (0..len).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(
            PropConfig::default(),
            |r| r.gen_range(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failure() {
        check(
            PropConfig { cases: 64, seed: 1 },
            |r| r.gen_range(10),
            |&x| if x != 7 { Ok(()) } else { Err("hit 7".into()) },
        );
    }

    #[test]
    fn shrinker_minimizes() {
        // Property "x >= 10 fails" should shrink towards a small failing value.
        let shrunk = shrink_i64(1000, |x| x >= 10);
        assert!(shrunk < 20, "shrunk={shrunk}");
        assert!(shrunk >= 10);
    }

    #[test]
    fn vec_shrinker_minimizes() {
        // Failure = vector contains a 3. Minimal failing vec is [3].
        let shrunk = shrink_vec(&[1, 3, 5, 3, 2], |v| v.contains(&3));
        assert_eq!(shrunk, vec![3]);
    }
}
