//! Minimal error type (no `anyhow` in the offline image): a boxed message
//! with optional context frames, used by the runtime layer and anything
//! else that needs fallible I/O-ish APIs.

use std::fmt;

/// A string-message error with context frames, innermost last.
#[derive(Debug, Clone)]
pub struct Error {
    frames: Vec<String>,
}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { frames: vec![m.into()] }
    }

    /// Wrap with an outer context frame.
    pub fn context(mut self, c: impl Into<String>) -> Error {
        self.frames.push(c.into());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, frame) in self.frames.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{frame}")?;
        }
        Ok(())
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// Add context to any displayable error carried by a `Result`.
pub trait Context<T> {
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_orders_context_outermost_first() {
        let e = Error::msg("root cause").context("loading file");
        assert_eq!(e.to_string(), "loading file: root cause");
    }

    #[test]
    fn context_trait_wraps_io_errors() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.with_context(|| "opening artifact".into()).unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("opening artifact"), "{s}");
        assert!(s.contains("missing"), "{s}");
    }
}
