//! Blockize and tensorize: wrap a loop subtree into an opaque block and map
//! it onto a hardware tensor intrinsic.
//!
//! Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
//! `Use-Tensor-Core` targets CUDA WMMA 16x16x16 fragments; we register that
//! intrinsic for the GPU-flavoured target and an MXU-flavoured 128x128x128
//! systolic intrinsic for the TPU notes. Tensorize validates that the
//! blockized subtree is a matmul-shaped reduction with matching extents.

use std::collections::HashMap;

use crate::schedule::{BlockRv, LoopRef, LoopRv, SchResult, Schedule, ScheduleError};
use crate::tir::analysis::is_ancestor;
use crate::tir::{AExpr, BlockBody, BlockData, BinOp, Region, VarId};
use crate::trace::Inst;

/// A registered tensor intrinsic.
#[derive(Debug, Clone)]
pub struct TensorIntrin {
    pub name: &'static str,
    /// (m, n, k) dims of the matmul fragment.
    pub dims: (i64, i64, i64),
    /// Throughput multiplier the simulator credits relative to scalar FMA.
    pub speedup: f64,
}

/// Intrinsic registry. `wmma_16x16x16`: CUDA TensorCore fragment;
/// `mxu_128x128`: TPU MXU systolic tile (see DESIGN.md).
pub fn intrin_registry() -> Vec<TensorIntrin> {
    vec![
        TensorIntrin {
            name: "wmma_16x16x16",
            dims: (16, 16, 16),
            speedup: 8.0,
        },
        TensorIntrin {
            name: "mxu_128x128",
            dims: (128, 128, 128),
            speedup: 16.0,
        },
        TensorIntrin {
            name: "dot_4x4",
            dims: (4, 4, 4),
            speedup: 2.0,
        },
    ]
}

/// Look up an intrinsic by name.
pub fn find_intrin(name: &str) -> Option<TensorIntrin> {
    intrin_registry().into_iter().find(|i| i.name == name)
}

impl Schedule {
    /// Convert the subtree rooted at `loop_rv` into a single opaque block
    /// carrying aggregate statistics (flops, region footprints).
    pub fn blockize(&mut self, loop_rv: LoopRv) -> SchResult<BlockRv> {
        let loop_item = self.loop_item(loop_rv)?;
        let blk = self.blockize_impl(loop_item)?;
        let rv = self.push_block(blk);
        self.record(Inst::Blockize {
            loop_rv: loop_rv.0,
            out: rv.0,
        });
        Ok(rv)
    }

    pub(crate) fn blockize_impl(&mut self, loop_item: usize) -> SchResult<usize> {
        let inner_blocks = self.prog.blocks_under(loop_item);
        if inner_blocks.is_empty() {
            return Err(ScheduleError::Unsupported("blockize of empty subtree".into()));
        }
        // Loops inside the subtree (including the root loop).
        let inner_loops: Vec<usize> = self
            .prog
            .preorder()
            .into_iter()
            .filter(|&l| self.prog.is_loop(l) && is_ancestor(&self.prog, loop_item, l))
            .collect();
        let sweep = crate::tir::analysis::sweep_env(&self.prog, &inner_loops);
        let mut pin_zero: HashMap<VarId, AExpr> = HashMap::new();
        for &l in &inner_loops {
            pin_zero.insert(self.prog.loop_data(l).var, AExpr::Const(0));
        }
        // Aggregate flops + regions at the blockized boundary.
        let mut flops = 0.0;
        let mut reads: Vec<Region> = Vec::new();
        let mut writes: Vec<Region> = Vec::new();
        let mut has_reduce = false;
        for &b in &inner_blocks {
            let bd = self.prog.block_data(b);
            has_reduce |= bd.is_reduction();
            // Trip count of loops between (inclusive) loop_item and block.
            let trips: i64 = self
                .prog
                .loops_above(b)
                .into_iter()
                .filter(|&l| is_ancestor(&self.prog, loop_item, l))
                .map(|l| self.prog.loop_data(l).extent)
                .product();
            flops += trips as f64 * bd.body.flops();
            let mut iter_ranges: HashMap<VarId, (i64, i64)> = HashMap::new();
            let mut iter_binding: HashMap<VarId, AExpr> = HashMap::new();
            for iv in &bd.iters {
                iter_ranges.insert(iv.var, iv.binding.interval(&sweep));
                iter_binding.insert(iv.var, iv.binding.clone());
            }
            for (src, dst) in [(&bd.reads, &mut reads), (&bd.writes, &mut writes)] {
                for r in src {
                    let ranges: Vec<(AExpr, i64)> = r
                        .ranges
                        .iter()
                        .map(|(start, extent)| {
                            let width = start.width(&iter_ranges) + extent - 1;
                            let offset = start.subst(&iter_binding).subst(&pin_zero);
                            (offset, width)
                        })
                        .collect();
                    // Merge with an existing region on the same buffer.
                    if let Some(existing) = dst.iter_mut().find(|e| e.buffer == r.buffer) {
                        for (d, (_, w)) in ranges.iter().enumerate() {
                            if d < existing.ranges.len() {
                                existing.ranges[d].1 = existing.ranges[d].1.max(*w);
                            }
                        }
                    } else {
                        dst.push(Region {
                            buffer: r.buffer,
                            ranges,
                        });
                    }
                }
            }
        }
        // Intermediate buffers written and read entirely inside the subtree
        // stay listed; that is fine for cost purposes.
        let mut blk = BlockData::new(format!(
            "{}_o",
            self.prog.block_data(inner_blocks[0]).name
        ));
        blk.reads = reads;
        blk.writes = writes;
        blk.body = BlockBody::Opaque {
            flops_per_instance: flops,
        };
        if has_reduce {
            blk.annotations
                .insert("blockized_reduction".into(), "1".into());
        }
        // Record the inner extents for tensorize validation.
        let extents: Vec<String> = inner_loops
            .iter()
            .map(|&l| self.prog.loop_data(l).extent.to_string())
            .collect();
        blk.annotations
            .insert("blockized_extents".into(), extents.join("x"));
        let blk_item = self.prog.alloc_block(blk);
        // Replace the subtree with the opaque block.
        let parent = self.prog.items[loop_item].parent;
        let pos = match parent {
            Some(p) => self.prog.items[p]
                .children
                .iter()
                .position(|&c| c == loop_item)
                .unwrap(),
            None => self
                .prog
                .roots
                .iter()
                .position(|&c| c == loop_item)
                .unwrap(),
        };
        self.prog.remove_subtree(loop_item);
        self.prog.attach_at(blk_item, parent, pos);
        Ok(blk_item)
    }

    /// Tensorize: blockize the subtree at `loop_rv` and mark it as executed
    /// by the named tensor intrinsic. Validates the fragment shape.
    pub fn tensorize(&mut self, loop_rv: LoopRv, intrin_name: &str) -> SchResult<BlockRv> {
        let intrin = find_intrin(intrin_name).ok_or_else(|| {
            ScheduleError::TensorizeMismatch(format!("unknown intrinsic {intrin_name}"))
        })?;
        let loop_item = match self.loop_ref(loop_rv) {
            LoopRef::Item(i) => i,
            _ => return Err(ScheduleError::NotALoop("tensorize sentinel".into())),
        };
        if !self.prog.items[loop_item].alive {
            return Err(ScheduleError::StaleHandle("tensorize loop".into()));
        }
        // Validate: the subtree must contain exactly one reduction block
        // whose inner loops match the intrinsic dims (m, n, k) in order.
        let inner_blocks = self.prog.blocks_under(loop_item);
        if inner_blocks.len() != 1 {
            return Err(ScheduleError::TensorizeMismatch(format!(
                "expected one block under the tensorized loop, found {}",
                inner_blocks.len()
            )));
        }
        let bd = self.prog.block_data(inner_blocks[0]);
        let is_matmul = matches!(&bd.body, BlockBody::Reduce { op: BinOp::Add, rhs, .. }
            if matches!(rhs, crate::tir::CExpr::Bin(BinOp::Mul, _, _)));
        if !is_matmul {
            return Err(ScheduleError::TensorizeMismatch(
                "tensorize target is not a multiply-accumulate reduction".into(),
            ));
        }
        let inner_loops: Vec<usize> = self
            .prog
            .preorder()
            .into_iter()
            .filter(|&l| self.prog.is_loop(l) && is_ancestor(&self.prog, loop_item, l))
            .collect();
        let extents: Vec<i64> = inner_loops
            .iter()
            .map(|&l| self.prog.loop_data(l).extent)
            .collect();
        let (m, n, k) = intrin.dims;
        if extents != vec![m, n, k] {
            return Err(ScheduleError::TensorizeMismatch(format!(
                "loop extents {extents:?} do not match intrinsic {:?}",
                intrin.dims
            )));
        }
        let blk = self.blockize_impl(loop_item)?;
        self.prog
            .block_data_mut(blk)
            .annotate("tensor_intrin", intrin_name);
        let rv = self.push_block(blk);
        self.record(Inst::Tensorize {
            loop_rv: loop_rv.0,
            intrin: intrin_name.to_string(),
            out: rv.0,
        });
        Ok(rv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::matmul_prog;
    use crate::schedule::Schedule;
    use crate::tir::analysis::program_flops;
    use crate::trace::FactorArg;

    #[test]
    fn blockize_preserves_total_flops() {
        let mut s = Schedule::new(matmul_prog(64, 32), 0);
        let before = program_flops(&s.prog);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        // Split i and blockize at the inner i loop.
        let parts = s
            .split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(16)])
            .unwrap();
        let ob = s.blockize(parts[1]).unwrap();
        s.prog.check_integrity().unwrap();
        assert_eq!(program_flops(&s.prog), before);
        let od = s.prog.block_data(s.block(ob).unwrap()).clone();
        assert!(matches!(od.body, BlockBody::Opaque { .. }));
        // Opaque block covers a 16-row slab of A and C, all of B.
        assert_eq!(od.reads.len(), 2);
        assert_eq!(od.writes.len(), 1);
        assert_eq!(od.writes[0].ranges[0].1, 16); // 16 rows of C
        assert_eq!(od.writes[0].ranges[1].1, 64); // all 64 cols
    }

    #[test]
    fn tensorize_matching_fragment() {
        // 64x64x32 matmul: tile to 16x16x16 fragments then tensorize.
        let mut s = Schedule::new(matmul_prog(64, 32), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let i = s
            .split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(16)])
            .unwrap();
        let j = s
            .split(loops[1], &[FactorArg::Lit(4), FactorArg::Lit(16)])
            .unwrap();
        let k = s
            .split(loops[2], &[FactorArg::Lit(2), FactorArg::Lit(16)])
            .unwrap();
        // reorder to i0 j0 k0 i1 j1 k1
        s.reorder(&[i[0], j[0], k[0], i[1], j[1], k[1]]).unwrap();
        let frag = s.tensorize(i[1], "wmma_16x16x16").unwrap();
        s.prog.check_integrity().unwrap();
        let fd = s.prog.block_data(s.block(frag).unwrap()).clone();
        assert_eq!(fd.annotations["tensor_intrin"], "wmma_16x16x16");
        // flops preserved through blockize.
        assert_eq!(program_flops(&s.prog), 64.0 * 64.0 * 32.0 * 2.0);
    }

    #[test]
    fn tensorize_wrong_shape_rejected() {
        let mut s = Schedule::new(matmul_prog(64, 32), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        // whole nest is 64x64x32, not a 16x16x16 fragment
        let e = s.tensorize(loops[0], "wmma_16x16x16");
        assert!(matches!(e, Err(ScheduleError::TensorizeMismatch(_))));
    }

    #[test]
    fn tensorize_unknown_intrin_rejected() {
        let mut s = Schedule::new(matmul_prog(64, 32), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        assert!(matches!(
            s.tensorize(loops[0], "nope"),
            Err(ScheduleError::TensorizeMismatch(_))
        ));
    }
}
