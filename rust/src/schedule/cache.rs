//! Caching primitives: cache-read, cache-write, set-scope, storage-align.
//!
//! `cache-read` stages a consumed buffer into a faster storage scope via a
//! fresh copy block (which is then usually moved inward with `compute-at`);
//! `cache-write` stages a produced buffer symmetrically.

use crate::schedule::{BlockRv, SchResult, Schedule, ScheduleError};
use crate::tir::{
    AExpr, BlockBody, BlockData, Buffer, CExpr, IterKind, IterVar, LoopData, Region, Scope,
};
use crate::trace::Inst;

impl Schedule {
    /// Create a block that stages `block`'s `read_idx`-th read buffer into
    /// `scope`, and redirect the consumer to the staged copy. The copy block
    /// initially covers the whole buffer at the program root, immediately
    /// before the consumer's nest; move it inward with `compute-at`.
    pub fn cache_read(&mut self, block: BlockRv, read_idx: usize, scope: &str) -> SchResult<BlockRv> {
        let item = self.block(block)?;
        let bd = self.prog.block_data(item).clone();
        let region = bd
            .reads
            .get(read_idx)
            .ok_or_else(|| {
                ScheduleError::InvalidDecision(format!(
                    "cache-read index {read_idx} out of {} reads",
                    bd.reads.len()
                ))
            })?
            .clone();
        let src = region.buffer;
        let src_buf = self.prog.buffers[src].clone();
        let cached = self.prog.add_buffer(Buffer {
            name: format!("{}_{}", src_buf.name, Scope::parse(scope).name().replace('.', "_")),
            shape: src_buf.shape.clone(),
            dtype: src_buf.dtype,
            scope: Scope::parse(scope),
            align: src_buf.align,
            inlined: false,
        });
        // Copy block: one spatial iter per dim over the full buffer.
        let copy = self.build_copy_block(
            &format!("{}_cache", src_buf.name),
            src,
            cached,
            &src_buf.shape,
        );
        // Insert the copy nest at root level before the consumer's root.
        let consumer_root = self.prog.root_of(item);
        let pos = self
            .prog
            .roots
            .iter()
            .position(|&r| r == consumer_root)
            .unwrap_or(0);
        self.attach_nest_at_root(copy, pos);
        // Redirect the consumer: reads + body loads of src -> cached.
        {
            let bd_mut = self.prog.block_data_mut(item);
            if let Some(r) = bd_mut.reads.get_mut(read_idx) {
                r.buffer = cached;
            }
            let redirect = |e: &CExpr| {
                e.map_loads(&mut |b, idx| {
                    if b == src {
                        CExpr::Load(cached, idx.to_vec())
                    } else {
                        CExpr::Load(b, idx.to_vec())
                    }
                })
            };
            bd_mut.body = match &bd_mut.body {
                BlockBody::Assign { expr } => BlockBody::Assign {
                    expr: redirect(expr),
                },
                BlockBody::Reduce { init, op, rhs } => BlockBody::Reduce {
                    init: redirect(init),
                    op: *op,
                    rhs: redirect(rhs),
                },
                BlockBody::Opaque { flops_per_instance } => BlockBody::Opaque {
                    flops_per_instance: *flops_per_instance,
                },
            };
            // Other reads of the same buffer also redirect (matches TVM,
            // which redirects the consumer block wholesale).
            for r in bd_mut.reads.iter_mut() {
                if r.buffer == src {
                    r.buffer = cached;
                }
            }
        }
        let rv = self.push_block(copy);
        self.record(Inst::CacheRead {
            block: block.0,
            read_idx,
            scope: scope.to_string(),
            out: rv.0,
        });
        Ok(rv)
    }

    /// Create a block that copies `block`'s `write_idx`-th written buffer
    /// from a staged `scope` copy back to its original storage; `block` now
    /// writes the staged copy.
    pub fn cache_write(&mut self, block: BlockRv, write_idx: usize, scope: &str) -> SchResult<BlockRv> {
        let item = self.block(block)?;
        let bd = self.prog.block_data(item).clone();
        let region = bd
            .writes
            .get(write_idx)
            .ok_or_else(|| {
                ScheduleError::InvalidDecision(format!(
                    "cache-write index {write_idx} out of {} writes",
                    bd.writes.len()
                ))
            })?
            .clone();
        let dst = region.buffer;
        let dst_buf = self.prog.buffers[dst].clone();
        let staged = self.prog.add_buffer(Buffer {
            name: format!("{}_{}", dst_buf.name, Scope::parse(scope).name().replace('.', "_")),
            shape: dst_buf.shape.clone(),
            dtype: dst_buf.dtype,
            scope: Scope::parse(scope),
            align: dst_buf.align,
            inlined: false,
        });
        // Producer now writes the staged buffer.
        {
            let bd_mut = self.prog.block_data_mut(item);
            for w in bd_mut.writes.iter_mut() {
                if w.buffer == dst {
                    w.buffer = staged;
                }
            }
        }
        // Copy block staged -> dst, after the producer's nest.
        let copy = self.build_copy_block(
            &format!("{}_writeback", dst_buf.name),
            staged,
            dst,
            &dst_buf.shape,
        );
        let producer_root = self.prog.root_of(item);
        let pos = self
            .prog
            .roots
            .iter()
            .position(|&r| r == producer_root)
            .map(|p| p + 1)
            .unwrap_or(self.prog.roots.len());
        self.attach_nest_at_root(copy, pos);
        let rv = self.push_block(copy);
        self.record(Inst::CacheWrite {
            block: block.0,
            write_idx,
            scope: scope.to_string(),
            out: rv.0,
        });
        Ok(rv)
    }

    /// Build `dst[i...] = src[i...]` over `shape`, returning the block item
    /// (loops not yet attached; see `attach_nest_at_root`).
    pub(crate) fn build_copy_block(&mut self, name: &str, src: usize, dst: usize, shape: &[i64]) -> usize {
        let mut iters = Vec::new();
        let mut loops = Vec::new();
        for (d, &extent) in shape.iter().enumerate() {
            let lv = self.prog.fresh_var(&format!("c{d}_"));
            let bv = self.prog.fresh_var(&format!("cc{d}_"));
            loops.push(self.prog.alloc_loop(LoopData::new(lv, extent)));
            iters.push(IterVar {
                var: bv,
                extent,
                kind: IterKind::Spatial,
                binding: AExpr::Var(lv),
            });
        }
        let idx: Vec<AExpr> = iters.iter().map(|iv| AExpr::Var(iv.var)).collect();
        let mut blk = BlockData::new(name);
        blk.reads = vec![Region::point(src, idx.clone())];
        blk.writes = vec![Region::point(dst, idx.clone())];
        blk.body = BlockBody::Assign {
            expr: CExpr::Load(src, idx),
        };
        blk.iters = iters;
        let blk = self.prog.alloc_block(blk);
        // Chain loops; remember them on the side via parent links.
        let mut parent: Option<usize> = None;
        for &l in &loops {
            if let Some(p) = parent {
                self.prog.items[l].parent = Some(p);
                self.prog.items[p].children.push(l);
            }
            parent = Some(l);
        }
        if let Some(p) = parent {
            self.prog.items[blk].parent = Some(p);
            self.prog.items[p].children.push(blk);
        }
        blk
    }

    /// Attach the (pre-linked) nest containing `block` at root position `pos`.
    pub(crate) fn attach_nest_at_root(&mut self, block: usize, pos: usize) {
        let mut top = block;
        while let Some(p) = self.prog.items[top].parent {
            top = p;
        }
        self.prog.roots.insert(pos.min(self.prog.roots.len()), top);
    }

    /// Set the storage scope of the buffer written by `block` at `write_idx`.
    pub fn set_scope(&mut self, block: BlockRv, write_idx: usize, scope: &str) -> SchResult<()> {
        let item = self.block(block)?;
        let buf = self
            .prog
            .block_data(item)
            .writes
            .get(write_idx)
            .map(|r| r.buffer)
            .ok_or_else(|| ScheduleError::InvalidDecision("set-scope write index".into()))?;
        if self.prog.params.contains(&buf) {
            return Err(ScheduleError::Unsupported(
                "cannot change scope of a parameter buffer".into(),
            ));
        }
        self.prog.buffers[buf].scope = Scope::parse(scope);
        self.record(Inst::SetScope {
            block: block.0,
            write_idx,
            scope: scope.to_string(),
        });
        Ok(())
    }

    /// Set an alignment requirement on a buffer dimension (bank-conflict
    /// avoidance on GPU shared memory; cacheline padding on CPU).
    pub fn storage_align(
        &mut self,
        block: BlockRv,
        write_idx: usize,
        axis: usize,
        factor: i64,
    ) -> SchResult<()> {
        let item = self.block(block)?;
        let buf = self
            .prog
            .block_data(item)
            .writes
            .get(write_idx)
            .map(|r| r.buffer)
            .ok_or_else(|| ScheduleError::InvalidDecision("storage-align write index".into()))?;
        if axis >= self.prog.buffers[buf].shape.len() {
            return Err(ScheduleError::InvalidDecision(format!(
                "storage-align axis {axis} out of rank"
            )));
        }
        self.prog.buffers[buf].align = factor * self.prog.buffers[buf].dtype.bytes();
        self.record(Inst::StorageAlign {
            block: block.0,
            write_idx,
            axis,
            factor,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::matmul_prog;
    use crate::schedule::Schedule;
    use crate::tir::analysis::program_flops;

    #[test]
    fn cache_read_inserts_copy_and_redirects() {
        let mut s = Schedule::new(matmul_prog(16, 8), 0);
        let b = s.get_block("matmul").unwrap();
        let c = s.cache_read(b, 0, "shared").unwrap();
        s.prog.check_integrity().unwrap();
        // A new buffer A_shared exists with shared scope.
        let cached = s
            .prog
            .buffers
            .iter()
            .find(|bf| bf.name == "A_shared")
            .unwrap();
        assert_eq!(cached.scope, Scope::Shared);
        // Copy block reads A and consumer now reads A_shared.
        let copy_item = s.block(c).unwrap();
        assert_eq!(s.prog.block_data(copy_item).name, "A_cache");
        let mm = s.prog.find_block("matmul").unwrap();
        let cached_id = s
            .prog
            .buffers
            .iter()
            .position(|bf| bf.name == "A_shared")
            .unwrap();
        assert_eq!(s.prog.block_data(mm).reads[0].buffer, cached_id);
        // Copy nest precedes the consumer nest at root.
        assert_eq!(s.prog.roots.len(), 2);
        assert_eq!(s.prog.root_of(copy_item), s.prog.roots[0]);
    }

    #[test]
    fn cache_read_then_compute_at_shrinks_copy() {
        let mut s = Schedule::new(matmul_prog(16, 8), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let c = s.cache_read(b, 0, "shared").unwrap();
        // Move the copy under matmul's i loop: per i it must stage A[i, 0:8].
        s.compute_at(c, loops[0]).unwrap();
        s.prog.check_integrity().unwrap();
        let copy_item = s.block(c).unwrap();
        let above = s.prog.loops_above(copy_item);
        let extents: Vec<i64> = above.iter().map(|&l| s.prog.loop_data(l).extent).collect();
        assert_eq!(extents, vec![16, 8]); // i loop, then the k-dim copy loop
    }

    #[test]
    fn cache_write_stages_output() {
        let mut s = Schedule::new(matmul_prog(16, 8), 0);
        let before = program_flops(&s.prog);
        let b = s.get_block("matmul").unwrap();
        let wb = s.cache_write(b, 0, "local").unwrap();
        s.prog.check_integrity().unwrap();
        let mm = s.prog.find_block("matmul").unwrap();
        let staged = s
            .prog
            .buffers
            .iter()
            .position(|bf| bf.name == "C_local")
            .unwrap();
        assert_eq!(s.prog.block_data(mm).writes[0].buffer, staged);
        // Writeback block writes C.
        let wb_item = s.block(wb).unwrap();
        assert_eq!(s.prog.block_data(wb_item).writes[0].buffer, 2);
        // Writeback nest follows the producer nest.
        assert_eq!(s.prog.roots.len(), 2);
        assert!(program_flops(&s.prog) >= before);
    }

    #[test]
    fn set_scope_on_param_rejected() {
        let mut s = Schedule::new(matmul_prog(16, 8), 0);
        let b = s.get_block("matmul").unwrap();
        assert!(s.set_scope(b, 0, "shared").is_err()); // C is a param
    }

    #[test]
    fn storage_align_sets_buffer_alignment() {
        let mut s = Schedule::new(matmul_prog(16, 8), 0);
        let b = s.get_block("matmul").unwrap();
        let c = s.cache_write(b, 0, "shared").unwrap();
        let _ = c;
        let mm = s.get_block("matmul").unwrap();
        s.storage_align(mm, 0, 1, 32).unwrap();
        let staged = s
            .prog
            .buffers
            .iter()
            .find(|bf| bf.name == "C_shared")
            .unwrap();
        assert_eq!(staged.align, 32 * 4);
    }
}
