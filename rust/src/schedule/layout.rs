//! Layout transformation: `transform-layout` repacks a read buffer into a
//! permuted layout through an explicit pack block, then rewrites the
//! consumer to read the packed copy.
//!
//! This is the primitive behind the `layout-rewrite` schedule rule: when a
//! matmul-class block reads a tensor whose innermost-varying dimension is
//! not last in memory (e.g. `dense`'s `W[j, k]` traversed with `j` as the
//! innermost spatial loop), repacking so the hot dimension is contiguous
//! turns strided loads into unit-stride ones.

use crate::schedule::{BlockRv, SchResult, Schedule, ScheduleError};
use crate::tir::{
    AExpr, BlockBody, BlockData, Buffer, CExpr, IterKind, IterVar, LoopData, Region,
};
use crate::trace::Inst;

impl Schedule {
    /// Repack the buffer of `block`'s `read_idx`-th read through dimension
    /// permutation `perm`: the packed buffer's `i`-th dimension is the
    /// source's `perm[i]`-th. A root-level pack block performs the data
    /// movement and the consumer's regions and loads are rewritten to the
    /// packed layout (`idx'[i] = idx[perm[i]]`). Returns the pack block.
    pub fn transform_layout(
        &mut self,
        block: BlockRv,
        read_idx: usize,
        perm: &[usize],
    ) -> SchResult<BlockRv> {
        let item = self.block(block)?;
        let bd = self.prog.block_data(item).clone();
        let region = bd
            .reads
            .get(read_idx)
            .ok_or_else(|| {
                ScheduleError::InvalidDecision(format!(
                    "transform-layout index {read_idx} out of {} reads",
                    bd.reads.len()
                ))
            })?
            .clone();
        let src = region.buffer;
        let src_buf = self.prog.buffers[src].clone();
        let rank = src_buf.shape.len();
        // perm must be a permutation of 0..rank.
        let mut seen = vec![false; rank];
        if perm.len() != rank || perm.iter().any(|&d| d >= rank || std::mem::replace(&mut seen[d], true)) {
            return Err(ScheduleError::InvalidDecision(format!(
                "transform-layout perm {perm:?} is not a permutation of 0..{rank}"
            )));
        }
        // Every access to src in this block must be full-rank for the
        // index rewrite to be meaningful.
        let mut full_rank = true;
        let check = |e: &CExpr| {
            e.map_loads(&mut |b, idx| {
                if b == src && idx.len() != rank {
                    full_rank = false;
                }
                CExpr::Load(b, idx.to_vec())
            })
        };
        match &bd.body {
            BlockBody::Assign { expr } => {
                check(expr);
            }
            BlockBody::Reduce { init, rhs, .. } => {
                check(init);
                check(rhs);
            }
            BlockBody::Opaque { .. } => {
                return Err(ScheduleError::Unsupported(
                    "transform-layout on an opaque block".into(),
                ))
            }
        }
        if !full_rank || bd.reads.iter().any(|r| r.buffer == src && r.ranges.len() != rank) {
            return Err(ScheduleError::Unsupported(
                "transform-layout: source accessed below full rank".into(),
            ));
        }
        let packed_shape: Vec<i64> = perm.iter().map(|&d| src_buf.shape[d]).collect();
        let packed = self.prog.add_buffer(Buffer {
            name: format!("{}_layout", src_buf.name),
            shape: packed_shape.clone(),
            dtype: src_buf.dtype,
            scope: src_buf.scope,
            align: src_buf.align,
            inlined: false,
        });
        // Pack block: iterate the packed dims; src dim `perm[i]` is indexed
        // by packed iter `i`.
        let pack = self.build_pack_block(
            &format!("{}_pack", src_buf.name),
            src,
            packed,
            &packed_shape,
            perm,
        );
        let consumer_root = self.prog.root_of(item);
        let pos = self
            .prog
            .roots
            .iter()
            .position(|&r| r == consumer_root)
            .unwrap_or(0);
        self.attach_nest_at_root(pack, pos);
        // Rewrite the consumer to the packed layout.
        {
            let bd_mut = self.prog.block_data_mut(item);
            for r in bd_mut.reads.iter_mut() {
                if r.buffer == src {
                    r.ranges = perm.iter().map(|&d| r.ranges[d].clone()).collect();
                    r.buffer = packed;
                }
            }
            let redirect = |e: &CExpr| {
                e.map_loads(&mut |b, idx| {
                    if b == src {
                        CExpr::Load(packed, perm.iter().map(|&d| idx[d].clone()).collect())
                    } else {
                        CExpr::Load(b, idx.to_vec())
                    }
                })
            };
            bd_mut.body = match &bd_mut.body {
                BlockBody::Assign { expr } => BlockBody::Assign {
                    expr: redirect(expr),
                },
                BlockBody::Reduce { init, op, rhs } => BlockBody::Reduce {
                    init: redirect(init),
                    op: *op,
                    rhs: redirect(rhs),
                },
                BlockBody::Opaque { flops_per_instance } => BlockBody::Opaque {
                    flops_per_instance: *flops_per_instance,
                },
            };
        }
        let rv = self.push_block(pack);
        self.record(Inst::TransformLayout {
            block: block.0,
            read_idx,
            perm: perm.to_vec(),
            out: rv.0,
        });
        Ok(rv)
    }

    /// Build `dst[a0..] = src[b]` with `b[perm[i]] = a_i`, loops not yet
    /// attached (the permuted sibling of `build_copy_block`).
    fn build_pack_block(
        &mut self,
        name: &str,
        src: usize,
        dst: usize,
        dst_shape: &[i64],
        perm: &[usize],
    ) -> usize {
        let mut iters = Vec::new();
        let mut loops = Vec::new();
        for (d, &extent) in dst_shape.iter().enumerate() {
            let lv = self.prog.fresh_var(&format!("p{d}_"));
            let bv = self.prog.fresh_var(&format!("pp{d}_"));
            loops.push(self.prog.alloc_loop(LoopData::new(lv, extent)));
            iters.push(IterVar {
                var: bv,
                extent,
                kind: IterKind::Spatial,
                binding: AExpr::Var(lv),
            });
        }
        let dst_idx: Vec<AExpr> = iters.iter().map(|iv| AExpr::Var(iv.var)).collect();
        // src dim perm[i] <- packed iter i.
        let mut src_idx = vec![AExpr::Const(0); dst_shape.len()];
        for (i, &d) in perm.iter().enumerate() {
            src_idx[d] = dst_idx[i].clone();
        }
        let mut blk = BlockData::new(name);
        blk.reads = vec![Region::point(src, src_idx.clone())];
        blk.writes = vec![Region::point(dst, dst_idx)];
        blk.body = BlockBody::Assign {
            expr: CExpr::Load(src, src_idx),
        };
        blk.iters = iters;
        let blk = self.prog.alloc_block(blk);
        let mut parent: Option<usize> = None;
        for &l in &loops {
            if let Some(p) = parent {
                self.prog.items[l].parent = Some(p);
                self.prog.items[p].children.push(l);
            }
            parent = Some(l);
        }
        if let Some(p) = parent {
            self.prog.items[blk].parent = Some(p);
            self.prog.items[p].children.push(blk);
        }
        blk
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::tir::analysis::program_flops;
    use crate::workloads;

    #[test]
    fn transform_layout_repacks_dense_weights() {
        // dense reads W[j, k]; innermost spatial j strides by k. Repacking
        // with perm [1, 0] gives W_layout[k, j] with j contiguous.
        let mut s = Schedule::new(workloads::dense(16, 8, 32), 0);
        let b = s.get_block("dense").unwrap();
        let pack = s.transform_layout(b, 1, &[1, 0]).unwrap();
        s.prog.check_integrity().unwrap();
        let packed = s
            .prog
            .buffers
            .iter()
            .find(|bf| bf.name == "W_layout")
            .unwrap();
        assert_eq!(packed.shape, vec![32, 8]); // transposed [8, 32]
        let pack_item = s.block(pack).unwrap();
        assert_eq!(s.prog.block_data(pack_item).name, "W_pack");
        // Consumer now loads W_layout[k, j].
        let d = s.prog.find_block("dense").unwrap();
        let packed_id = s
            .prog
            .buffers
            .iter()
            .position(|bf| bf.name == "W_layout")
            .unwrap();
        assert_eq!(s.prog.block_data(d).reads[1].buffer, packed_id);
        // Pack nest precedes the consumer nest at root.
        assert_eq!(s.prog.root_of(pack_item), s.prog.roots[0]);
        // The pack adds data movement, not FLOPs beyond the copy.
        assert!(program_flops(&s.prog) >= 2.0 * 16.0 * 8.0 * 32.0);
    }

    #[test]
    fn transform_layout_rejects_bad_perms() {
        let mut s = Schedule::new(workloads::dense(8, 8, 8), 0);
        let b = s.get_block("dense").unwrap();
        assert!(s.transform_layout(b, 1, &[0, 0]).is_err());
        assert!(s.transform_layout(b, 1, &[0]).is_err());
        assert!(s.transform_layout(b, 1, &[0, 2]).is_err());
        assert!(s.transform_layout(b, 9, &[1, 0]).is_err());
    }

    #[test]
    fn transform_layout_replays_from_trace() {
        let mut s = Schedule::new(workloads::dense(16, 8, 32), 0);
        let b = s.get_block("dense").unwrap();
        s.transform_layout(b, 1, &[1, 0]).unwrap();
        let replayed = crate::trace::replay(&s.trace, &workloads::dense(16, 8, 32), 0).unwrap();
        assert_eq!(
            crate::tir::structural_hash(&replayed.prog),
            crate::tir::structural_hash(&s.prog)
        );
    }
}
