//! Loop transformations: split, fuse, reorder, parallel, vectorize, unroll,
//! bind, add-unit-loop.
//!
//! Loop restructuring rewrites only the *iter bindings* of blocks beneath
//! the affected loops (the block bodies are expressed over block iteration
//! variables and never change).

use crate::schedule::{LoopRef, LoopRv, SchResult, Schedule, ScheduleError, BlockRv};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::{AExpr, ItemId, LoopData, LoopKind};
use crate::trace::{FactorArg, Inst};

impl Schedule {
    /// Split a loop into `factors.len()` nested loops (outermost first).
    /// The factor product must equal the loop extent (perfect split).
    pub fn split(&mut self, loop_rv: LoopRv, factors: &[FactorArg]) -> SchResult<Vec<LoopRv>> {
        let item = self.loop_item(loop_rv)?;
        let concrete: Vec<i64> = factors
            .iter()
            .map(|f| match f {
                FactorArg::Rv(rv) => self.exprs[*rv],
                FactorArg::Lit(v) => *v,
            })
            .collect();
        let outs = self.split_concrete(item, &concrete)?;
        let out_rvs: Vec<LoopRv> = outs
            .iter()
            .map(|&l| self.push_loop(LoopRef::Item(l)))
            .collect();
        self.record(Inst::Split {
            loop_rv: loop_rv.0,
            factors: factors.to_vec(),
            outs: out_rvs.iter().map(|r| r.0).collect(),
        });
        Ok(out_rvs)
    }

    /// Internal: split `item` by concrete factors; returns new loop items.
    pub(crate) fn split_concrete(
        &mut self,
        item: ItemId,
        factors: &[i64],
    ) -> SchResult<Vec<ItemId>> {
        if factors.is_empty() {
            return Err(ScheduleError::InvalidDecision("empty split factors".into()));
        }
        if factors.iter().any(|&f| f <= 0) {
            return Err(ScheduleError::InvalidDecision(format!(
                "non-positive split factor in {factors:?}"
            )));
        }
        let data = self.prog.loop_data(item).clone();
        let product: i64 = factors.iter().product();
        if product != data.extent {
            return Err(ScheduleError::ImperfectSplit {
                extent: data.extent,
                product,
            });
        }
        if data.kind != LoopKind::Serial {
            return Err(ScheduleError::WrongLoopKind(format!(
                "cannot split {} loop",
                data.kind.name()
            )));
        }
        // Allocate new vars + loops, outermost first.
        let base = self.prog.var_name(data.var).to_string();
        let new_vars: Vec<_> = (0..factors.len())
            .map(|i| self.prog.fresh_var(&format!("{base}_{i}_")))
            .collect();
        // old_var = v0*s0 + v1*s1 + ... where s_i = prod(factors[i+1..])
        let mut replacement = AExpr::Const(0);
        for (i, &v) in new_vars.iter().enumerate() {
            let stride: i64 = factors[i + 1..].iter().product();
            replacement = replacement.add(AExpr::Var(v).mul(stride));
        }
        // Rewrite bindings beneath before restructuring.
        self.prog.subst_loop_var_under(item, data.var, &replacement);
        // Build the chain of new loops in place of `item`.
        let parent = self.prog.items[item].parent;
        let pos = match parent {
            Some(p) => self.prog.items[p]
                .children
                .iter()
                .position(|&c| c == item)
                .unwrap(),
            None => self.prog.roots.iter().position(|&c| c == item).unwrap(),
        };
        let children = self.prog.items[item].children.clone();
        self.prog.detach(item);
        self.prog.items[item].alive = false;
        let mut new_items = Vec::with_capacity(factors.len());
        let mut cur_parent = parent;
        let mut cur_pos = pos;
        for (i, (&v, &f)) in new_vars.iter().zip(factors).enumerate() {
            let l = self.prog.alloc_loop(LoopData::new(v, f));
            self.prog.attach_at(l, cur_parent, cur_pos);
            new_items.push(l);
            cur_parent = Some(l);
            cur_pos = 0;
            let _ = i;
        }
        let innermost = *new_items.last().unwrap();
        for c in children {
            self.prog.items[c].parent = Some(innermost);
            self.prog.items[innermost].children.push(c);
        }
        Ok(new_items)
    }

    /// Fuse a chain of perfectly-nested loops into one.
    pub fn fuse(&mut self, loop_rvs: &[LoopRv]) -> SchResult<LoopRv> {
        if loop_rvs.is_empty() {
            return Err(ScheduleError::InvalidDecision("fuse of zero loops".into()));
        }
        let items: Vec<ItemId> = loop_rvs
            .iter()
            .map(|&rv| self.loop_item(rv))
            .collect::<SchResult<_>>()?;
        let fused = self.fuse_concrete(&items)?;
        let rv = self.push_loop(LoopRef::Item(fused));
        self.record(Inst::Fuse {
            loops: loop_rvs.iter().map(|r| r.0).collect(),
            out: rv.0,
        });
        Ok(rv)
    }

    pub(crate) fn fuse_concrete(&mut self, items: &[ItemId]) -> SchResult<ItemId> {
        // Verify a simple parent-child chain, each link an only child.
        for w in items.windows(2) {
            let (a, b) = (w[0], w[1]);
            if self.prog.items[b].parent != Some(a) {
                return Err(ScheduleError::NotAChain(format!("items {a} -> {b}")));
            }
            if self.prog.items[a].children.len() != 1 {
                return Err(ScheduleError::NotAChain(format!(
                    "loop {a} has multiple children"
                )));
            }
        }
        for &i in items {
            if self.prog.loop_data(i).kind != LoopKind::Serial {
                return Err(ScheduleError::WrongLoopKind("fuse non-serial loop".into()));
            }
        }
        let extents: Vec<i64> = items.iter().map(|&i| self.prog.loop_data(i).extent).collect();
        let total: i64 = extents.iter().product();
        let fused_var = self.prog.fresh_var("f");
        // var_i = (fused / prod(extents[i+1..])) % extents[i]
        let innermost = *items.last().unwrap();
        for (i, &item) in items.iter().enumerate() {
            let stride: i64 = extents[i + 1..].iter().product();
            let mut expr = AExpr::Var(fused_var);
            if stride > 1 {
                expr = expr.floordiv(stride);
            }
            if i > 0 {
                expr = expr.modulo(extents[i]);
            }
            let var = self.prog.loop_data(item).var;
            self.prog.subst_loop_var_under(innermost, var, &expr);
        }
        // Replace the chain with the fused loop.
        let outermost = items[0];
        let parent = self.prog.items[outermost].parent;
        let pos = match parent {
            Some(p) => self.prog.items[p]
                .children
                .iter()
                .position(|&c| c == outermost)
                .unwrap(),
            None => self
                .prog
                .roots
                .iter()
                .position(|&c| c == outermost)
                .unwrap(),
        };
        let inner_children = self.prog.items[innermost].children.clone();
        self.prog.detach(outermost);
        for &i in items {
            self.prog.items[i].alive = false;
        }
        let fused = self.prog.alloc_loop(LoopData::new(fused_var, total));
        self.prog.attach_at(fused, parent, pos);
        for c in inner_children {
            self.prog.items[c].parent = Some(fused);
            self.prog.items[fused].children.push(c);
        }
        Ok(fused)
    }

    /// Reorder the given loops (which must lie on one single-child chain)
    /// into the order given (outermost first).
    pub fn reorder(&mut self, loop_rvs: &[LoopRv]) -> SchResult<()> {
        let items: Vec<ItemId> = loop_rvs
            .iter()
            .map(|&rv| self.loop_item(rv))
            .collect::<SchResult<_>>()?;
        self.reorder_concrete(&items)?;
        self.record(Inst::Reorder {
            loops: loop_rvs.iter().map(|r| r.0).collect(),
        });
        Ok(())
    }

    pub(crate) fn reorder_concrete(&mut self, order: &[ItemId]) -> SchResult<()> {
        if order.len() < 2 {
            return Ok(());
        }
        // Find the chain: sort the given loops by depth.
        let mut with_depth: Vec<(usize, ItemId)> = order
            .iter()
            .map(|&i| (self.prog.loops_above(i).len(), i))
            .collect();
        with_depth.sort_by_key(|&(d, _)| d);
        let chain_positions: Vec<ItemId> = with_depth.iter().map(|&(_, i)| i).collect();
        // Verify they are on one chain with single children in between.
        for w in chain_positions.windows(2) {
            let (outer, inner) = (w[0], w[1]);
            let mut cur = self.prog.items[inner].parent;
            loop {
                match cur {
                    Some(p) if p == outer => break,
                    Some(p) => {
                        if self.prog.items[p].children.len() != 1 {
                            return Err(ScheduleError::NotAChain(format!(
                                "branching at loop {p} between reordered loops"
                            )));
                        }
                        cur = self.prog.items[p].parent;
                    }
                    None => {
                        return Err(ScheduleError::NotAChain(
                            "reordered loops not nested".into(),
                        ))
                    }
                }
            }
            if self.prog.items[outer].children.len() != 1 {
                return Err(ScheduleError::NotAChain(format!(
                    "loop {outer} has multiple children"
                )));
            }
        }
        // Swap the loop *payloads* at the chain positions into the requested
        // order, then fix RV tables so handles keep following their loops.
        //
        // order[i] should end up at chain_positions[i]. Payload swap means
        // the ItemId at chain_positions[i] now holds order[i]'s data; update
        // loop RV entries pointing at moved items accordingly.
        let mut payloads: Vec<LoopData> = order
            .iter()
            .map(|&i| self.prog.loop_data(i).clone())
            .collect();
        // Map old item -> new item for RV fixup.
        let mut moves: Vec<(ItemId, ItemId)> = Vec::new();
        for (slot, &src) in chain_positions.iter().zip(order.iter()) {
            if *slot != src {
                moves.push((src, *slot));
            }
        }
        for (slot, payload) in chain_positions.iter().zip(payloads.drain(..)) {
            *self.prog.loop_data_mut(*slot) = payload;
        }
        for lr in self.loops.iter_mut() {
            if let LoopRef::Item(item) = lr {
                if let Some(&(_, dst)) = moves.iter().find(|&&(src, _)| src == *item) {
                    *lr = LoopRef::Item(dst);
                }
            }
        }
        Ok(())
    }

    fn set_loop_kind(&mut self, loop_rv: LoopRv, kind: LoopKind, spatial_only: bool) -> SchResult<ItemId> {
        let item = self.loop_item(loop_rv)?;
        if spatial_only {
            match classify_loop(&self.prog, item) {
                LoopClass::Spatial | LoopClass::Unused => {}
                c => {
                    return Err(ScheduleError::WrongLoopKind(format!(
                        "cannot apply {} to {:?} loop",
                        kind.name(),
                        c
                    )))
                }
            }
        }
        self.prog.loop_data_mut(item).kind = kind;
        Ok(item)
    }

    /// Parallelize a (data-parallel) loop across CPU cores.
    pub fn parallel(&mut self, loop_rv: LoopRv) -> SchResult<()> {
        self.set_loop_kind(loop_rv, LoopKind::Parallel, true)?;
        self.record(Inst::Parallel { loop_rv: loop_rv.0 });
        Ok(())
    }

    /// Vectorize a (data-parallel) loop with SIMD.
    pub fn vectorize(&mut self, loop_rv: LoopRv) -> SchResult<()> {
        self.set_loop_kind(loop_rv, LoopKind::Vectorized, true)?;
        self.record(Inst::Vectorize { loop_rv: loop_rv.0 });
        Ok(())
    }

    /// Unroll a loop.
    pub fn unroll(&mut self, loop_rv: LoopRv) -> SchResult<()> {
        self.set_loop_kind(loop_rv, LoopKind::Unrolled, false)?;
        self.record(Inst::Unroll { loop_rv: loop_rv.0 });
        Ok(())
    }

    /// Bind a loop to a GPU thread axis (blockIdx.* / threadIdx.*).
    pub fn bind(&mut self, loop_rv: LoopRv, thread: &str) -> SchResult<()> {
        // Reduction loops may only bind to threadIdx when the block does
        // cross-thread reduction; we allow it and let the simulator model it.
        let spatial_only = thread.starts_with("blockIdx");
        self.set_loop_kind(loop_rv, LoopKind::ThreadBinding(thread.to_string()), spatial_only)?;
        self.record(Inst::Bind {
            loop_rv: loop_rv.0,
            thread: thread.to_string(),
        });
        Ok(())
    }

    /// Create a unit (extent-1) loop immediately above a block.
    pub fn add_unit_loop(&mut self, block: BlockRv) -> SchResult<LoopRv> {
        let item = self.block(block)?;
        let var = self.prog.fresh_var("u");
        let parent = self.prog.items[item].parent;
        let pos = match parent {
            Some(p) => self.prog.items[p]
                .children
                .iter()
                .position(|&c| c == item)
                .unwrap(),
            None => self.prog.roots.iter().position(|&c| c == item).unwrap(),
        };
        self.prog.detach(item);
        let l = self.prog.alloc_loop(LoopData::new(var, 1));
        self.prog.attach_at(l, parent, pos);
        self.prog.attach(item, Some(l));
        let rv = self.push_loop(LoopRef::Item(l));
        self.record(Inst::AddUnitLoop {
            block: block.0,
            out: rv.0,
        });
        Ok(rv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::matmul_prog;
    use crate::tir::analysis::program_flops;

    fn sch() -> Schedule {
        Schedule::new(matmul_prog(64, 32), 0)
    }

    #[test]
    fn split_preserves_flops_and_structure() {
        let mut s = sch();
        let before = program_flops(&s.prog);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let outs = s
            .split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(16)])
            .unwrap();
        assert_eq!(outs.len(), 2);
        s.prog.check_integrity().unwrap();
        assert_eq!(program_flops(&s.prog), before);
        // Block now sits under 4 loops.
        let item = s.block(b).unwrap();
        assert_eq!(s.prog.loops_above(item).len(), 4);
    }

    #[test]
    fn imperfect_split_rejected() {
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let e = s.split(loops[0], &[FactorArg::Lit(7), FactorArg::Lit(9)]);
        assert!(matches!(e, Err(ScheduleError::ImperfectSplit { .. })));
    }

    #[test]
    fn stale_handle_after_split_rejected() {
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(16)])
            .unwrap();
        // The original loop RV is now dead.
        assert!(matches!(
            s.split(loops[0], &[FactorArg::Lit(2), FactorArg::Lit(32)]),
            Err(ScheduleError::StaleHandle(_))
        ));
    }

    #[test]
    fn fuse_then_extent_is_product() {
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let f = s.fuse(&loops[0..2]).unwrap();
        let item = s.loop_item(f).unwrap();
        assert_eq!(s.prog.loop_data(item).extent, 64 * 64);
        s.prog.check_integrity().unwrap();
        assert_eq!(program_flops(&s.prog), 64.0 * 64.0 * 32.0 * 2.0);
    }

    #[test]
    fn split_fuse_roundtrip_bindings() {
        // split i into (4,16) then fuse back: binding must still evaluate
        // to the same set of instances (flops invariant + integrity).
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let parts = s
            .split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(16)])
            .unwrap();
        let fused = s.fuse(&parts).unwrap();
        let item = s.loop_item(fused).unwrap();
        assert_eq!(s.prog.loop_data(item).extent, 64);
        s.prog.check_integrity().unwrap();
    }

    #[test]
    fn reorder_swaps_loop_payloads() {
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        // original order: i(64) j(64) k(32); request k j i
        s.reorder(&[loops[2], loops[1], loops[0]]).unwrap();
        let item = s.block(b).unwrap();
        let above = s.prog.loops_above(item);
        let extents: Vec<i64> = above.iter().map(|&l| s.prog.loop_data(l).extent).collect();
        assert_eq!(extents, vec![32, 64, 64]);
        // RVs must follow their loops: loops[0] (i) should now be innermost.
        let i_item = s.loop_item(loops[0]).unwrap();
        assert_eq!(above[2], i_item);
        s.prog.check_integrity().unwrap();
    }

    #[test]
    fn parallel_on_reduce_loop_rejected() {
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        assert!(s.parallel(loops[2]).is_err()); // k is a reduction loop
        s.parallel(loops[0]).unwrap();
        s.vectorize(loops[1]).unwrap();
        s.unroll(loops[2]).unwrap(); // unroll is fine on reduce loops
    }

    #[test]
    fn add_unit_loop_wraps_block() {
        let mut s = sch();
        let b = s.get_block("matmul").unwrap();
        let u = s.add_unit_loop(b).unwrap();
        let item = s.block(b).unwrap();
        let above = s.prog.loops_above(item);
        assert_eq!(above.len(), 4);
        assert_eq!(*above.last().unwrap(), s.loop_item(u).unwrap());
        s.prog.check_integrity().unwrap();
    }
}
