//! The probabilistic schedule: program state + transformation primitives.
//!
//! A [`Schedule`] wraps a [`Program`] together with random-variable tables
//! and the execution [`Trace`](crate::trace::Trace). Every primitive both
//! transforms the program *and* appends an instruction to the trace, so a
//! schedule execution can be re-run, mutated, serialized, and validated —
//! the paper's "execution tracing" (§4, Figure 6).
//!
//! Primitives are grouped by file: [`loops`], [`cache`], [`location`],
//! [`reduction`], [`blockize`], [`sampling`].

pub mod blockize;
pub mod cache;
pub mod layout;
pub mod location;
pub mod loops;
pub mod reduction;
pub mod sampling;

use std::fmt;

use crate::tir::{ItemId, Program};
use crate::trace::{Inst, Trace};
use crate::util::rng::Rng;

/// Handle to a block random variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockRv(pub usize);

/// Handle to a loop random variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LoopRv(pub usize);

/// Handle to an integer expression random variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExprRv(pub usize);

/// What a loop RV refers to. `Root` and `Inlined` are the sentinel
/// locations produced by `sample-compute-location` (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopRef {
    Item(ItemId),
    Root,
    Inlined,
}

/// Errors from schedule primitives. During search these are *expected*: the
/// trace validator (paper §4, "Trace validation") rejects mutated traces
/// whose decisions fall off the support by catching exactly these.
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleError {
    BlockNotFound(String),
    StaleHandle(String),
    NotALoop(String),
    ImperfectSplit { extent: i64, product: i64 },
    NotAChain(String),
    WrongLoopKind(String),
    InvalidDecision(String),
    NotInlineable(String),
    NotReduction(String),
    InvalidComputeAt(String),
    TensorizeMismatch(String),
    Unsupported(String),
}

impl fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScheduleError::BlockNotFound(s) => write!(f, "block not found: {s}"),
            ScheduleError::StaleHandle(s) => write!(f, "stale handle: {s}"),
            ScheduleError::NotALoop(s) => write!(f, "not a loop: {s}"),
            ScheduleError::ImperfectSplit { extent, product } => {
                write!(f, "imperfect split: extent {extent} != factor product {product}")
            }
            ScheduleError::NotAChain(s) => write!(f, "loops not a simple chain: {s}"),
            ScheduleError::WrongLoopKind(s) => write!(f, "wrong loop kind: {s}"),
            ScheduleError::InvalidDecision(s) => write!(f, "invalid decision: {s}"),
            ScheduleError::NotInlineable(s) => write!(f, "not inlineable: {s}"),
            ScheduleError::NotReduction(s) => write!(f, "not a reduction: {s}"),
            ScheduleError::InvalidComputeAt(s) => write!(f, "invalid compute-at: {s}"),
            ScheduleError::TensorizeMismatch(s) => write!(f, "tensorize mismatch: {s}"),
            ScheduleError::Unsupported(s) => write!(f, "unsupported: {s}"),
        }
    }
}

impl std::error::Error for ScheduleError {}

pub type SchResult<T> = Result<T, ScheduleError>;

/// Program state + RV tables + trace: one stochastic schedule execution.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub prog: Program,
    pub trace: Trace,
    pub rng: Rng,
    pub(crate) blocks: Vec<Option<ItemId>>,
    pub(crate) loops: Vec<LoopRef>,
    pub(crate) exprs: Vec<i64>,
}

impl Schedule {
    /// Start scheduling from an initial program `e_0`.
    pub fn new(prog: Program, seed: u64) -> Schedule {
        Schedule {
            prog,
            trace: Trace::default(),
            rng: Rng::seed_from_u64(seed),
            blocks: Vec::new(),
            loops: Vec::new(),
            exprs: Vec::new(),
        }
    }

    // ---- RV table plumbing -------------------------------------------------

    pub(crate) fn push_block(&mut self, item: ItemId) -> BlockRv {
        self.blocks.push(Some(item));
        BlockRv(self.blocks.len() - 1)
    }

    pub(crate) fn push_loop(&mut self, r: LoopRef) -> LoopRv {
        self.loops.push(r);
        LoopRv(self.loops.len() - 1)
    }

    pub(crate) fn push_expr(&mut self, v: i64) -> ExprRv {
        self.exprs.push(v);
        ExprRv(self.exprs.len() - 1)
    }

    /// Resolve a block RV, checking liveness.
    pub fn block(&self, rv: BlockRv) -> SchResult<ItemId> {
        let item = self.blocks[rv.0]
            .ok_or_else(|| ScheduleError::StaleHandle(format!("block rv {}", rv.0)))?;
        if !self.prog.items[item].alive {
            return Err(ScheduleError::StaleHandle(format!(
                "block rv {} (item {item} dead)",
                rv.0
            )));
        }
        Ok(item)
    }

    /// Resolve a loop RV to an item, checking liveness.
    pub fn loop_item(&self, rv: LoopRv) -> SchResult<ItemId> {
        match self.loops[rv.0] {
            LoopRef::Item(item) => {
                if !self.prog.items[item].alive {
                    return Err(ScheduleError::StaleHandle(format!(
                        "loop rv {} (item {item} dead)",
                        rv.0
                    )));
                }
                Ok(item)
            }
            LoopRef::Root | LoopRef::Inlined => Err(ScheduleError::NotALoop(format!(
                "loop rv {} is a sentinel location",
                rv.0
            ))),
        }
    }

    /// Resolve a loop RV including sentinel locations.
    pub fn loop_ref(&self, rv: LoopRv) -> LoopRef {
        self.loops[rv.0]
    }

    /// Value of an integer expression RV.
    pub fn expr_value(&self, rv: ExprRv) -> i64 {
        self.exprs[rv.0]
    }

    pub(crate) fn record(&mut self, inst: Inst) {
        self.trace.insts.push(inst);
    }

    // ---- state queries (recorded, so traces replay identically) ------------

    /// Look up a block by name and bind it to a fresh block RV.
    pub fn get_block(&mut self, name: &str) -> SchResult<BlockRv> {
        let item = self
            .prog
            .find_block(name)
            .ok_or_else(|| ScheduleError::BlockNotFound(name.to_string()))?;
        let rv = self.push_block(item);
        self.record(Inst::GetBlock {
            name: name.to_string(),
            out: rv.0,
        });
        Ok(rv)
    }

    /// Loops above a block, outermost first, bound to fresh loop RVs.
    pub fn get_loops(&mut self, block: BlockRv) -> SchResult<Vec<LoopRv>> {
        let item = self.block(block)?;
        let loops = self.prog.loops_above(item);
        let rvs: Vec<LoopRv> = loops.iter().map(|&l| self.push_loop(LoopRef::Item(l))).collect();
        self.record(Inst::GetLoops {
            block: block.0,
            outs: rvs.iter().map(|r| r.0).collect(),
        });
        Ok(rvs)
    }

    /// Producer blocks of `block`, bound to fresh RVs.
    pub fn get_producers(&mut self, block: BlockRv) -> SchResult<Vec<BlockRv>> {
        let item = self.block(block)?;
        let prods = self.prog.producers_of(item);
        let rvs: Vec<BlockRv> = prods.iter().map(|&b| self.push_block(b)).collect();
        self.record(Inst::GetProducers {
            block: block.0,
            outs: rvs.iter().map(|r| r.0).collect(),
        });
        Ok(rvs)
    }

    /// Consumer blocks of `block`, bound to fresh RVs.
    pub fn get_consumers(&mut self, block: BlockRv) -> SchResult<Vec<BlockRv>> {
        let item = self.block(block)?;
        let cons = self.prog.consumers_of(item);
        let rvs: Vec<BlockRv> = cons.iter().map(|&b| self.push_block(b)).collect();
        self.record(Inst::GetConsumers {
            block: block.0,
            outs: rvs.iter().map(|r| r.0).collect(),
        });
        Ok(rvs)
    }

    /// Annotate a block with a key/value pair.
    pub fn annotate_block(&mut self, block: BlockRv, key: &str, value: &str) -> SchResult<()> {
        let item = self.block(block)?;
        self.prog
            .block_data_mut(item)
            .annotate(key, value);
        self.record(Inst::AnnotateBlock {
            block: block.0,
            key: key.to_string(),
            value: value.to_string(),
        });
        Ok(())
    }

    /// Annotate a loop with a key/value pair.
    pub fn annotate_loop(&mut self, loop_rv: LoopRv, key: &str, value: &str) -> SchResult<()> {
        let item = self.loop_item(loop_rv)?;
        self.prog
            .loop_data_mut(item)
            .annotations
            .insert(key.to_string(), value.to_string());
        self.record(Inst::AnnotateLoop {
            loop_rv: loop_rv.0,
            key: key.to_string(),
            value: value.to_string(),
        });
        Ok(())
    }

    /// Remove an annotation from a block.
    pub fn unannotate_block(&mut self, block: BlockRv, key: &str) -> SchResult<()> {
        let item = self.block(block)?;
        self.prog.block_data_mut(item).annotations.remove(key);
        self.record(Inst::UnannotateBlock {
            block: block.0,
            key: key.to_string(),
        });
        Ok(())
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::tir::*;

    /// C[i,j] = sum_k A[i,k]*B[k,j], square `n`, reduce `k`.
    pub fn matmul_prog(n: i64, k: i64) -> Program {
        let mut p = Program::new("matmul");
        let a = p.param("A", vec![n, k], DType::F32);
        let b = p.param("B", vec![k, n], DType::F32);
        let c = p.param("C", vec![n, n], DType::F32);
        p.emit(
            "matmul",
            &[sp("i", n), sp("j", n), rd("k", k)],
            |iv| {
                let (i, j, kk) = (iv[0], iv[1], iv[2]);
                (
                    vec![
                        Region::point(a, vec![AExpr::Var(i), AExpr::Var(kk)]),
                        Region::point(b, vec![AExpr::Var(kk), AExpr::Var(j)]),
                    ],
                    vec![Region::point(c, vec![AExpr::Var(i), AExpr::Var(j)])],
                    BlockBody::Reduce {
                        init: CExpr::ConstF(0.0),
                        op: BinOp::Add,
                        rhs: CExpr::bin(
                            BinOp::Mul,
                            CExpr::load(a, vec![AExpr::Var(i), AExpr::Var(kk)]),
                            CExpr::load(b, vec![AExpr::Var(kk), AExpr::Var(j)]),
                        ),
                    },
                )
            },
        );
        p
    }

    /// Dense (matmul) followed by elementwise ReLU — the paper's Figure 3
    /// running example.
    pub fn dense_relu_prog(n: i64, k: i64) -> Program {
        let mut p = matmul_prog(n, k);
        p.name = "dense_relu".into();
        let c = 2; // matmul output buffer id from matmul_prog
        let d = p.param("D", vec![n, n], DType::F32);
        p.emit("relu", &[sp("i", n), sp("j", n)], |iv| {
            let (i, j) = (iv[0], iv[1]);
            (
                vec![Region::point(c, vec![AExpr::Var(i), AExpr::Var(j)])],
                vec![Region::point(d, vec![AExpr::Var(i), AExpr::Var(j)])],
                BlockBody::Assign {
                    expr: CExpr::un(
                        UnOp::Relu,
                        CExpr::load(c, vec![AExpr::Var(i), AExpr::Var(j)]),
                    ),
                },
            )
        });
        p
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn get_block_and_loops() {
        let mut sch = Schedule::new(matmul_prog(16, 8), 0);
        let b = sch.get_block("matmul").unwrap();
        let loops = sch.get_loops(b).unwrap();
        assert_eq!(loops.len(), 3);
        assert_eq!(sch.trace.insts.len(), 2);
    }

    #[test]
    fn missing_block_errors() {
        let mut sch = Schedule::new(matmul_prog(16, 8), 0);
        assert!(matches!(
            sch.get_block("nope"),
            Err(ScheduleError::BlockNotFound(_))
        ));
    }

    #[test]
    fn producers_consumers() {
        let mut sch = Schedule::new(dense_relu_prog(16, 8), 0);
        let dense = sch.get_block("matmul").unwrap();
        let relu = sch.get_block("relu").unwrap();
        let cons = sch.get_consumers(dense).unwrap();
        assert_eq!(cons.len(), 1);
        assert_eq!(sch.block(cons[0]).unwrap(), sch.block(relu).unwrap());
        let prods = sch.get_producers(relu).unwrap();
        assert_eq!(prods.len(), 1);
    }

    #[test]
    fn annotations_recorded() {
        let mut sch = Schedule::new(matmul_prog(16, 8), 0);
        let b = sch.get_block("matmul").unwrap();
        sch.annotate_block(b, "k", "v").unwrap();
        let item = sch.block(b).unwrap();
        assert_eq!(sch.prog.block_data(item).annotations["k"], "v");
        sch.unannotate_block(b, "k").unwrap();
        assert!(sch.prog.block_data(item).annotations.is_empty());
    }
}
