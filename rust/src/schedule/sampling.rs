//! Sampling instructions — the probabilistic core of the language.
//!
//! Random variables are drawn from distributions that depend on the
//! *current* program state (paper §3.1): `sample-perfect-tile` enumerates
//! the factorizations of the loop's current extent,
//! `sample-compute-location` enumerates the loops of the block's consumer
//! in the current loop tree. Decisions are recorded in the trace and can be
//! overridden on replay (mutation) — invalid overrides surface as
//! `ScheduleError::InvalidDecision`, which is what the trace validator
//! catches.

use crate::schedule::{BlockRv, ExprRv, LoopRef, LoopRv, SchResult, Schedule, ScheduleError};
use crate::tir::ItemId;
use crate::trace::Inst;

/// Enumerate ordered factorizations of `extent` into `n` positive factors
/// with the last factor bounded by `max_innermost` (0 = unbounded).
/// Memoized per thread: the same (extent, n, bound) support is enumerated
/// on every fork-and-sample of a trace, which made this the hottest part
/// of population initialization (§Perf).
pub fn enumerate_perfect_tiles(extent: i64, n: usize, max_innermost: i64) -> std::rc::Rc<Vec<Vec<i64>>> {
    thread_local! {
        static CACHE: std::cell::RefCell<std::collections::HashMap<(i64, usize, i64), std::rc::Rc<Vec<Vec<i64>>>>> =
            std::cell::RefCell::new(std::collections::HashMap::new());
    }
    CACHE.with(|c| {
        if let Some(hit) = c.borrow().get(&(extent, n, max_innermost)) {
            return hit.clone();
        }
        let v = std::rc::Rc::new(enumerate_perfect_tiles_uncached(extent, n, max_innermost));
        c.borrow_mut().insert((extent, n, max_innermost), v.clone());
        v
    })
}

fn enumerate_perfect_tiles_uncached(extent: i64, n: usize, max_innermost: i64) -> Vec<Vec<i64>> {
    fn divisors(x: i64) -> Vec<i64> {
        let mut out = Vec::new();
        let mut d = 1;
        while d * d <= x {
            if x % d == 0 {
                out.push(d);
                if d != x / d {
                    out.push(x / d);
                }
            }
            d += 1;
        }
        out.sort_unstable();
        out
    }
    fn rec(remaining: i64, parts: usize, max_innermost: i64, cur: &mut Vec<i64>, out: &mut Vec<Vec<i64>>) {
        if parts == 1 {
            if max_innermost == 0 || remaining <= max_innermost {
                cur.push(remaining);
                out.push(cur.clone());
                cur.pop();
            }
            return;
        }
        for d in divisors(remaining) {
            cur.push(d);
            rec(remaining / d, parts - 1, max_innermost, cur, out);
            cur.pop();
            if out.len() > 100_000 {
                return; // safety cap; never hit for realistic extents
            }
        }
    }
    let mut out = Vec::new();
    rec(extent, n, max_innermost, &mut Vec::new(), &mut out);
    out
}

impl Schedule {
    /// Sample tiling factors that perfectly tile `loop_rv` into `n` parts.
    pub fn sample_perfect_tile(
        &mut self,
        loop_rv: LoopRv,
        n: usize,
        max_innermost: i64,
    ) -> SchResult<Vec<ExprRv>> {
        self.sample_perfect_tile_decided(loop_rv, n, max_innermost, None)
    }

    /// Like [`Schedule::sample_perfect_tile`] but with an optional decision
    /// override (used by trace replay / mutation).
    pub fn sample_perfect_tile_decided(
        &mut self,
        loop_rv: LoopRv,
        n: usize,
        max_innermost: i64,
        decision: Option<Vec<i64>>,
    ) -> SchResult<Vec<ExprRv>> {
        let item = self.loop_item(loop_rv)?;
        let extent = self.prog.loop_data(item).extent;
        let factors = match decision {
            Some(d) => {
                if d.len() != n {
                    return Err(ScheduleError::InvalidDecision(format!(
                        "perfect-tile decision has {} parts, expected {n}",
                        d.len()
                    )));
                }
                let product: i64 = d.iter().product();
                if product != extent || d.iter().any(|&f| f <= 0) {
                    return Err(ScheduleError::InvalidDecision(format!(
                        "perfect-tile {d:?} does not tile extent {extent}"
                    )));
                }
                if max_innermost > 0 && *d.last().unwrap() > max_innermost {
                    return Err(ScheduleError::InvalidDecision(format!(
                        "innermost factor {} exceeds bound {max_innermost}",
                        d.last().unwrap()
                    )));
                }
                d
            }
            None => {
                let all = enumerate_perfect_tiles(extent, n, max_innermost);
                if all.is_empty() {
                    return Err(ScheduleError::InvalidDecision(format!(
                        "no perfect tiling of {extent} into {n} parts (max_innermost={max_innermost})"
                    )));
                }
                all[self.rng.gen_range(all.len())].clone()
            }
        };
        let rvs: Vec<ExprRv> = factors.iter().map(|&f| self.push_expr(f)).collect();
        self.record(Inst::SamplePerfectTile {
            loop_rv: loop_rv.0,
            n,
            max_innermost,
            outs: rvs.iter().map(|r| r.0).collect(),
            decision: factors,
        });
        Ok(rvs)
    }

    /// Sample one of `candidates` according to `probs`.
    pub fn sample_categorical(&mut self, candidates: &[i64], probs: &[f64]) -> SchResult<ExprRv> {
        self.sample_categorical_decided(candidates, probs, None)
    }

    /// Decision-overridable version of [`Schedule::sample_categorical`].
    pub fn sample_categorical_decided(
        &mut self,
        candidates: &[i64],
        probs: &[f64],
        decision: Option<usize>,
    ) -> SchResult<ExprRv> {
        if candidates.is_empty() || candidates.len() != probs.len() {
            return Err(ScheduleError::InvalidDecision(
                "categorical candidates/probs mismatch".into(),
            ));
        }
        let idx = match decision {
            Some(i) => {
                if i >= candidates.len() {
                    return Err(ScheduleError::InvalidDecision(format!(
                        "categorical decision {i} out of {} candidates",
                        candidates.len()
                    )));
                }
                i
            }
            None => self.rng.sample_weighted(probs),
        };
        let rv = self.push_expr(candidates[idx]);
        self.record(Inst::SampleCategorical {
            candidates: candidates.to_vec(),
            probs: probs.to_vec(),
            out: rv.0,
            decision: idx,
        });
        Ok(rv)
    }

    /// Candidate compute-at locations for `block`: all loops of its first
    /// consumer (for `compute-at`), or — when the block has no consumer,
    /// i.e. it is an output block — the loops of its first producer (for
    /// `reverse-compute-at`, the paper's Figure 3 Step 2 where ReLU is
    /// fused into a tile loop of Dense). Outermost first either way.
    /// State-dependent support: the candidate set changes as earlier
    /// transformations restructure the loop tree.
    pub fn compute_location_candidates(&self, block_item: ItemId) -> Vec<ItemId> {
        let consumers = self.prog.consumers_of(block_item);
        let loops = if let Some(&c) = consumers.first() {
            self.prog.loops_above(c)
        } else {
            let producers = self.prog.producers_of(block_item);
            match producers.first() {
                Some(&p) => self.prog.loops_above(p),
                None => Vec::new(),
            }
        };
        // Only the spatial prefix is a legal location: placing a block at
        // or below a reduction loop would re-execute it per reduction step
        // (recompute at best, wrong values at worst).
        let mut out = Vec::new();
        for l in loops {
            match crate::tir::analysis::classify_loop(&self.prog, l) {
                crate::tir::analysis::LoopClass::Spatial
                | crate::tir::analysis::LoopClass::Unused => out.push(l),
                _ => break,
            }
        }
        out
    }

    /// Sample a compute-at location for `block`: one of its consumer's
    /// loops, or `Root` (leave standalone), or `Inlined`.
    pub fn sample_compute_location(&mut self, block: BlockRv) -> SchResult<LoopRv> {
        self.sample_compute_location_decided(block, None)
    }

    /// Decision-overridable version of [`Schedule::sample_compute_location`].
    /// Decision: `-1` root, `-2` inlined, `k >= 0` the k-th candidate loop.
    pub fn sample_compute_location_decided(
        &mut self,
        block: BlockRv,
        decision: Option<i64>,
    ) -> SchResult<LoopRv> {
        let item = self.block(block)?;
        let candidates = self.compute_location_candidates(item);
        let inlineable = self.prog.block_data(item).write_is_trivial()
            && matches!(
                self.prog.block_data(item).body,
                crate::tir::BlockBody::Assign { .. }
            );
        let d = match decision {
            Some(d) => {
                match d {
                    -1 => {}
                    -2 => {
                        if !inlineable {
                            return Err(ScheduleError::InvalidDecision(
                                "compute-location: block is not inlineable".into(),
                            ));
                        }
                    }
                    k if k >= 0 && (k as usize) < candidates.len() => {}
                    k => {
                        return Err(ScheduleError::InvalidDecision(format!(
                            "compute-location decision {k} out of support ({} candidates)",
                            candidates.len()
                        )))
                    }
                }
                d
            }
            None => {
                // Uniform over {root} ∪ {inlined if legal} ∪ candidates.
                let extra = 1 + usize::from(inlineable);
                let total = candidates.len() + extra;
                let pick = self.rng.gen_range(total);
                if pick == 0 {
                    -1
                } else if inlineable && pick == 1 {
                    -2
                } else {
                    (pick - extra) as i64
                }
            }
        };
        let r = match d {
            -1 => LoopRef::Root,
            -2 => LoopRef::Inlined,
            k => LoopRef::Item(candidates[k as usize]),
        };
        let rv = self.push_loop(r);
        self.record(Inst::SampleComputeLocation {
            block: block.0,
            out: rv.0,
            decision: d,
        });
        Ok(rv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::{dense_relu_prog, matmul_prog};
    use crate::schedule::Schedule;

    #[test]
    fn enumerate_tiles_small() {
        let tiles = enumerate_perfect_tiles(8, 2, 0);
        assert_eq!(
            *tiles,
            vec![vec![1, 8], vec![2, 4], vec![4, 2], vec![8, 1]]
        );
    }

    #[test]
    fn enumerate_tiles_respects_innermost_bound() {
        let tiles = enumerate_perfect_tiles(16, 2, 4);
        assert!(tiles.iter().all(|t| *t.last().unwrap() <= 4));
        assert!(tiles.contains(&vec![4, 4]));
        assert!(!tiles.contains(&vec![1, 16]));
    }

    #[test]
    fn enumerate_tiles_products_correct() {
        for t in enumerate_perfect_tiles(24, 3, 0).iter() {
            assert_eq!(t.iter().product::<i64>(), 24);
        }
    }

    #[test]
    fn sample_perfect_tile_draws_valid_factors() {
        let mut s = Schedule::new(matmul_prog(64, 32), 7);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        for _ in 0..10 {
            let mut s2 = s.clone();
            let rvs = s2.sample_perfect_tile(loops[0], 4, 16).unwrap();
            let fs: Vec<i64> = rvs.iter().map(|&r| s2.expr_value(r)).collect();
            assert_eq!(fs.iter().product::<i64>(), 64);
            assert!(*fs.last().unwrap() <= 16);
        }
    }

    #[test]
    fn bad_tile_decision_rejected() {
        let mut s = Schedule::new(matmul_prog(64, 32), 7);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let e = s.sample_perfect_tile_decided(loops[0], 2, 0, Some(vec![3, 21]));
        assert!(matches!(e, Err(ScheduleError::InvalidDecision(_))));
    }

    #[test]
    fn categorical_decision_out_of_range_rejected() {
        let mut s = Schedule::new(matmul_prog(64, 32), 7);
        let e = s.sample_categorical_decided(&[4, 8, 16], &[0.3, 0.3, 0.4], Some(3));
        assert!(matches!(e, Err(ScheduleError::InvalidDecision(_))));
        let ok = s
            .sample_categorical_decided(&[4, 8, 16], &[0.3, 0.3, 0.4], Some(2))
            .unwrap();
        assert_eq!(s.expr_value(ok), 16);
    }

    #[test]
    fn compute_location_candidates_are_consumer_loops() {
        let mut s = Schedule::new(dense_relu_prog(16, 8), 7);
        let dense = s.get_block("matmul").unwrap();
        let item = s.block(dense).unwrap();
        // dense's consumer is relu with 2 loops.
        assert_eq!(s.compute_location_candidates(item).len(), 2);
    }

    #[test]
    fn compute_location_inline_requires_assign_block() {
        let mut s = Schedule::new(dense_relu_prog(16, 8), 7);
        let dense = s.get_block("matmul").unwrap();
        // dense is a reduction — decision -2 (inline) must be rejected.
        let e = s.sample_compute_location_decided(dense, Some(-2));
        assert!(matches!(e, Err(ScheduleError::InvalidDecision(_))));
        // root is always fine.
        let rv = s.sample_compute_location_decided(dense, Some(-1)).unwrap();
        assert_eq!(s.loop_ref(rv), crate::schedule::LoopRef::Root);
    }
}
