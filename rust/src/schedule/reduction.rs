//! Reduction primitives: rfactor and decompose-reduction.

use crate::schedule::{BlockRv, LoopRv, SchResult, Schedule, ScheduleError};
use crate::tir::{
    AExpr, BlockBody, BlockData, Buffer, CExpr, IterKind, IterVar, LoopData, Region,
};
use crate::trace::Inst;

impl Schedule {
    /// Factorize an associative reduction along `loop_rv`: the block writes
    /// partial results to a fresh rfactor buffer indexed by that loop, and a
    /// new block reduces the partials into the original output.
    ///
    /// Enables cross-thread / parallel reductions for NRM- and SFM-style
    /// workloads where all original loops are reductions.
    pub fn rfactor(&mut self, block: BlockRv, loop_rv: LoopRv) -> SchResult<BlockRv> {
        let item = self.block(block)?;
        let loop_item = self.loop_item(loop_rv)?;
        let bd = self.prog.block_data(item).clone();
        let (init, op) = match &bd.body {
            BlockBody::Reduce { init, op, .. } => (init.clone(), *op),
            _ => return Err(ScheduleError::NotReduction(bd.name.clone())),
        };
        if !bd.write_is_trivial() {
            return Err(ScheduleError::Unsupported(
                "rfactor requires a trivial write region".into(),
            ));
        }
        let loop_var = self.prog.loop_data(loop_item).var;
        let loop_extent = self.prog.loop_data(loop_item).extent;
        // The loop must participate linearly in exactly one reduce iter's
        // binding: binding = Var(loop)*c + g(inner) with g ranging [0, c).
        // (Identity bindings are the c = 1 special case; split products like
        // `l0*32 + l1` are the general one.)
        let mut riter_idx = None;
        for (i, iv) in bd.iters.iter().enumerate() {
            if iv.binding.uses_var(loop_var) {
                if iv.kind != IterKind::Reduce || riter_idx.is_some() {
                    return Err(ScheduleError::NotReduction(format!(
                        "loop feeds a non-reduction or multiple iters of {}",
                        bd.name
                    )));
                }
                riter_idx = Some(i);
            }
        }
        let riter_idx = riter_idx.ok_or_else(|| {
            ScheduleError::NotReduction(format!(
                "loop does not bind a reduction iter of {}",
                bd.name
            ))
        })?;
        let binding = bd.iters[riter_idx].binding.clone();
        // g = binding with loop var pinned to 0; c = binding(L=1) - binding(L=0).
        let mut pin0: std::collections::HashMap<crate::tir::VarId, AExpr> =
            std::collections::HashMap::new();
        pin0.insert(loop_var, AExpr::Const(0));
        let g = binding.subst(&pin0);
        let env_ranges = self.prog.loop_var_ranges();
        let at = |lval: i64| -> i64 {
            let mut env: std::collections::HashMap<crate::tir::VarId, i64> =
                env_ranges.keys().map(|&v| (v, 0)).collect();
            env.insert(loop_var, lval);
            binding.eval(&env)
        };
        let c = at(1) - at(0);
        if c <= 0 || at(2) - at(1) != c {
            return Err(ScheduleError::Unsupported(
                "rfactor binding is not linear in the loop variable".into(),
            ));
        }
        let (g_lo, g_hi) = g.interval(&env_ranges);
        if g_lo != 0 || g_hi != c - 1 {
            return Err(ScheduleError::Unsupported(format!(
                "rfactor residual range [{g_lo},{g_hi}] does not tile stride {c}"
            )));
        }
        let out_buf = bd.writes[0].buffer;
        let spatial_extents: Vec<i64> = bd.spatial_iters().map(|iv| iv.extent).collect();
        // rfactor buffer: spatial dims + factored axis (last).
        let mut rf_shape = spatial_extents.clone();
        rf_shape.push(loop_extent);
        let rf_buf = self.prog.add_buffer(Buffer::new(
            format!("{}_rf", self.prog.buffers[out_buf].name),
            rf_shape,
            self.prog.buffers[out_buf].dtype,
        ));
        // --- Rewrite the original block: a fresh spatial iter tracks the
        // factored loop; the reduce iter shrinks to the residual range and
        // accesses compose as rfv*c + r.
        {
            let riter_var = bd.iters[riter_idx].var;
            let rfv = self.prog.fresh_var("rfx_");
            let bd_mut = self.prog.block_data_mut(item);
            bd_mut.iters[riter_idx].binding = g;
            bd_mut.iters[riter_idx].extent = c;
            bd_mut.iters.push(IterVar {
                var: rfv,
                extent: loop_extent,
                kind: IterKind::Spatial,
                binding: AExpr::Var(loop_var),
            });
            // Substitute r -> rfv*c + r in reads and body.
            let mut sub: std::collections::HashMap<crate::tir::VarId, AExpr> =
                std::collections::HashMap::new();
            sub.insert(riter_var, AExpr::Var(rfv).mul(c).add(AExpr::Var(riter_var)));
            for r in bd_mut.reads.iter_mut() {
                for (start, _) in r.ranges.iter_mut() {
                    *start = start.subst(&sub);
                }
            }
            bd_mut.body = match &bd_mut.body {
                BlockBody::Reduce { init, op, rhs } => BlockBody::Reduce {
                    init: init.subst_indices(&sub),
                    op: *op,
                    rhs: rhs.subst_indices(&sub),
                },
                other => other.clone(),
            };
            let mut idx: Vec<AExpr> = bd_mut
                .iters
                .iter()
                .filter(|iv| iv.kind == IterKind::Spatial && iv.var != rfv)
                .map(|iv| AExpr::Var(iv.var))
                .collect();
            idx.push(AExpr::Var(rfv));
            bd_mut.writes = vec![Region::point(rf_buf, idx)];
            bd_mut.name = format!("{}_rf", bd_mut.name);
        }
        // --- New final-reduction block at root level after the original nest.
        let spatial_meta: Vec<(i64,)> = spatial_extents.iter().map(|&e| (e,)).collect();
        let mut iters = Vec::new();
        let mut loops = Vec::new();
        for (d, (extent,)) in spatial_meta.iter().enumerate() {
            let lv = self.prog.fresh_var(&format!("rf{d}_"));
            let bv = self.prog.fresh_var(&format!("rfb{d}_"));
            loops.push(self.prog.alloc_loop(LoopData::new(lv, *extent)));
            iters.push(IterVar {
                var: bv,
                extent: *extent,
                kind: IterKind::Spatial,
                binding: AExpr::Var(lv),
            });
        }
        let rlv = self.prog.fresh_var("rfk_");
        let rbv = self.prog.fresh_var("rfkb_");
        loops.push(self.prog.alloc_loop(LoopData::new(rlv, loop_extent)));
        iters.push(IterVar {
            var: rbv,
            extent: loop_extent,
            kind: IterKind::Reduce,
            binding: AExpr::Var(rlv),
        });
        let spatial_idx: Vec<AExpr> = iters[..iters.len() - 1]
            .iter()
            .map(|iv| AExpr::Var(iv.var))
            .collect();
        let mut rf_idx = spatial_idx.clone();
        rf_idx.push(AExpr::Var(rbv));
        let mut blk = BlockData::new(format!("{}_final", bd.name));
        blk.reads = vec![Region {
            buffer: rf_buf,
            ranges: rf_idx.iter().map(|e| (e.clone(), 1)).collect(),
        }];
        blk.writes = vec![Region::point(out_buf, spatial_idx)];
        blk.body = BlockBody::Reduce {
            init,
            op,
            rhs: CExpr::Load(rf_buf, rf_idx),
        };
        blk.iters = iters;
        let blk_item = self.prog.alloc_block(blk);
        // Link the new nest.
        let mut parent: Option<usize> = None;
        for &l in &loops {
            if let Some(p) = parent {
                self.prog.items[l].parent = Some(p);
                self.prog.items[p].children.push(l);
            }
            parent = Some(l);
        }
        let top = loops.first().copied().unwrap_or(blk_item);
        if let Some(p) = parent {
            self.prog.items[blk_item].parent = Some(p);
            self.prog.items[p].children.push(blk_item);
        }
        let orig_root = self.prog.root_of(item);
        let pos = self
            .prog
            .roots
            .iter()
            .position(|&r| r == orig_root)
            .map(|p| p + 1)
            .unwrap_or(self.prog.roots.len());
        self.prog.roots.insert(pos, top);
        let rv = self.push_block(blk_item);
        self.record(Inst::RFactor {
            block: block.0,
            loop_rv: loop_rv.0,
            out: rv.0,
        });
        Ok(rv)
    }

    /// Hoist the reduction's init assignment into a separate block placed
    /// immediately before `loop_rv` (which must enclose the block).
    pub fn decompose_reduction(&mut self, block: BlockRv, loop_rv: LoopRv) -> SchResult<BlockRv> {
        let item = self.block(block)?;
        let loop_item = self.loop_item(loop_rv)?;
        if !crate::tir::analysis::is_ancestor(&self.prog, loop_item, item) {
            return Err(ScheduleError::InvalidComputeAt(
                "decompose-reduction loop does not enclose the block".into(),
            ));
        }
        let bd = self.prog.block_data(item).clone();
        let init = match &bd.body {
            BlockBody::Reduce { init, .. } => init.clone(),
            _ => return Err(ScheduleError::NotReduction(bd.name.clone())),
        };
        if bd.init_decomposed {
            return Err(ScheduleError::Unsupported(
                "reduction already decomposed".into(),
            ));
        }
        if !bd.write_is_trivial() {
            return Err(ScheduleError::Unsupported(
                "decompose-reduction requires a trivial write".into(),
            ));
        }
        let out_buf = bd.writes[0].buffer;
        // Init block: fresh loops over the spatial extents.
        let mut iters = Vec::new();
        let mut loops = Vec::new();
        for (d, siv) in bd.spatial_iters().enumerate() {
            let lv = self.prog.fresh_var(&format!("in{d}_"));
            let bv = self.prog.fresh_var(&format!("inb{d}_"));
            loops.push(self.prog.alloc_loop(LoopData::new(lv, siv.extent)));
            iters.push(IterVar {
                var: bv,
                extent: siv.extent,
                kind: IterKind::Spatial,
                binding: AExpr::Var(lv),
            });
        }
        let idx: Vec<AExpr> = iters.iter().map(|iv| AExpr::Var(iv.var)).collect();
        let mut blk = BlockData::new(format!("{}_init", bd.name));
        blk.writes = vec![Region::point(out_buf, idx)];
        blk.body = BlockBody::Assign { expr: init };
        blk.iters = iters;
        let blk_item = self.prog.alloc_block(blk);
        let mut parent: Option<usize> = None;
        for &l in &loops {
            if let Some(p) = parent {
                self.prog.items[l].parent = Some(p);
                self.prog.items[p].children.push(l);
            }
            parent = Some(l);
        }
        if let Some(p) = parent {
            self.prog.items[blk_item].parent = Some(p);
            self.prog.items[p].children.push(blk_item);
        }
        let top = loops.first().copied().unwrap_or(blk_item);
        // Insert before `loop_item` under its parent.
        let lparent = self.prog.items[loop_item].parent;
        let pos = match lparent {
            Some(p) => self.prog.items[p]
                .children
                .iter()
                .position(|&c| c == loop_item)
                .unwrap(),
            None => self
                .prog
                .roots
                .iter()
                .position(|&c| c == loop_item)
                .unwrap(),
        };
        self.prog.items[top].parent = lparent;
        match lparent {
            Some(p) => self.prog.items[p].children.insert(pos, top),
            None => self.prog.roots.insert(pos, top),
        }
        self.prog.block_data_mut(item).init_decomposed = true;
        let rv = self.push_block(blk_item);
        self.record(Inst::DecomposeReduction {
            block: block.0,
            loop_rv: loop_rv.0,
            out: rv.0,
        });
        Ok(rv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::matmul_prog;
    use crate::schedule::Schedule;
    use crate::tir::analysis::classify_loop;
    use crate::tir::analysis::LoopClass;

    /// s[i] = sum_j A[i,j] — a row-sum with a wide reduction.
    fn rowsum() -> crate::tir::Program {
        use crate::tir::*;
        let mut p = Program::new("rowsum");
        let a = p.param("A", vec![4, 256], DType::F32);
        let s = p.param("S", vec![4], DType::F32);
        p.emit("rowsum", &[sp("i", 4), rd("j", 256)], |iv| {
            (
                vec![Region::point(a, vec![AExpr::Var(iv[0]), AExpr::Var(iv[1])])],
                vec![Region::point(s, vec![AExpr::Var(iv[0])])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::load(a, vec![AExpr::Var(iv[0]), AExpr::Var(iv[1])]),
                },
            )
        });
        p
    }

    #[test]
    fn rfactor_splits_reduction_into_two_blocks() {
        let mut s = Schedule::new(rowsum(), 0);
        let b = s.get_block("rowsum").unwrap();
        let loops = s.get_loops(b).unwrap();
        // Split j into 8 x 32, rfactor over the outer part.
        let parts = s
            .split(loops[1], &[crate::trace::FactorArg::Lit(8), crate::trace::FactorArg::Lit(32)])
            .unwrap();
        let final_block = s.rfactor(b, parts[0]).unwrap();
        s.prog.check_integrity().unwrap();
        // Two blocks now; partial block's factored loop is spatial.
        assert_eq!(s.prog.blocks().len(), 2);
        let rf_item = s.block(b).unwrap();
        let part_loop = s.loop_item(parts[0]).unwrap();
        assert_eq!(classify_loop(&s.prog, part_loop), LoopClass::Spatial);
        // The partial block writes S_rf (shape [4, 8]).
        let rf_buf = &s.prog.buffers[s.prog.block_data(rf_item).writes[0].buffer];
        assert_eq!(rf_buf.name, "S_rf");
        assert_eq!(rf_buf.shape, vec![4, 8]);
        // Final block reduces 8 partials into S.
        let fin = s.block(final_block).unwrap();
        assert_eq!(s.prog.block_data(fin).writes[0].buffer, 1);
        let fin_loops = s.prog.loops_above(fin);
        let extents: Vec<i64> = fin_loops.iter().map(|&l| s.prog.loop_data(l).extent).collect();
        assert_eq!(extents, vec![4, 8]);
        // Now the factored loop can be parallelized.
        s.parallel(parts[0]).unwrap();
    }

    #[test]
    fn rfactor_on_non_reduction_rejected() {
        use crate::tir::*;
        let mut p = Program::new("copy");
        let a = p.param("A", vec![8], DType::F32);
        let o = p.param("O", vec![8], DType::F32);
        p.emit("copy", &[sp("i", 8)], |iv| {
            (
                vec![Region::point(a, vec![AExpr::Var(iv[0])])],
                vec![Region::point(o, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::load(a, vec![AExpr::Var(iv[0])]),
                },
            )
        });
        let mut s = Schedule::new(p, 0);
        let b = s.get_block("copy").unwrap();
        let loops = s.get_loops(b).unwrap();
        assert!(matches!(
            s.rfactor(b, loops[0]),
            Err(ScheduleError::NotReduction(_))
        ));
    }

    #[test]
    fn decompose_reduction_hoists_init() {
        let mut s = Schedule::new(matmul_prog(16, 8), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let init = s.decompose_reduction(b, loops[2]).unwrap();
        s.prog.check_integrity().unwrap();
        let init_item = s.block(init).unwrap();
        assert_eq!(s.prog.block_data(init_item).name, "matmul_init");
        let mm = s.block(b).unwrap();
        assert!(s.prog.block_data(mm).init_decomposed);
        // Init block sits before the k loop under j.
        let k_loop = s.loop_item(loops[2]).unwrap();
        let parent = s.prog.items[k_loop].parent.unwrap();
        let kids = &s.prog.items[parent].children;
        assert_eq!(kids.len(), 2);
        assert!(crate::tir::analysis::is_ancestor(&s.prog, kids[0], init_item));
        // Double decomposition is rejected.
        assert!(s.decompose_reduction(b, loops[2]).is_err());
    }
}
