//! Compute-location primitives: compute-at, reverse-compute-at,
//! compute-inline, reverse-compute-inline.
//!
//! `compute-at` moves a producer block under a loop of its consumer and
//! shrinks its loop nest to the region the consumer actually needs there
//! (computed by exact interval analysis of the consumer's read regions).
//! `compute-inline` substitutes a trivially-written Assign block's
//! expression into its consumers, eliminating the intermediate buffer.

use std::collections::HashMap;

use crate::schedule::{BlockRv, LoopRef, LoopRv, SchResult, Schedule, ScheduleError};
use crate::tir::analysis::is_ancestor;
use crate::tir::{AExpr, BlockBody, CExpr, IterKind, ItemId, LoopData, Region, VarId};
use crate::trace::Inst;

impl Schedule {
    /// Move producer `block` under `loop_rv` (a loop of its consumer),
    /// recomputing its iteration domain to cover exactly the region the
    /// consumers under that loop require per iteration.
    pub fn compute_at(&mut self, block: BlockRv, loop_rv: LoopRv) -> SchResult<()> {
        match self.loop_ref(loop_rv) {
            LoopRef::Root => {
                // Leave the block where it is; still record for replay fidelity.
                self.record(Inst::ComputeAt {
                    block: block.0,
                    loop_rv: loop_rv.0,
                });
                return Ok(());
            }
            LoopRef::Inlined => {
                let r = self.compute_inline_impl(block);
                if r.is_ok() {
                    self.record(Inst::ComputeAt {
                        block: block.0,
                        loop_rv: loop_rv.0,
                    });
                }
                return r;
            }
            LoopRef::Item(_) => {}
        }
        let loop_item = self.loop_item(loop_rv)?;
        self.compute_at_impl(block, loop_item, /*reverse=*/ false)?;
        self.record(Inst::ComputeAt {
            block: block.0,
            loop_rv: loop_rv.0,
        });
        Ok(())
    }

    /// Move consumer `block` under `loop_rv` (a loop of its producer).
    /// A `Root` sentinel location is a recorded no-op, so mutating a
    /// compute-location decision to "root" (un-fuse) stays on-support.
    pub fn reverse_compute_at(&mut self, block: BlockRv, loop_rv: LoopRv) -> SchResult<()> {
        if self.loop_ref(loop_rv) == LoopRef::Root {
            self.record(Inst::ReverseComputeAt {
                block: block.0,
                loop_rv: loop_rv.0,
            });
            return Ok(());
        }
        let loop_item = self.loop_item(loop_rv)?;
        self.compute_at_impl(block, loop_item, /*reverse=*/ true)?;
        self.record(Inst::ReverseComputeAt {
            block: block.0,
            loop_rv: loop_rv.0,
        });
        Ok(())
    }

    fn compute_at_impl(&mut self, block: BlockRv, target_loop: ItemId, reverse: bool) -> SchResult<()> {
        let item = self.block(block)?;
        if is_ancestor(&self.prog, target_loop, item) {
            return Err(ScheduleError::InvalidComputeAt(
                "target loop already encloses the block".into(),
            ));
        }
        // The target must sit in the spatial prefix of its nest: at or
        // below a reduction loop the block would re-execute per reduction
        // step (see `compute_location_candidates`).
        {
            let mut cur = Some(target_loop);
            while let Some(l) = cur {
                if self.prog.is_loop(l) {
                    match crate::tir::analysis::classify_loop(&self.prog, l) {
                        crate::tir::analysis::LoopClass::Spatial
                        | crate::tir::analysis::LoopClass::Unused => {}
                        c => {
                            return Err(ScheduleError::InvalidComputeAt(format!(
                                "target under a {c:?} loop"
                            )))
                        }
                    }
                }
                cur = self.prog.items[l].parent;
            }
        }
        let bd = self.prog.block_data(item).clone();
        if !bd.write_is_trivial() {
            return Err(ScheduleError::InvalidComputeAt(format!(
                "block {} write region is not a trivial identity",
                bd.name
            )));
        }
        let out_buf = bd.writes[0].buffer;

        // Peer blocks under the target loop that link to this block.
        let peers: Vec<ItemId> = self
            .prog
            .blocks_under(target_loop)
            .into_iter()
            .filter(|&c| {
                c != item
                    && if reverse {
                        // producer peers: write a buffer we read
                        self.prog.block_data(c).writes.iter().any(|w| {
                            bd.reads.iter().any(|r| r.buffer == w.buffer)
                        })
                    } else {
                        // consumer peers: read our output
                        self.prog
                            .block_data(c)
                            .reads
                            .iter()
                            .any(|r| r.buffer == out_buf)
                    }
            })
            .collect();
        if peers.is_empty() {
            return Err(ScheduleError::InvalidComputeAt(format!(
                "no {} of {} under the target loop",
                if reverse { "producer" } else { "consumer" },
                bd.name
            )));
        }
        // The block's own loop nest must contain only this block (exclusive
        // ownership), so detaching it cannot strand other computation.
        let own_root = self.prog.root_of(item);
        if self.prog.blocks_under(own_root).len() != 1 {
            return Err(ScheduleError::InvalidComputeAt(format!(
                "block {} shares its loop nest with other blocks",
                bd.name
            )));
        }

        // Required region of `out_buf` per one iteration of `target_loop`:
        // loops strictly inside the target sweep, everything else pinned.
        let inner_loops: Vec<ItemId> = self
            .prog
            .preorder()
            .into_iter()
            .filter(|&l| {
                self.prog.is_loop(l) && l != target_loop && is_ancestor(&self.prog, target_loop, l)
            })
            .collect();
        let sweep = crate::tir::analysis::sweep_env(&self.prog, &inner_loops);
        // Vars of inner loops, for offset computation (pin them to 0).
        let mut pin_zero: HashMap<VarId, AExpr> = HashMap::new();
        for &l in &inner_loops {
            pin_zero.insert(self.prog.loop_data(l).var, AExpr::Const(0));
        }

        // Per output-buffer dim: needed extent + symbolic offset.
        let ndim = bd.writes[0].ranges.len();
        let mut need_extent = vec![1i64; ndim];
        let mut offsets: Vec<Option<AExpr>> = vec![None; ndim];
        for &peer in &peers {
            let pd = self.prog.block_data(peer);
            // Map peer iter vars to their binding intervals under the sweep.
            let mut iter_ranges: HashMap<VarId, (i64, i64)> = HashMap::new();
            let mut iter_binding: HashMap<VarId, AExpr> = HashMap::new();
            for iv in &pd.iters {
                iter_ranges.insert(iv.var, iv.binding.interval(&sweep));
                iter_binding.insert(iv.var, iv.binding.clone());
            }
            let regions = if reverse { &pd.writes } else { &pd.reads };
            for region in regions {
                let relevant = if reverse {
                    bd.reads.iter().any(|r| r.buffer == region.buffer)
                } else {
                    region.buffer == out_buf
                };
                if !relevant || region.ranges.len() != ndim {
                    continue;
                }
                for (d, (start, extent)) in region.ranges.iter().enumerate() {
                    let width = start.width(&iter_ranges) + extent - 1;
                    need_extent[d] = need_extent[d].max(width);
                    if offsets[d].is_none() {
                        // Offset = start with iter vars replaced by their
                        // bindings, inner loop vars pinned to zero.
                        let over_loops = start.subst(&iter_binding);
                        offsets[d] = Some(over_loops.subst(&pin_zero));
                    }
                }
            }
        }

        // Detach the block's old nest entirely.
        self.prog.detach(item); // unlink block from old innermost loop
        let old_root = own_root;
        if old_root != item {
            self.prog.remove_subtree(old_root);
        }
        self.prog.items[item].alive = true; // keep the block itself alive

        // Build the new nest under target_loop.
        // Spatial iters follow the needed region; reduce iters keep full extent.
        let mut parent = target_loop;
        // Insert position: producers go before the first peer subtree,
        // consumers after the last.
        let pos = if reverse {
            self.prog.items[target_loop].children.len()
        } else {
            0
        };
        let mut first_attach_pos = Some(pos);
        let mut new_bindings: HashMap<VarId, AExpr> = HashMap::new();
        let spatial_vars: Vec<VarId> = bd.spatial_iters().map(|iv| iv.var).collect();
        for (d, &sv) in spatial_vars.iter().enumerate() {
            if d >= ndim {
                break;
            }
            let off = offsets[d].clone().unwrap_or(AExpr::Const(0));
            if need_extent[d] > 1 {
                let lv = self.prog.fresh_var("ca");
                let l = self.prog.alloc_loop(LoopData::new(lv, need_extent[d]));
                match first_attach_pos.take() {
                    Some(p) => self.prog.attach_at(l, Some(parent), p),
                    None => self.prog.attach(l, Some(parent)),
                }
                parent = l;
                new_bindings.insert(sv, off.add(AExpr::Var(lv)));
            } else {
                new_bindings.insert(sv, off);
            }
        }
        for iv in bd.iters.iter().filter(|iv| iv.kind == IterKind::Reduce) {
            let lv = self.prog.fresh_var("cr");
            let l = self.prog.alloc_loop(LoopData::new(lv, iv.extent));
            match first_attach_pos.take() {
                Some(p) => self.prog.attach_at(l, Some(parent), p),
                None => self.prog.attach(l, Some(parent)),
            }
            parent = l;
            new_bindings.insert(iv.var, AExpr::Var(lv));
        }
        // If no loops were created at all, attach the block directly.
        match first_attach_pos.take() {
            Some(p) => self.prog.attach_at(item, Some(parent), p),
            None => self.prog.attach(item, Some(parent)),
        }
        // Update bindings and (for spatial) extents.
        let bd_mut = self.prog.block_data_mut(item);
        for iv in &mut bd_mut.iters {
            if let Some(b) = new_bindings.get(&iv.var) {
                iv.binding = b.clone();
            }
            if iv.kind == IterKind::Spatial {
                if let Some(d) = spatial_vars.iter().position(|&v| v == iv.var) {
                    if d < ndim {
                        iv.extent = need_extent[d];
                    }
                }
            }
        }
        Ok(())
    }

    /// Inline a trivially-written Assign block into all its consumers,
    /// eliminating the intermediate buffer.
    pub fn compute_inline(&mut self, block: BlockRv) -> SchResult<()> {
        self.compute_inline_impl(block)?;
        self.record(Inst::ComputeInline { block: block.0 });
        Ok(())
    }

    pub(crate) fn compute_inline_impl(&mut self, block: BlockRv) -> SchResult<()> {
        let item = self.block(block)?;
        let bd = self.prog.block_data(item).clone();
        let expr = match &bd.body {
            BlockBody::Assign { expr } => expr.clone(),
            _ => {
                return Err(ScheduleError::NotInlineable(format!(
                    "block {} is not a simple assignment",
                    bd.name
                )))
            }
        };
        if !bd.write_is_trivial() {
            return Err(ScheduleError::NotInlineable(format!(
                "block {} write is not a trivial identity",
                bd.name
            )));
        }
        let out_buf = bd.writes[0].buffer;
        if self.prog.params.contains(&out_buf) {
            return Err(ScheduleError::NotInlineable(format!(
                "block {} writes a parameter buffer",
                bd.name
            )));
        }
        let consumers = self.prog.readers_of(out_buf);
        let consumers: Vec<ItemId> = consumers.into_iter().filter(|&c| c != item).collect();
        if consumers.is_empty() {
            return Err(ScheduleError::NotInlineable(format!(
                "block {} has no consumers",
                bd.name
            )));
        }
        // Exclusive loop nest required so we can delete it.
        let own_root = self.prog.root_of(item);
        if self.prog.blocks_under(own_root).len() != 1 {
            return Err(ScheduleError::NotInlineable(format!(
                "block {} shares its loop nest",
                bd.name
            )));
        }
        let spatial_vars: Vec<VarId> = bd.spatial_iters().map(|iv| iv.var).collect();
        for &c in &consumers {
            let cd = self.prog.block_data(c).clone();
            // Rewrite loads of out_buf in the consumer body.
            let new_body = match &cd.body {
                BlockBody::Assign { expr: ce } => BlockBody::Assign {
                    expr: inline_into(ce, out_buf, &spatial_vars, &expr),
                },
                BlockBody::Reduce { init, op, rhs } => BlockBody::Reduce {
                    init: inline_into(init, out_buf, &spatial_vars, &expr),
                    op: *op,
                    rhs: inline_into(rhs, out_buf, &spatial_vars, &expr),
                },
                BlockBody::Opaque { .. } => {
                    return Err(ScheduleError::NotInlineable(
                        "cannot inline into an opaque block".into(),
                    ))
                }
            };
            // Rewrite the consumer's read regions: regions on out_buf are
            // replaced by the producer's reads with indices substituted.
            let mut new_reads: Vec<Region> = Vec::new();
            for r in &cd.reads {
                if r.buffer != out_buf {
                    new_reads.push(r.clone());
                    continue;
                }
                // Substitution: producer spatial var d -> consumer index d.
                let mut map: HashMap<VarId, AExpr> = HashMap::new();
                for (d, &v) in spatial_vars.iter().enumerate() {
                    if d < r.ranges.len() {
                        map.insert(v, r.ranges[d].0.clone());
                    }
                }
                for pr in &bd.reads {
                    let ranges = pr
                        .ranges
                        .iter()
                        .map(|(s, e)| (s.subst(&map), *e))
                        .collect();
                    new_reads.push(Region {
                        buffer: pr.buffer,
                        ranges,
                    });
                }
            }
            let cd_mut = self.prog.block_data_mut(c);
            cd_mut.body = new_body;
            cd_mut.reads = new_reads;
        }
        // Remove the producer nest and tombstone the buffer.
        if own_root == item {
            self.prog.detach(item);
            self.prog.items[item].alive = false;
        } else {
            self.prog.remove_subtree(own_root);
        }
        self.prog.buffers[out_buf].inlined = true;
        // Invalidate the RV so later uses error out.
        self.blocks[block.0] = None;
        Ok(())
    }

    /// Inline an elementwise consumer block back into its only producer:
    /// the producer's body is post-composed with the consumer's expression
    /// and the producer now writes the consumer's output buffer.
    pub fn reverse_compute_inline(&mut self, block: BlockRv) -> SchResult<()> {
        let item = self.block(block)?;
        let cd = self.prog.block_data(item).clone();
        let cexpr = match &cd.body {
            BlockBody::Assign { expr } => expr.clone(),
            _ => {
                return Err(ScheduleError::NotInlineable(
                    "reverse-inline target must be a simple assignment".into(),
                ))
            }
        };
        if !cd.write_is_trivial() {
            return Err(ScheduleError::NotInlineable(
                "reverse-inline target write is not trivial".into(),
            ));
        }
        // Must read exactly one distinct buffer, produced by an Assign
        // producer with a trivial write, at identity indices.
        let read_bufs: Vec<usize> = {
            let mut b: Vec<usize> = cd.reads.iter().map(|r| r.buffer).collect();
            b.dedup();
            b.sort_unstable();
            b.dedup();
            b
        };
        if read_bufs.len() != 1 {
            return Err(ScheduleError::NotInlineable(
                "reverse-inline target must read exactly one buffer".into(),
            ));
        }
        let in_buf = read_bufs[0];
        let producers = self.prog.writers_of(in_buf);
        let producers: Vec<ItemId> = producers.into_iter().filter(|&p| p != item).collect();
        if producers.len() != 1 {
            return Err(ScheduleError::NotInlineable(
                "reverse-inline requires exactly one producer".into(),
            ));
        }
        let prod = producers[0];
        let pd = self.prog.block_data(prod).clone();
        if !pd.write_is_trivial() {
            return Err(ScheduleError::NotInlineable(
                "producer write is not trivial".into(),
            ));
        }
        // Consumer reads must be identity over its spatial iters, matching
        // producer dims one-to-one.
        let c_spatial: Vec<VarId> = cd.spatial_iters().map(|iv| iv.var).collect();
        for r in cd.reads.iter().filter(|r| r.buffer == in_buf) {
            if r.ranges.len() != c_spatial.len() {
                return Err(ScheduleError::NotInlineable(
                    "reverse-inline read arity mismatch".into(),
                ));
            }
            for (d, (s, e)) in r.ranges.iter().enumerate() {
                if *e != 1 || *s != AExpr::Var(c_spatial[d]) {
                    return Err(ScheduleError::NotInlineable(
                        "reverse-inline read is not identity".into(),
                    ));
                }
            }
        }
        // Exclusive nest for the consumer.
        let own_root = self.prog.root_of(item);
        if self.prog.blocks_under(own_root).len() != 1 {
            return Err(ScheduleError::NotInlineable(
                "reverse-inline target shares its loop nest".into(),
            ));
        }
        let out_buf = cd.writes[0].buffer;
        let p_spatial: Vec<VarId> = pd.spatial_iters().map(|iv| iv.var).collect();
        // Map consumer spatial var d -> producer spatial var d.
        let mut map: HashMap<VarId, AExpr> = HashMap::new();
        for (cv, pv) in c_spatial.iter().zip(&p_spatial) {
            map.insert(*cv, AExpr::Var(*pv));
        }
        let composed = |inner_value: &CExpr| -> CExpr {
            cexpr.subst_indices(&map).map_loads(&mut |b, idx| {
                if b == in_buf {
                    inner_value.clone()
                } else {
                    CExpr::Load(b, idx.to_vec())
                }
            })
        };
        let new_body = match &pd.body {
            BlockBody::Assign { expr } => BlockBody::Assign {
                expr: composed(expr),
            },
            BlockBody::Reduce { .. } => {
                return Err(ScheduleError::NotInlineable(
                    "cannot reverse-inline into a reduction (use compute-at)".into(),
                ))
            }
            BlockBody::Opaque { .. } => {
                return Err(ScheduleError::NotInlineable(
                    "cannot reverse-inline into an opaque block".into(),
                ))
            }
        };
        {
            let pd_mut = self.prog.block_data_mut(prod);
            pd_mut.body = new_body;
            pd_mut.writes = vec![Region::point(
                out_buf,
                p_spatial.iter().map(|&v| AExpr::Var(v)).collect(),
            )];
        }
        if own_root == item {
            self.prog.detach(item);
            self.prog.items[item].alive = false;
        } else {
            self.prog.remove_subtree(own_root);
        }
        self.prog.buffers[in_buf].inlined = true;
        self.blocks[block.0] = None;
        self.record(Inst::ReverseComputeInline { block: block.0 });
        Ok(())
    }
}

/// Replace `Load(buf, idx)` in `e` with `producer_expr[spatial -> idx]`.
fn inline_into(e: &CExpr, buf: usize, spatial: &[VarId], producer_expr: &CExpr) -> CExpr {
    e.map_loads(&mut |b, idx| {
        if b == buf {
            let mut map: HashMap<VarId, AExpr> = HashMap::new();
            for (d, &v) in spatial.iter().enumerate() {
                if d < idx.len() {
                    map.insert(v, idx[d].clone());
                }
            }
            producer_expr.subst_indices(&map)
        } else {
            CExpr::Load(b, idx.to_vec())
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::testutil::dense_relu_prog;
    use crate::schedule::Schedule;
    use crate::tir::analysis::program_flops;
    use crate::trace::FactorArg;

    #[test]
    fn reverse_compute_at_moves_relu_under_dense_loop() {
        let mut s = Schedule::new(dense_relu_prog(16, 8), 0);
        let dense = s.get_block("matmul").unwrap();
        let relu = s.get_block("relu").unwrap();
        let loops = s.get_loops(dense).unwrap();
        // Move relu under dense's i loop: relu should get a 16-extent j loop.
        s.reverse_compute_at(relu, loops[0]).unwrap();
        s.prog.check_integrity().unwrap();
        let relu_item = s.block(relu).unwrap();
        let above = s.prog.loops_above(relu_item);
        assert_eq!(above[0], s.loop_item(loops[0]).unwrap());
        // i is fixed by the outer loop: only j (16) remains.
        let extents: Vec<i64> = above[1..]
            .iter()
            .map(|&l| s.prog.loop_data(l).extent)
            .collect();
        assert_eq!(extents, vec![16]);
        // Flops preserved (relu executes 16*16 times total still).
        assert_eq!(program_flops(&s.prog), 16.0 * 16.0 * 8.0 * 2.0 + 16.0 * 16.0);
    }

    #[test]
    fn compute_at_after_split_covers_tile_region() {
        // Split relu's loops and compute dense at an outer tile loop.
        let mut s = Schedule::new(dense_relu_prog(16, 8), 0);
        let dense = s.get_block("matmul").unwrap();
        let relu = s.get_block("relu").unwrap();
        let rloops = s.get_loops(relu).unwrap();
        let ri = s
            .split(rloops[0], &[FactorArg::Lit(4), FactorArg::Lit(4)])
            .unwrap();
        // compute dense at the outer i tile (extent 4): dense must cover a
        // 4x16 tile of C plus the full k reduction.
        s.compute_at(dense, ri[0]).unwrap();
        s.prog.check_integrity().unwrap();
        let d_item = s.block(dense).unwrap();
        let above = s.prog.loops_above(d_item);
        // outer = the ri[0] loop; then i-tile 4, j 16, k 8.
        let extents: Vec<i64> = above.iter().map(|&l| s.prog.loop_data(l).extent).collect();
        assert_eq!(extents, vec![4, 4, 16, 8]);
        // dense comes before relu's inner loops under ri[0].
        let kids = &s.prog.items[s.loop_item(ri[0]).unwrap()].children;
        assert_eq!(kids.len(), 2);
        assert_eq!(program_flops(&s.prog), 16.0 * 16.0 * 8.0 * 2.0 + 16.0 * 16.0);
    }

    #[test]
    fn compute_inline_merges_elementwise_chain() {
        // Build add -> relu chain and inline add into relu.
        let mut p = crate::tir::Program::new("chain");
        let a = p.param("A", vec![32], crate::tir::DType::F32);
        let t = p.temp("T", vec![32], crate::tir::DType::F32);
        let o = p.param("O", vec![32], crate::tir::DType::F32);
        use crate::tir::*;
        p.emit("add1", &[sp("i", 32)], |iv| {
            (
                vec![Region::point(a, vec![AExpr::Var(iv[0])])],
                vec![Region::point(t, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::bin(
                        BinOp::Add,
                        CExpr::load(a, vec![AExpr::Var(iv[0])]),
                        CExpr::ConstF(1.0),
                    ),
                },
            )
        });
        p.emit("relu", &[sp("i", 32)], |iv| {
            (
                vec![Region::point(t, vec![AExpr::Var(iv[0])])],
                vec![Region::point(o, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::un(UnOp::Relu, CExpr::load(t, vec![AExpr::Var(iv[0])])),
                },
            )
        });
        let mut s = Schedule::new(p, 0);
        let add = s.get_block("add1").unwrap();
        s.compute_inline(add).unwrap();
        s.prog.check_integrity().unwrap();
        // Only relu remains; it reads A directly; T is gone.
        assert_eq!(s.prog.blocks().len(), 1);
        let relu = s.prog.find_block("relu").unwrap();
        assert_eq!(s.prog.block_data(relu).reads[0].buffer, a);
        assert!(s.prog.buffers[t].inlined);
        // relu body now computes relu(A[i] + 1).
        assert_eq!(program_flops(&s.prog), 32.0 * 2.0);
        // The inlined block's RV is dead.
        assert!(s.compute_inline(add).is_err());
    }

    #[test]
    fn reverse_compute_inline_fuses_epilogue() {
        // add -> relu; reverse-inline relu into add.
        let mut p = crate::tir::Program::new("chain");
        use crate::tir::*;
        let a = p.param("A", vec![32], DType::F32);
        let t = p.temp("T", vec![32], DType::F32);
        let o = p.param("O", vec![32], DType::F32);
        p.emit("add1", &[sp("i", 32)], |iv| {
            (
                vec![Region::point(a, vec![AExpr::Var(iv[0])])],
                vec![Region::point(t, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::bin(
                        BinOp::Add,
                        CExpr::load(a, vec![AExpr::Var(iv[0])]),
                        CExpr::ConstF(1.0),
                    ),
                },
            )
        });
        p.emit("relu", &[sp("i", 32)], |iv| {
            (
                vec![Region::point(t, vec![AExpr::Var(iv[0])])],
                vec![Region::point(o, vec![AExpr::Var(iv[0])])],
                BlockBody::Assign {
                    expr: CExpr::un(UnOp::Relu, CExpr::load(t, vec![AExpr::Var(iv[0])])),
                },
            )
        });
        let mut s = Schedule::new(p, 0);
        let relu = s.get_block("relu").unwrap();
        s.reverse_compute_inline(relu).unwrap();
        s.prog.check_integrity().unwrap();
        assert_eq!(s.prog.blocks().len(), 1);
        let add = s.prog.find_block("add1").unwrap();
        // add now writes O directly.
        assert_eq!(s.prog.block_data(add).writes[0].buffer, o);
        assert!(s.prog.buffers[t].inlined);
    }

    #[test]
    fn reverse_inline_into_reduction_rejected() {
        let mut s = Schedule::new(dense_relu_prog(16, 8), 0);
        let relu = s.get_block("relu").unwrap();
        // relu's producer (matmul) is a reduction: must be rejected.
        assert!(matches!(
            s.reverse_compute_inline(relu),
            Err(ScheduleError::NotInlineable(_))
        ));
    }

    #[test]
    fn inline_of_reduction_rejected() {
        let mut s = Schedule::new(dense_relu_prog(16, 8), 0);
        let dense = s.get_block("matmul").unwrap();
        assert!(matches!(
            s.compute_inline(dense),
            Err(ScheduleError::NotInlineable(_))
        ));
    }
}
