//! # MetaSchedule — tensor program optimization with probabilistic programs
//!
//! A from-scratch reproduction of the NeurIPS 2022 paper (Shao et al.) as a
//! three-layer Rust + JAX + Pallas stack. The Rust layer implements the
//! whole system: a TensorIR-style program representation ([`tir`]),
//! stochastic schedule primitives ([`schedule`]), execution traces
//! ([`trace`]), composable schedule rules ([`space`]) resolved from a
//! named rule registry into a pluggable tuning context ([`ctx`]), the
//! learning-driven evolutionary search with a gradient-boosted-tree cost
//! model ([`search`], [`cost_model`]), a persistent tuning-record
//! database that warm-starts search and pretrains the cost model across
//! sessions ([`db`]), cross-target transfer priors that re-use another
//! target's records as re-measured seeds and discounted cost-model
//! samples ([`transfer`]), a read-optimized serving layer with compaction
//! and indexed snapshots over that database ([`serve`]), a deterministic
//! hardware latency
//! simulator standing in for the paper's testbeds ([`sim`]), baseline
//! tuners ([`baselines`]), graph-level task extraction and end-to-end model
//! tuning ([`graph`]), the Appendix A.2 workload suite ([`workloads`]), a
//! PJRT runtime for real-hardware measurement of AOT-compiled Pallas
//! kernels ([`runtime`]), the experiment harness that regenerates every
//! figure and table of the paper's evaluation ([`exp`]), and a zero-dep
//! observability layer — metrics registry, Chrome-trace spans, Prometheus
//! `/metrics` — threaded through search, db, and serving ([`telemetry`]).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for results.
//!
//! Lint policy lives in `Cargo.toml` (`[lints]`): correctness and perf
//! clippy lints are hard errors in CI; a small set of style lints is
//! allowed where the codebase deliberately deviates (explicit index loops
//! in kernel-adjacent math code, many-argument internal plumbing).

pub mod baselines;
pub mod cost_model;
pub mod ctx;
pub mod db;
pub mod exp;
pub mod graph;
pub mod runtime;
pub mod schedule;
pub mod search;
pub mod serve;
pub mod sim;
pub mod space;
pub mod telemetry;
pub mod tir;
pub mod trace;
pub mod transfer;
pub mod util;
pub mod workloads;
