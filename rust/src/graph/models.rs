//! Model zoo: the end-to-end networks of Figures 9/10b and Table 1, built
//! from the parameterized operator builders at their standard shapes
//! (batch = 1, as in the paper's evaluation).

use crate::tir::Program;
use crate::workloads::{
    add2d, conv2d, dense, depthwise_conv2d, fused_dense, matmul, norm, softmax,
    transpose_batch_matmul, Conv2dParams,
};

/// An operator occurrence in a model: the program plus its repeat count.
pub type OpList = Vec<(Program, usize)>;

fn c2d(h: i64, ci: i64, co: i64, k: i64, s: i64) -> Program {
    conv2d(Conv2dParams::new(1, h, h, ci, co, k, s, k / 2))
}

/// ResNet-50 (He et al.): stem + 4 bottleneck stages [3,4,6,3] + head.
pub fn resnet50() -> OpList {
    let mut ops: OpList = Vec::new();
    ops.push((c2d(224, 3, 64, 7, 2), 1)); // stem
    let stages: [(i64, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    let mut h = 56i64;
    let mut in_c = 64i64;
    for (si, &(w, blocks)) in stages.iter().enumerate() {
        let out_c = w * 4;
        let stride = if si == 0 { 1 } else { 2 };
        // First block (with projection shortcut + optional stride).
        ops.push((c2d(h, in_c, w, 1, 1), 1));
        ops.push((c2d(h, w, w, 3, stride), 1));
        h /= stride;
        ops.push((c2d(h, w, out_c, 1, 1), 1));
        ops.push((c2d(h * stride, in_c, out_c, 1, stride), 1)); // projection
        ops.push((add2d(out_c, h * h), 1));
        // Remaining identity blocks.
        let rest = blocks - 1;
        if rest > 0 {
            ops.push((c2d(h, out_c, w, 1, 1), rest));
            ops.push((c2d(h, w, w, 3, 1), rest));
            ops.push((c2d(h, w, out_c, 1, 1), rest));
            ops.push((add2d(out_c, h * h), rest));
        }
        in_c = out_c;
    }
    ops.push((dense(1, 1000, 2048), 1)); // classifier
    ops
}

/// MobileNet-v2 (Sandler et al.): stem + 17 inverted residual blocks + head.
pub fn mobilenet_v2() -> OpList {
    let mut ops: OpList = Vec::new();
    ops.push((c2d(224, 3, 32, 3, 2), 1)); // stem, 112x112x32
    // (expansion t, out channels c, repeats n, stride s)
    let cfg: [(i64, i64, usize, i64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut h = 112i64;
    let mut in_c = 32i64;
    for &(t, c, n, s) in &cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let exp = in_c * t;
            if t > 1 {
                ops.push((c2d(h, in_c, exp, 1, 1), 1)); // expand
            }
            ops.push((depthwise_conv2d(1, h, h, exp, 3, stride, 1), 1));
            let oh = h / stride;
            ops.push((c2d(oh, exp, c, 1, 1), 1)); // project
            if stride == 1 && in_c == c {
                ops.push((add2d(c, oh * oh), 1));
            }
            h = oh;
            in_c = c;
        }
    }
    ops.push((c2d(7, 320, 1280, 1, 1), 1));
    ops.push((dense(1, 1000, 1280), 1));
    ops
}

/// One transformer encoder layer's operators.
fn transformer_layer(seq: i64, hidden: i64, heads: i64, ffn: i64) -> OpList {
    let dim = hidden / heads;
    vec![
        (dense(seq, hidden, hidden), 3),                      // Q, K, V
        (transpose_batch_matmul(seq, heads, dim), 1),         // scores
        (softmax(1, heads * seq, seq), 1),                    // attention probs
        (matmul(heads, seq, dim, seq), 1),                    // probs @ V
        (dense(seq, hidden, hidden), 1),                      // output proj
        (add2d(seq, hidden), 2),                              // residuals
        (norm(1, seq, hidden), 2),                            // layernorms
        (fused_dense(seq, ffn, hidden), 1),                   // FFN up + act
        (dense(seq, hidden, ffn), 1),                         // FFN down
    ]
}

fn repeat_layers(layer: OpList, n: usize) -> OpList {
    layer.into_iter().map(|(p, c)| (p, c * n)).collect()
}

/// BERT-base: 12 layers, hidden 768, 12 heads, FFN 3072, seq 128.
pub fn bert_base() -> OpList {
    repeat_layers(transformer_layer(128, 768, 12, 3072), 12)
}

/// BERT-large: 24 layers, hidden 1024, 16 heads, FFN 4096, seq 128
/// (the Figure 10b workload).
pub fn bert_large() -> OpList {
    repeat_layers(transformer_layer(128, 1024, 16, 4096), 24)
}

/// GPT-2 (117M): 12 layers, hidden 768, 12 heads, FFN 3072, seq 128.
/// Structurally the BERT-base decoder twin at this granularity.
pub fn gpt2() -> OpList {
    repeat_layers(transformer_layer(128, 768, 12, 3072), 12)
}

/// Inception-v1 (GoogLeNet): stem plus representative inception-branch
/// convolutions with their occurrence counts across the 9 modules.
pub fn inception_v1() -> OpList {
    vec![
        (c2d(224, 3, 64, 7, 2), 1),
        (c2d(56, 64, 64, 1, 1), 1),
        (c2d(56, 64, 192, 3, 1), 1),
        // 28x28 modules (3a, 3b)
        (c2d(28, 192, 64, 1, 1), 2),
        (c2d(28, 96, 128, 3, 1), 2),
        (c2d(28, 16, 32, 5, 1), 2),
        (c2d(28, 192, 96, 1, 1), 2),
        // 14x14 modules (4a-4e)
        (c2d(14, 480, 192, 1, 1), 5),
        (c2d(14, 96, 208, 3, 1), 5),
        (c2d(14, 16, 48, 5, 1), 5),
        (c2d(14, 480, 96, 1, 1), 5),
        // 7x7 modules (5a, 5b)
        (c2d(7, 832, 256, 1, 1), 2),
        (c2d(7, 160, 320, 3, 1), 2),
        (c2d(7, 32, 128, 5, 1), 2),
        (dense(1, 1000, 1024), 1),
    ]
}

/// Look a model up by name.
pub fn by_name(name: &str) -> Option<OpList> {
    match name.to_lowercase().as_str() {
        "resnet50" | "resnet-50" => Some(resnet50()),
        "mobilenetv2" | "mobilenet-v2" => Some(mobilenet_v2()),
        "bert-base" | "bert_base" => Some(bert_base()),
        "bert-large" | "bert_large" => Some(bert_large()),
        "gpt2" | "gpt-2" => Some(gpt2()),
        "inception-v1" | "inceptionv1" => Some(inception_v1()),
        _ => None,
    }
}

/// All model names used by the experiments.
pub const MODEL_NAMES: [&str; 6] = [
    "resnet50",
    "mobilenet-v2",
    "bert-base",
    "bert-large",
    "gpt2",
    "inception-v1",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;

    fn total_flops(ops: &OpList) -> f64 {
        ops.iter()
            .map(|(p, c)| program_flops(p) * *c as f64)
            .sum()
    }

    #[test]
    fn resnet50_flops_in_expected_range() {
        // ResNet-50 is ~3.8 GFLOPs (multiply+add) at 224x224.
        let f = total_flops(&resnet50());
        assert!(f > 6e9 && f < 9.5e9, "{f}"); // conv-only approximation
    }

    #[test]
    fn mobilenet_flops_much_smaller_than_resnet() {
        let m = total_flops(&mobilenet_v2());
        let r = total_flops(&resnet50());
        assert!(m < r / 8.0, "mobilenet {m} vs resnet {r}");
        assert!(m > 4e8, "{m}"); // ~0.3 GMACs => ~0.6 GFLOPs
    }

    #[test]
    fn bert_base_flops_match_formula() {
        // ~= 12 layers * (4 * s * h^2 + 2 * s^2 * h + 2 * s * h * ffn) * 2
        let f = total_flops(&bert_base());
        let s = 128.0f64;
        let h = 768.0;
        let ffn = 3072.0;
        let expect = 12.0 * 2.0 * (4.0 * s * h * h + 2.0 * s * s * h + 2.0 * s * h * ffn);
        assert!((f / expect - 1.0).abs() < 0.1, "{f} vs {expect}");
    }

    #[test]
    fn bert_large_heavier_than_base() {
        assert!(total_flops(&bert_large()) > 2.5 * total_flops(&bert_base()));
    }

    #[test]
    fn all_models_build_and_verify() {
        for name in MODEL_NAMES {
            let ops = by_name(name).unwrap();
            assert!(!ops.is_empty());
            for (p, c) in &ops {
                p.check_integrity().unwrap();
                assert!(*c >= 1);
            }
        }
    }
}
