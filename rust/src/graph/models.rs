//! Model zoo: the end-to-end networks of Figures 9/10b and Table 1, built
//! from the parameterized operator builders at their standard shapes
//! (batch = 1, as in the paper's evaluation).
//!
//! Every model is constructed as an [`OpGraph`] (`*_graph()` builders)
//! with real producer → consumer edges; the flat `OpList` entry points
//! are lossless projections of those graphs, so pre-graph callers see
//! exactly the same operators and counts while the fusion pass gets the
//! dataflow.

use crate::graph::OpGraph;
use crate::tir::Program;
use crate::workloads::{
    add2d, add4d, conv2d, dense, depthwise_conv2d, fused_dense, matmul, norm, softmax,
    transpose_batch_matmul, Conv2dParams,
};

/// An operator occurrence in a model: the program plus its repeat count.
pub type OpList = Vec<(Program, usize)>;

fn c2d(h: i64, ci: i64, co: i64, k: i64, s: i64) -> Program {
    conv2d(Conv2dParams::new(1, h, h, ci, co, k, s, k / 2))
}

/// ResNet-50 (He et al.) as an operator DAG: stem + 4 bottleneck stages
/// [3,4,6,3] + head. Residual adds are NCHW ([`add4d`]) so they bind to
/// the conv outputs that feed them.
pub fn resnet50_graph() -> OpGraph {
    let mut g = OpGraph::new();
    let mut prev = g.add(c2d(224, 3, 64, 7, 2), 1); // stem
    let stages: [(i64, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    let mut h = 56i64;
    let mut in_c = 64i64;
    for (si, &(w, blocks)) in stages.iter().enumerate() {
        let out_c = w * 4;
        let stride = if si == 0 { 1 } else { 2 };
        // First block (with projection shortcut + optional stride).
        let c1 = g.add(c2d(h, in_c, w, 1, 1), 1);
        g.connect(prev, c1);
        let c2 = g.add(c2d(h, w, w, 3, stride), 1);
        g.connect(c1, c2);
        h /= stride;
        let c3 = g.add(c2d(h, w, out_c, 1, 1), 1);
        g.connect(c2, c3);
        let proj = g.add(c2d(h * stride, in_c, out_c, 1, stride), 1);
        g.connect(prev, proj);
        let add = g.add(add4d(out_c, h), 1);
        g.connect(c3, add);
        g.connect(proj, add);
        prev = add;
        // Remaining identity blocks (count-collapsed).
        let rest = blocks - 1;
        if rest > 0 {
            let c1r = g.add(c2d(h, out_c, w, 1, 1), rest);
            g.connect(prev, c1r);
            let c2r = g.add(c2d(h, w, w, 3, 1), rest);
            g.connect(c1r, c2r);
            let c3r = g.add(c2d(h, w, out_c, 1, 1), rest);
            g.connect(c2r, c3r);
            let addr = g.add(add4d(out_c, h), rest);
            g.connect(c3r, addr);
            g.connect(prev, addr); // residual shortcut
            prev = addr;
        }
        in_c = out_c;
    }
    let head = g.add(dense(1, 1000, 2048), 1); // classifier
    g.connect(prev, head);
    g
}

/// ResNet-50 as a flat operator list (projection of [`resnet50_graph`]).
pub fn resnet50() -> OpList {
    resnet50_graph().ops()
}

/// MobileNet-v2 (Sandler et al.) as an operator DAG: stem + 17 inverted
/// residual blocks + head.
pub fn mobilenet_v2_graph() -> OpGraph {
    let mut g = OpGraph::new();
    let mut prev = g.add(c2d(224, 3, 32, 3, 2), 1); // stem, 112x112x32
    // (expansion t, out channels c, repeats n, stride s)
    let cfg: [(i64, i64, usize, i64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut h = 112i64;
    let mut in_c = 32i64;
    for &(t, c, n, s) in &cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let exp = in_c * t;
            let block_in = prev;
            let mut last = prev;
            if t > 1 {
                let e = g.add(c2d(h, in_c, exp, 1, 1), 1); // expand
                g.connect(last, e);
                last = e;
            }
            let dw = g.add(depthwise_conv2d(1, h, h, exp, 3, stride, 1), 1);
            g.connect(last, dw);
            let oh = h / stride;
            let pr = g.add(c2d(oh, exp, c, 1, 1), 1); // project
            g.connect(dw, pr);
            prev = pr;
            if stride == 1 && in_c == c {
                let add = g.add(add4d(c, oh), 1);
                g.connect(pr, add);
                g.connect(block_in, add); // residual shortcut
                prev = add;
            }
            h = oh;
            in_c = c;
        }
    }
    let tail = g.add(c2d(7, 320, 1280, 1, 1), 1);
    g.connect(prev, tail);
    let head = g.add(dense(1, 1000, 1280), 1);
    g.connect(tail, head);
    g
}

/// MobileNet-v2 as a flat operator list.
pub fn mobilenet_v2() -> OpList {
    mobilenet_v2_graph().ops()
}

/// A stack of transformer encoder layers as an operator DAG. The layer is
/// count-collapsed: each node carries `layers` (× its per-layer
/// multiplicity) as its repeat count, and edges follow the in-layer
/// dataflow QKV → scores → softmax → PV → proj → add → norm → FFN →
/// add → norm.
fn transformer_graph(seq: i64, hidden: i64, heads: i64, ffn: i64, layers: usize) -> OpGraph {
    let dim = hidden / heads;
    let n = layers;
    let mut g = OpGraph::new();
    let qkv = g.add(dense(seq, hidden, hidden), 3 * n); // Q, K, V
    let tbg = g.add(transpose_batch_matmul(seq, heads, dim), n); // scores
    let sfm = g.add(softmax(1, heads * seq, seq), n); // attention probs
    let pv = g.add(matmul(heads, seq, dim, seq), n); // probs @ V
    let proj = g.add(dense(seq, hidden, hidden), n); // output proj
    let add1 = g.add(add2d(seq, hidden), n); // attention residual
    let norm1 = g.add(norm(1, seq, hidden), n);
    let ffn_up = g.add(fused_dense(seq, ffn, hidden), n); // FFN up + act
    let ffn_down = g.add(dense(seq, hidden, ffn), n); // FFN down
    let add2 = g.add(add2d(seq, hidden), n); // FFN residual
    let norm2 = g.add(norm(1, seq, hidden), n);
    for (p, c) in [
        (qkv, tbg),
        (tbg, sfm),
        (sfm, pv),
        (pv, proj),
        (proj, add1),
        (add1, norm1),
        (norm1, ffn_up),
        (ffn_up, ffn_down),
        (ffn_down, add2),
        (add2, norm2),
    ] {
        g.connect(p, c);
    }
    g
}

/// BERT-base: 12 layers, hidden 768, 12 heads, FFN 3072, seq 128.
pub fn bert_base_graph() -> OpGraph {
    transformer_graph(128, 768, 12, 3072, 12)
}

/// BERT-base as a flat operator list.
pub fn bert_base() -> OpList {
    bert_base_graph().ops()
}

/// BERT-large: 24 layers, hidden 1024, 16 heads, FFN 4096, seq 128
/// (the Figure 10b workload).
pub fn bert_large_graph() -> OpGraph {
    transformer_graph(128, 1024, 16, 4096, 24)
}

/// BERT-large as a flat operator list.
pub fn bert_large() -> OpList {
    bert_large_graph().ops()
}

/// GPT-2 (117M): 12 layers, hidden 768, 12 heads, FFN 3072, seq 128.
/// Structurally the BERT-base decoder twin at this granularity.
pub fn gpt2_graph() -> OpGraph {
    transformer_graph(128, 768, 12, 3072, 12)
}

/// GPT-2 as a flat operator list.
pub fn gpt2() -> OpList {
    gpt2_graph().ops()
}

/// Inception-v1 (GoogLeNet): stem plus representative inception-branch
/// convolutions with their occurrence counts across the 9 modules. The
/// branch structure is not modeled (counts are aggregated across
/// modules), so the graph is edge-free and fusion treats every op as its
/// own group.
pub fn inception_v1_graph() -> OpGraph {
    OpGraph::from_ops(&inception_v1())
}

/// Inception-v1 as a flat operator list.
pub fn inception_v1() -> OpList {
    vec![
        (c2d(224, 3, 64, 7, 2), 1),
        (c2d(56, 64, 64, 1, 1), 1),
        (c2d(56, 64, 192, 3, 1), 1),
        // 28x28 modules (3a, 3b)
        (c2d(28, 192, 64, 1, 1), 2),
        (c2d(28, 96, 128, 3, 1), 2),
        (c2d(28, 16, 32, 5, 1), 2),
        (c2d(28, 192, 96, 1, 1), 2),
        // 14x14 modules (4a-4e)
        (c2d(14, 480, 192, 1, 1), 5),
        (c2d(14, 96, 208, 3, 1), 5),
        (c2d(14, 16, 48, 5, 1), 5),
        (c2d(14, 480, 96, 1, 1), 5),
        // 7x7 modules (5a, 5b)
        (c2d(7, 832, 256, 1, 1), 2),
        (c2d(7, 160, 320, 3, 1), 2),
        (c2d(7, 32, 128, 5, 1), 2),
        (dense(1, 1000, 1024), 1),
    ]
}

/// Look a model up by name (flat operator-list view).
pub fn by_name(name: &str) -> Option<OpList> {
    graph_by_name(name).map(|g| g.ops())
}

/// Look a model up by name as an operator DAG. Uses the same
/// canonicalization as [`crate::workloads::by_name`] (case-insensitive,
/// `_` == `-`) so the two resolvers form one namespace.
pub fn graph_by_name(name: &str) -> Option<OpGraph> {
    match crate::workloads::canon_name(name).as_str() {
        "resnet50" | "resnet-50" => Some(resnet50_graph()),
        "mobilenetv2" | "mobilenet-v2" => Some(mobilenet_v2_graph()),
        "bert-base" => Some(bert_base_graph()),
        "bert-large" => Some(bert_large_graph()),
        "gpt2" | "gpt-2" => Some(gpt2_graph()),
        "inception-v1" | "inceptionv1" => Some(inception_v1_graph()),
        _ => None,
    }
}

/// All model names used by the experiments.
pub const MODEL_NAMES: [&str; 6] = [
    "resnet50",
    "mobilenet-v2",
    "bert-base",
    "bert-large",
    "gpt2",
    "inception-v1",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;

    fn total_flops(ops: &OpList) -> f64 {
        ops.iter()
            .map(|(p, c)| program_flops(p) * *c as f64)
            .sum()
    }

    #[test]
    fn resnet50_flops_in_expected_range() {
        // ResNet-50 is ~3.8 GFLOPs (multiply+add) at 224x224.
        let f = total_flops(&resnet50());
        assert!(f > 6e9 && f < 9.5e9, "{f}"); // conv-only approximation
    }

    #[test]
    fn mobilenet_flops_much_smaller_than_resnet() {
        let m = total_flops(&mobilenet_v2());
        let r = total_flops(&resnet50());
        assert!(m < r / 8.0, "mobilenet {m} vs resnet {r}");
        assert!(m > 4e8, "{m}"); // ~0.3 GMACs => ~0.6 GFLOPs
    }

    #[test]
    fn bert_base_flops_match_formula() {
        // ~= 12 layers * (4 * s * h^2 + 2 * s^2 * h + 2 * s * h * ffn) * 2
        let f = total_flops(&bert_base());
        let s = 128.0f64;
        let h = 768.0;
        let ffn = 3072.0;
        let expect = 12.0 * 2.0 * (4.0 * s * h * h + 2.0 * s * s * h + 2.0 * s * h * ffn);
        assert!((f / expect - 1.0).abs() < 0.1, "{f} vs {expect}");
    }

    #[test]
    fn bert_large_heavier_than_base() {
        assert!(total_flops(&bert_large()) > 2.5 * total_flops(&bert_base()));
    }

    #[test]
    fn all_models_build_and_verify() {
        for name in MODEL_NAMES {
            let ops = by_name(name).unwrap();
            assert!(!ops.is_empty());
            for (p, c) in &ops {
                p.check_integrity().unwrap();
                assert!(*c >= 1);
            }
        }
    }

    #[test]
    fn graphs_project_losslessly_and_have_edges() {
        for name in MODEL_NAMES {
            let g = graph_by_name(name).unwrap();
            let ops = by_name(name).unwrap();
            assert_eq!(g.len(), ops.len(), "{name}");
            let gw: usize = g.nodes().iter().map(|n| n.count).sum();
            let ow: usize = ops.iter().map(|(_, c)| c).sum();
            assert_eq!(gw, ow, "{name}");
        }
        // The CNN and transformer graphs carry real dataflow.
        for name in ["resnet50", "mobilenet-v2", "bert-base"] {
            let g = graph_by_name(name).unwrap();
            let edges: usize = (0..g.len()).map(|i| g.consumers(i).len()).sum();
            assert!(edges >= g.len() - 1, "{name}: {edges} edges");
        }
    }

    #[test]
    fn residual_adds_bind_to_conv_outputs() {
        // The resnet graph must use NCHW adds so conv -> add fuses.
        let g = resnet50_graph();
        let found = g
            .nodes()
            .iter()
            .any(|n| n.prog.name == "add4d" && n.prog.buffers[0].shape.len() == 4);
        assert!(found);
        assert!(g.nodes().iter().all(|n| n.prog.name != "add2d" || n.prog.buffers[0].shape.len() == 2));
    }
}
