//! Deterministic operator fusion over an [`OpGraph`] (the TVM four-class
//! rules, adapted to linear chains):
//!
//! 1. reductions absorb their single-consumer injective *producer*
//!    chains (add → layernorm);
//! 2. complex-out-fusable anchors absorb injective *consumer* chains —
//!    elementwise epilogues (conv → residual-add, dense → bias → relu);
//! 3. remaining adjacent injective pairs fuse;
//! 4. opaque nodes never merge on either side.
//!
//! A merge additionally requires equal repeat counts and a shape-exact
//! buffer binding between the adjacent programs, so every fused group is
//! a linear chain that re-emits as one valid `Program`
//! ([`fuse_group_program`]). The pass is pure over the graph — same input,
//! same groups — and idempotent: fusing a graph built from fused outputs
//! (no edges) yields singleton groups.

use std::collections::HashMap;

use crate::graph::dag::{input_buffers, output_buffer, FusionKind, OpGraph};
use crate::search::Task;
use crate::telemetry;
use crate::tir::{rd, sp, structural_hash, AExpr, Axis, BlockBody, CExpr, IterKind, Program, Region};

/// A fused group: a producer-ordered chain of node indices that tune as
/// one program, repeated `count` times in the model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedGroup {
    /// Member node indices in dataflow order (producer first).
    pub members: Vec<usize>,
    /// Repeat count (all members of a group have the same count).
    pub count: usize,
    /// The group's dominant class (complex > reduction > injective;
    /// opaque groups are always singletons).
    pub kind: FusionKind,
}

impl FusedGroup {
    /// Original op occurrences this group covers (`count * members`):
    /// summing over all groups must equal the graph's total op weight.
    pub fn op_weight(&self) -> usize {
        self.count * self.members.len()
    }
}

/// The consumer input buffer that binds to `producer`'s output: the first
/// read-only param of `consumer` whose shape equals the producer's
/// terminal output shape. `None` means the pair cannot fuse.
fn bind_input(producer: &Program, consumer: &Program) -> Option<usize> {
    let out = output_buffer(producer)?;
    let shape = &producer.buffers[out].shape;
    input_buffers(consumer)
        .into_iter()
        .find(|&b| &consumer.buffers[b].shape == shape)
}

/// Whether ungrouped node `cand` may join a chain ending (or starting) at
/// `anchor`'s group: equal counts and a valid adjacent binding.
fn mergeable(g: &OpGraph, producer: usize, consumer: usize) -> bool {
    g.node(producer).count == g.node(consumer).count
        && bind_input(&g.node(producer).prog, &g.node(consumer).prog).is_some()
}

fn group_kind(g: &OpGraph, members: &[usize]) -> FusionKind {
    if members.len() == 1 {
        return g.node(members[0]).kind;
    }
    if members.iter().any(|&m| g.node(m).kind == FusionKind::ComplexOutFusable) {
        FusionKind::ComplexOutFusable
    } else if members.iter().any(|&m| g.node(m).kind == FusionKind::Reduction) {
        FusionKind::Reduction
    } else {
        FusionKind::Injective
    }
}

/// Run the fusion pass. Deterministic: nodes are visited in index order
/// and merges never depend on hash iteration; calling it twice on the
/// same graph yields identical groups.
pub fn fuse(g: &OpGraph) -> Vec<FusedGroup> {
    let n = g.len();
    let mut chain_of: Vec<usize> = (0..n).collect();
    let mut chains: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    let ungrouped = |chains: &[Vec<usize>], chain_of: &[usize], i: usize| chains[chain_of[i]].len() == 1;

    // Pass 1: reductions absorb single-consumer injective producer chains.
    for i in 0..n {
        if g.node(i).kind != FusionKind::Reduction {
            continue;
        }
        let c = chain_of[i];
        loop {
            let head = chains[c][0];
            let cand = g.producers(head).iter().copied().find(|&p| {
                ungrouped(&chains, &chain_of, p)
                    && g.node(p).kind == FusionKind::Injective
                    && g.consumers(p).len() == 1
                    && g.consumers(p)[0] == head
                    && mergeable(g, p, head)
            });
            match cand {
                Some(p) => {
                    let old = chain_of[p];
                    chains[old].clear();
                    chains[c].insert(0, p);
                    chain_of[p] = c;
                }
                None => break,
            }
        }
    }

    // Pass 2: complex-out-fusable anchors absorb injective epilogue
    // chains. The absorbed consumer may have other producers (a residual
    // add reads both the conv and the shortcut) — the extra inputs stay
    // parameters of the fused program — but the anchor's own output must
    // feed only the absorbed consumer.
    for i in 0..n {
        if g.node(i).kind != FusionKind::ComplexOutFusable {
            continue;
        }
        let c = chain_of[i];
        loop {
            let tail = *chains[c].last().unwrap();
            if g.consumers(tail).len() != 1 {
                break;
            }
            let cand = g.consumers(tail)[0];
            if !ungrouped(&chains, &chain_of, cand)
                || chain_of[cand] == c
                || g.node(cand).kind != FusionKind::Injective
                || !mergeable(g, tail, cand)
            {
                break;
            }
            let old = chain_of[cand];
            chains[old].clear();
            chains[c].push(cand);
            chain_of[cand] = c;
        }
    }

    // Pass 3: remaining injective -> injective chains.
    for i in 0..n {
        if g.node(i).kind != FusionKind::Injective || chains[chain_of[i]].is_empty() {
            continue;
        }
        let c = chain_of[i];
        if *chains[c].last().unwrap() != i || group_kind(g, &chains[c]) != FusionKind::Injective {
            continue;
        }
        loop {
            let tail = *chains[c].last().unwrap();
            if g.consumers(tail).len() != 1 {
                break;
            }
            let cand = g.consumers(tail)[0];
            if !ungrouped(&chains, &chain_of, cand)
                || chain_of[cand] == c
                || g.node(cand).kind != FusionKind::Injective
                || !mergeable(g, tail, cand)
            {
                break;
            }
            let old = chain_of[cand];
            chains[old].clear();
            chains[c].push(cand);
            chain_of[cand] = c;
        }
    }

    // Emit groups ordered by their first member's node index.
    let mut emitted = vec![false; chains.len()];
    let mut out = Vec::new();
    for i in 0..n {
        let c = chain_of[i];
        if emitted[c] || chains[c].is_empty() {
            continue;
        }
        emitted[c] = true;
        let members = chains[c].clone();
        let kind = group_kind(g, &members);
        let count = g.node(members[0]).count;
        out.push(FusedGroup { members, count, kind });
    }
    out
}

/// Deterministic unique-name helper: first use keeps the original name,
/// later collisions get a `_m<member-index>` suffix.
fn unique_name(used: &mut HashMap<String, usize>, name: &str, member: usize) -> String {
    let hits = used.entry(name.to_string()).or_insert(0);
    *hits += 1;
    if *hits == 1 {
        name.to_string()
    } else {
        format!("{name}_m{member}")
    }
}

/// Re-emit a fused group as one `Program`. Singleton groups return the
/// member verbatim (so per-op and fused task identities coincide for
/// unfused ops). Multi-member chains re-emit every member block with
/// fresh loop nests; each interior producer→consumer tensor becomes an
/// internal temp, everything else stays a parameter. FLOP count is
/// conserved by construction (same block domains, same bodies).
pub fn fuse_group_program(g: &OpGraph, group: &FusedGroup) -> Program {
    if group.members.len() == 1 {
        return g.node(group.members[0]).prog.clone();
    }
    let mut name = String::from("fused");
    for &m in &group.members {
        name.push('_');
        name.push_str(&g.node(m).prog.name);
    }
    let mut fused = Program::new(name);
    let mut buf_names: HashMap<String, usize> = HashMap::new();
    let mut block_names: HashMap<String, usize> = HashMap::new();
    let mut prev_out_new: Option<usize> = None;
    let last = group.members.len() - 1;
    for (j, &m) in group.members.iter().enumerate() {
        let mp = &g.node(m).prog;
        let bound_in = if j == 0 {
            None
        } else {
            bind_input(&g.node(group.members[j - 1]).prog, mp)
        };
        let out_buf = output_buffer(mp)
            .expect("fusion precondition: every chain member has a terminal output buffer");
        // Map every member buffer to a buffer of the fused program.
        let mut bmap: Vec<usize> = Vec::with_capacity(mp.buffers.len());
        for (ob, buf) in mp.buffers.iter().enumerate() {
            if Some(ob) == bound_in {
                bmap.push(prev_out_new.expect("bound input follows a produced output"));
                continue;
            }
            let uniq = unique_name(&mut buf_names, &buf.name, j);
            let interior_out = ob == out_buf && j < last;
            let nb = if mp.params.contains(&ob) && !interior_out {
                fused.param(&uniq, buf.shape.clone(), buf.dtype)
            } else {
                fused.temp(&uniq, buf.shape.clone(), buf.dtype)
            };
            bmap.push(nb);
        }
        prev_out_new = Some(bmap[out_buf]);
        // Re-emit every block with a fresh canonical loop nest.
        for b in mp.blocks() {
            let bd = mp.block_data(b).clone();
            let axes: Vec<Axis> = bd
                .iters
                .iter()
                .map(|it| match it.kind {
                    IterKind::Spatial => sp("f", it.extent),
                    IterKind::Reduce => rd("r", it.extent),
                })
                .collect();
            let bname = unique_name(&mut block_names, &bd.name, j);
            fused.emit(&bname, &axes, |iv| {
                let vmap: HashMap<_, _> = bd
                    .iters
                    .iter()
                    .zip(iv.iter())
                    .map(|(it, &nv)| (it.var, AExpr::Var(nv)))
                    .collect();
                let remap_region = |r: &Region| Region {
                    buffer: bmap[r.buffer],
                    ranges: r.ranges.iter().map(|(e, ext)| (e.subst(&vmap), *ext)).collect(),
                };
                let remap_expr = |e: &CExpr| {
                    e.map_loads(&mut |bf, idx| {
                        CExpr::Load(bmap[bf], idx.iter().map(|x| x.subst(&vmap)).collect())
                    })
                };
                let body = match &bd.body {
                    BlockBody::Assign { expr } => BlockBody::Assign { expr: remap_expr(expr) },
                    BlockBody::Reduce { init, op, rhs } => BlockBody::Reduce {
                        init: remap_expr(init),
                        op: *op,
                        rhs: remap_expr(rhs),
                    },
                    BlockBody::Opaque { flops_per_instance } => {
                        BlockBody::Opaque { flops_per_instance: *flops_per_instance }
                    }
                };
                (
                    bd.reads.iter().map(remap_region).collect(),
                    bd.writes.iter().map(remap_region).collect(),
                    body,
                )
            });
        }
    }
    fused
}

/// Per-class group tallies, mirrored into the process-global metrics
/// registry (`graph_fused_groups_total`, `graph_fusion_kind_total_*`).
fn record_metrics(groups: &[FusedGroup]) {
    let m = telemetry::global();
    m.counter("graph_fused_groups_total", "fused groups produced by the graph fusion pass")
        .add(groups.len() as u64);
    for kind in [
        FusionKind::Injective,
        FusionKind::Reduction,
        FusionKind::ComplexOutFusable,
        FusionKind::Opaque,
    ] {
        let hits = groups.iter().filter(|gr| gr.kind == kind).count() as u64;
        m.counter(
            &format!("graph_fusion_kind_total_{}", kind.label()),
            "fused groups of this fusion class",
        )
        .add(hits);
    }
}

/// Human-readable per-class summary line (`tune-model --fused` output,
/// grepped by the CI fusion-smoke job).
pub fn summarize(groups: &[FusedGroup]) -> String {
    let count = |k: FusionKind| groups.iter().filter(|gr| gr.kind == k).count();
    format!(
        "fused groups: {} (injective {}, reduction {}, complex {}, opaque {})",
        groups.len(),
        count(FusionKind::Injective),
        count(FusionKind::Reduction),
        count(FusionKind::ComplexOutFusable),
        count(FusionKind::Opaque)
    )
}

/// Fused task extraction: run the fusion pass, emit each group's fused
/// program, and dedup structurally — the fused sibling of
/// [`crate::graph::extract_tasks`]. Task weight sums group repeat counts,
/// so total weight is conserved against the group list (and group
/// [`FusedGroup::op_weight`]s conserve the original op occurrences).
pub fn extract_fused_tasks(g: &OpGraph) -> Vec<Task> {
    let groups = fuse(g);
    record_metrics(&groups);
    let mut index: HashMap<u64, usize> = HashMap::new();
    let mut tasks: Vec<Task> = Vec::new();
    for gr in &groups {
        let prog = fuse_group_program(g, gr);
        let h = structural_hash(&prog);
        match index.get(&h) {
            Some(&i) => tasks[i].weight += gr.count,
            None => {
                index.insert(h, tasks.len());
                tasks.push(Task {
                    name: super::task_name(&prog.name, h),
                    prog,
                    weight: gr.count,
                });
            }
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;
    use crate::tir::analysis::program_flops;
    use crate::workloads;

    /// dense -> add (residual) -> norm: pass 1 gives {add, norm}.
    fn toy_graph() -> OpGraph {
        let mut g = OpGraph::new();
        let d = g.add(workloads::dense(16, 32, 8), 2);
        let a = g.add(workloads::add2d(16, 32), 2);
        let nm = g.add(workloads::norm(1, 16, 32), 2);
        g.connect(d, a);
        g.connect(a, nm);
        g
    }

    #[test]
    fn reduction_absorbs_injective_producer() {
        let g = toy_graph();
        let groups = fuse(&g);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].members, vec![0]);
        assert_eq!(groups[1].members, vec![1, 2]);
        assert_eq!(groups[1].kind, FusionKind::Reduction);
        let total: usize = groups.iter().map(|gr| gr.op_weight()).sum();
        assert_eq!(total, 6); // 3 nodes x count 2
    }

    #[test]
    fn fused_program_conserves_flops_and_verifies() {
        let g = toy_graph();
        let groups = fuse(&g);
        let fused = fuse_group_program(&g, &groups[1]);
        fused.check_integrity().unwrap();
        let expect = program_flops(&g.node(1).prog) + program_flops(&g.node(2).prog);
        assert_eq!(program_flops(&fused), expect);
        // Interior add output became a temp; fused params are the add's
        // two inputs plus norm's output.
        assert_eq!(fused.params.len(), 3);
        // Dataflow: add feeds sq_sum and normalize through the temp.
        let add = fused.find_block("add").unwrap();
        assert_eq!(fused.consumers_of(add).len(), 2);
    }

    #[test]
    fn complex_absorbs_epilogue_chain() {
        // dense -> bias-style add -> relu is swallowed by the anchor.
        let mut g = OpGraph::new();
        let d = g.add(workloads::dense(8, 8, 8), 1);
        let a = g.add(workloads::add2d(8, 8), 1);
        g.connect(d, a);
        let groups = fuse(&g);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].members, vec![0, 1]);
        assert_eq!(groups[0].kind, FusionKind::ComplexOutFusable);
        let fused = fuse_group_program(&g, &groups[0]);
        fused.check_integrity().unwrap();
        assert_eq!(
            program_flops(&fused),
            program_flops(&g.node(0).prog) + program_flops(&g.node(1).prog)
        );
    }

    #[test]
    fn count_mismatch_and_multi_consumer_block_fusion() {
        // Count mismatch: no merge.
        let mut g = OpGraph::new();
        let d = g.add(workloads::dense(8, 8, 8), 2);
        let a = g.add(workloads::add2d(8, 8), 1);
        g.connect(d, a);
        assert_eq!(fuse(&g).len(), 2);
        // Multi-consumer producer: its output is needed elsewhere.
        let mut g2 = OpGraph::new();
        let d2 = g2.add(workloads::dense(8, 8, 8), 1);
        let a2 = g2.add(workloads::add2d(8, 8), 1);
        let b2 = g2.add(workloads::add2d(8, 8), 1);
        g2.connect(d2, a2);
        g2.connect(d2, b2);
        assert_eq!(fuse(&g2).len(), 3);
    }

    #[test]
    fn opaque_boundaries_never_crossed() {
        let mut opaque = workloads::add2d(8, 8);
        let b = opaque.find_block("add").unwrap();
        opaque.block_data_mut(b).body = BlockBody::Opaque { flops_per_instance: 1.0 };
        let mut g = OpGraph::new();
        let d = g.add(workloads::dense(8, 8, 8), 1);
        let o = g.add(opaque, 1);
        g.connect(d, o);
        let groups = fuse(&g);
        assert_eq!(groups.len(), 2);
        assert!(groups.iter().all(|gr| gr.members.len() == 1));
        assert_eq!(groups[1].kind, FusionKind::Opaque);
    }

    #[test]
    fn fusion_is_deterministic_and_idempotent() {
        let g = graph::bert_base_graph();
        let a = fuse(&g);
        let b = fuse(&g);
        assert_eq!(a, b);
        // Idempotent: re-lifting the fused outputs (no edges — fusion
        // consumed them) and fusing again changes nothing.
        let tasks = extract_fused_tasks(&g);
        let refused: graph::OpList = tasks.iter().map(|t| (t.prog.clone(), t.weight)).collect();
        let g2 = OpGraph::from_ops(&refused);
        let again = fuse(&g2);
        assert!(again.iter().all(|gr| gr.members.len() == 1));
        assert_eq!(extract_fused_tasks(&g2).len(), tasks.len());
    }

    #[test]
    fn injective_chain_fuses() {
        let mut g = OpGraph::new();
        let a = g.add(workloads::relu(64), 1);
        let mut second = workloads::relu(64);
        second.name = "relu2".into();
        let b = g.add(second, 1);
        g.connect(a, b);
        let groups = fuse(&g);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].members, vec![0, 1]);
        assert_eq!(groups[0].kind, FusionKind::Injective);
        let fused = fuse_group_program(&g, &groups[0]);
        fused.check_integrity().unwrap();
        assert_eq!(program_flops(&fused), 128.0);
    }
}
