//! Graph-level integration: operator DAGs for whole models, task
//! extraction with structural deduplication (per-op and fused), and
//! end-to-end latency aggregation (paper §6.2 and Appendix A.6 —
//! frameworks hand us a computational graph; we extract the unique tensor
//! programs, tune each, and sum weighted best latencies).
//!
//! The DAG layer lives in [`dag`] (nodes, edges, `FusionKind`
//! classification) and the fusion pass in [`fusion`]; the flat `OpList`
//! remains a lossless projection for every pre-graph caller.

pub mod dag;
pub mod fusion;
pub mod models;

pub use dag::{classify, FusionKind, OpGraph, OpNode};
pub use fusion::{extract_fused_tasks, fuse, fuse_group_program, summarize, FusedGroup};
pub use models::{
    bert_base, bert_base_graph, bert_large, bert_large_graph, by_name, gpt2, gpt2_graph,
    graph_by_name, inception_v1, inception_v1_graph, mobilenet_v2, mobilenet_v2_graph, resnet50,
    resnet50_graph, OpList, MODEL_NAMES,
};

use std::collections::HashMap;

use crate::search::Task;
use crate::tir::structural_hash;

/// Stable task name: the program name plus a structural-hash suffix, so
/// the same op gets the same task name (and db workload identity) in
/// every model, independent of op-list insertion order.
pub(crate) fn task_name(base: &str, h: u64) -> String {
    format!("{}_{:08x}", base, (h ^ (h >> 32)) as u32)
}

/// Deduplicate an operator list into tuning tasks: operators with the same
/// structural hash share one task whose weight is the summed occurrence
/// count (the paper's task extraction).
pub fn extract_tasks(ops: &OpList) -> Vec<Task> {
    let mut index: HashMap<u64, usize> = HashMap::new();
    let mut tasks: Vec<Task> = Vec::new();
    for (prog, count) in ops {
        let h = structural_hash(prog);
        match index.get(&h) {
            Some(&i) => tasks[i].weight += count,
            None => {
                index.insert(h, tasks.len());
                tasks.push(Task {
                    name: task_name(&prog.name, h),
                    prog: prog.clone(),
                    weight: *count,
                });
            }
        }
    }
    tasks
}

/// End-to-end vendor-library latency: every op dispatched to the vendor
/// kernel model.
pub fn vendor_e2e(ops: &OpList, target: &crate::sim::Target) -> f64 {
    ops.iter()
        .map(|(p, c)| crate::baselines::vendor_latency(p, target) * *c as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extraction_dedups_repeated_ops() {
        let ops = bert_base();
        let tasks = extract_tasks(&ops);
        // The QKV dense and output-projection dense entries share one
        // structural hash, so tasks < distinct op-list entries.
        assert!(tasks.len() < ops.len());
        let total_ops: usize = ops.iter().map(|(_, c)| c).sum();
        let total_weight: usize = tasks.iter().map(|t| t.weight).sum();
        assert_eq!(total_ops, total_weight);
        // Q/K/V dense appears 3x per layer x 12 plus the output projection.
        let dense_task = tasks
            .iter()
            .find(|t| t.prog.name == "dense" && t.weight >= 36)
            .expect("qkv dense task");
        assert_eq!(dense_task.weight, 48);
    }

    #[test]
    fn task_names_are_insertion_order_independent() {
        // The same op must get the same task name regardless of which
        // model (or position) it is extracted from.
        let d = crate::workloads::dense(128, 768, 768);
        let r = crate::workloads::relu(1 << 12);
        let fwd = extract_tasks(&vec![(d.clone(), 1), (r.clone(), 1)]);
        let rev = extract_tasks(&vec![(r, 1), (d, 1)]);
        assert_eq!(fwd[0].name, rev[1].name);
        assert_eq!(fwd[1].name, rev[0].name);
        assert!(fwd[0].name.starts_with("dense_"));
    }

    #[test]
    fn resnet_tasks_are_manageable() {
        let tasks = extract_tasks(&resnet50());
        assert!(tasks.len() < 30, "{} tasks", tasks.len());
        assert!(tasks.len() > 10);
    }

    #[test]
    fn fused_extraction_is_strictly_smaller_and_conserves_weight() {
        for (graph, ops) in [
            (resnet50_graph(), resnet50()),
            (bert_base_graph(), bert_base()),
        ] {
            let per_op = extract_tasks(&ops);
            let fused = extract_fused_tasks(&graph);
            assert!(
                fused.len() < per_op.len(),
                "fused {} !< per-op {}",
                fused.len(),
                per_op.len()
            );
            // Group op-weights conserve the original op occurrences.
            let groups = fuse(&graph);
            let grouped: usize = groups.iter().map(|g| g.op_weight()).sum();
            let total_ops: usize = ops.iter().map(|(_, c)| c).sum();
            assert_eq!(grouped, total_ops);
            // Task weights conserve the group repeat counts.
            let task_weight: usize = fused.iter().map(|t| t.weight).sum();
            let group_count: usize = groups.iter().map(|g| g.count).sum();
            assert_eq!(task_weight, group_count);
        }
    }

    #[test]
    fn vendor_e2e_positive_for_all_models() {
        let cpu = crate::sim::Target::cpu_avx512();
        for name in MODEL_NAMES {
            let ops = by_name(name).unwrap();
            let l = vendor_e2e(&ops, &cpu);
            assert!(l > 0.0 && l.is_finite(), "{name}: {l}");
        }
    }

    #[test]
    fn identical_programs_same_hash_distinct_shapes_differ() {
        let a = crate::workloads::dense(128, 768, 768);
        let b = crate::workloads::dense(128, 768, 768);
        let c = crate::workloads::dense(128, 1024, 768);
        assert_eq!(structural_hash(&a), structural_hash(&b));
        assert_ne!(structural_hash(&a), structural_hash(&c));
    }
}
