//! Graph-level integration: operator lists for whole models, task
//! extraction with structural deduplication, and end-to-end latency
//! aggregation (paper §6.2 and Appendix A.6 — frameworks hand us a
//! computational graph; we extract the unique tensor programs, tune each,
//! and sum weighted best latencies).

pub mod models;

pub use models::{bert_base, bert_large, by_name, gpt2, inception_v1, mobilenet_v2, resnet50, OpList, MODEL_NAMES};

use std::collections::HashMap;

use crate::search::Task;
use crate::tir::structural_hash;

/// Deduplicate an operator list into tuning tasks: operators with the same
/// structural hash share one task whose weight is the summed occurrence
/// count (the paper's task extraction).
pub fn extract_tasks(ops: &OpList) -> Vec<Task> {
    let mut index: HashMap<u64, usize> = HashMap::new();
    let mut tasks: Vec<Task> = Vec::new();
    for (prog, count) in ops {
        let h = structural_hash(prog);
        match index.get(&h) {
            Some(&i) => tasks[i].weight += count,
            None => {
                index.insert(h, tasks.len());
                tasks.push(Task {
                    name: format!("{}_{}", prog.name, tasks.len()),
                    prog: prog.clone(),
                    weight: *count,
                });
            }
        }
    }
    tasks
}

/// End-to-end vendor-library latency: every op dispatched to the vendor
/// kernel model.
pub fn vendor_e2e(ops: &OpList, target: &crate::sim::Target) -> f64 {
    ops.iter()
        .map(|(p, c)| crate::baselines::vendor_latency(p, target) * *c as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extraction_dedups_repeated_ops() {
        let ops = bert_base();
        let tasks = extract_tasks(&ops);
        // The QKV dense and output-projection dense entries share one
        // structural hash, so tasks < distinct op-list entries.
        assert!(tasks.len() < ops.len());
        let total_ops: usize = ops.iter().map(|(_, c)| c).sum();
        let total_weight: usize = tasks.iter().map(|t| t.weight).sum();
        assert_eq!(total_ops, total_weight);
        // Q/K/V dense appears 3x per layer x 12 plus the output projection.
        let dense_task = tasks
            .iter()
            .find(|t| t.prog.name == "dense" && t.weight >= 36)
            .expect("qkv dense task");
        assert_eq!(dense_task.weight, 48);
    }

    #[test]
    fn resnet_tasks_are_manageable() {
        let tasks = extract_tasks(&resnet50());
        assert!(tasks.len() < 30, "{} tasks", tasks.len());
        assert!(tasks.len() > 10);
    }

    #[test]
    fn vendor_e2e_positive_for_all_models() {
        let cpu = crate::sim::Target::cpu_avx512();
        for name in MODEL_NAMES {
            let ops = by_name(name).unwrap();
            let l = vendor_e2e(&ops, &cpu);
            assert!(l > 0.0 && l.is_finite(), "{name}: {l}");
        }
    }

    #[test]
    fn identical_programs_same_hash_distinct_shapes_differ() {
        let a = crate::workloads::dense(128, 768, 768);
        let b = crate::workloads::dense(128, 768, 768);
        let c = crate::workloads::dense(128, 1024, 768);
        assert_eq!(structural_hash(&a), structural_hash(&b));
        assert_ne!(structural_hash(&a), structural_hash(&c));
    }
}
