//! Operator DAG: the graph-level IR above per-op `Program`s.
//!
//! A model is an [`OpGraph`]: nodes wrap a tensor program plus its repeat
//! count, edges are producer → consumer dataflow, and every node carries a
//! [`FusionKind`] classified from its TIR block structure (the TVM
//! four-class scheme). The flat `OpList` the rest of the system consumes
//! is a lossless projection ([`OpGraph::ops`]); the fusion pass in
//! [`crate::graph::fusion`] consumes the edges.

use crate::tir::{BlockBody, Program};

/// The TVM operator-fusion classification, derived here from block
/// structure instead of an operator registry:
///
/// | kind              | structural test                                    |
/// |-------------------|----------------------------------------------------|
/// | `Opaque`          | any block body is `BlockBody::Opaque`              |
/// | `ComplexOutFusable` | any block is matmul-like (MAC reduction, ≥2 spatial, ≥1 reduce) |
/// | `Reduction`       | any block reduces (and none is matmul-like)        |
/// | `Injective`       | everything else (elementwise / broadcast / copy)   |
///
/// Precedence is top-to-bottom: a program with a conv block *and* an
/// elementwise epilogue is complex-out-fusable, not injective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FusionKind {
    /// Elementwise / broadcast / data-movement: fuses with anything
    /// adjacent of equal repeat count.
    Injective,
    /// Contains a reduction (softmax, norm): absorbs injective inputs,
    /// but its own output does not fuse forward.
    Reduction,
    /// Matmul/conv-class anchor: absorbs elementwise epilogues
    /// (conv+bias+relu), never fuses into another complex op.
    ComplexOutFusable,
    /// Unknown internals: a hard fusion boundary on both sides.
    Opaque,
}

impl FusionKind {
    /// Stable lowercase label (metrics suffixes, reports).
    pub fn label(&self) -> &'static str {
        match self {
            FusionKind::Injective => "injective",
            FusionKind::Reduction => "reduction",
            FusionKind::ComplexOutFusable => "complex",
            FusionKind::Opaque => "opaque",
        }
    }
}

/// Classify a program by inspecting its live blocks (see [`FusionKind`]).
pub fn classify(prog: &Program) -> FusionKind {
    let blocks = prog.blocks();
    if blocks
        .iter()
        .any(|&b| matches!(prog.block_data(b).body, BlockBody::Opaque { .. }))
    {
        return FusionKind::Opaque;
    }
    if blocks
        .iter()
        .any(|&b| crate::space::analysis::is_matmul_like(prog, b))
    {
        return FusionKind::ComplexOutFusable;
    }
    if blocks.iter().any(|&b| prog.block_data(b).is_reduction()) {
        return FusionKind::Reduction;
    }
    FusionKind::Injective
}

/// The parameter buffer a program's dataflow terminates in: written by
/// some block, read by none. Returns `None` when the program has no such
/// buffer (or several candidates would be ambiguous — we take the last in
/// buffer order, matching builder convention of pushing outputs last).
pub fn output_buffer(prog: &Program) -> Option<usize> {
    prog.params
        .iter()
        .copied()
        .filter(|&b| !prog.writers_of(b).is_empty() && prog.readers_of(b).is_empty())
        .last()
}

/// Parameter buffers a program only reads (its true inputs), in buffer
/// order.
pub fn input_buffers(prog: &Program) -> Vec<usize> {
    prog.params
        .iter()
        .copied()
        .filter(|&b| prog.writers_of(b).is_empty() && !prog.readers_of(b).is_empty())
        .collect()
}

/// One operator occurrence in the graph.
#[derive(Debug, Clone)]
pub struct OpNode {
    pub prog: Program,
    /// Repeat count (e.g. 12 for a per-layer op in BERT-base).
    pub count: usize,
    pub kind: FusionKind,
}

/// A model as an operator DAG. Node indices are stable (insertion order);
/// edges mean "producer's output tensor feeds consumer".
#[derive(Debug, Clone, Default)]
pub struct OpGraph {
    nodes: Vec<OpNode>,
    succ: Vec<Vec<usize>>,
    pred: Vec<Vec<usize>>,
}

impl OpGraph {
    pub fn new() -> OpGraph {
        OpGraph::default()
    }

    /// Append a node; its [`FusionKind`] is classified on insertion.
    pub fn add(&mut self, prog: Program, count: usize) -> usize {
        let kind = classify(&prog);
        self.nodes.push(OpNode { prog, count, kind });
        self.succ.push(Vec::new());
        self.pred.push(Vec::new());
        self.nodes.len() - 1
    }

    /// Record a producer → consumer dataflow edge. Duplicate edges are
    /// collapsed; self-edges are rejected (a DAG node cannot feed itself).
    pub fn connect(&mut self, producer: usize, consumer: usize) {
        assert!(producer < self.nodes.len() && consumer < self.nodes.len(), "edge out of range");
        assert_ne!(producer, consumer, "self-edge");
        if !self.succ[producer].contains(&consumer) {
            self.succ[producer].push(consumer);
            self.pred[consumer].push(producer);
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, i: usize) -> &OpNode {
        &self.nodes[i]
    }

    pub fn nodes(&self) -> &[OpNode] {
        &self.nodes
    }

    /// Consumers of node `i`, in edge insertion order.
    pub fn consumers(&self, i: usize) -> &[usize] {
        &self.succ[i]
    }

    /// Producers of node `i`, in edge insertion order.
    pub fn producers(&self, i: usize) -> &[usize] {
        &self.pred[i]
    }

    /// Lossless flat projection: every node as a `(program, count)` entry
    /// in insertion order. This is what every pre-graph caller consumes;
    /// only the edges are dropped.
    pub fn ops(&self) -> super::OpList {
        self.nodes.iter().map(|n| (n.prog.clone(), n.count)).collect()
    }

    /// Lift a flat op list into an edge-free graph (fusion over it is the
    /// identity grouping — used for idempotence and by callers that have
    /// no dataflow information).
    pub fn from_ops(ops: &super::OpList) -> OpGraph {
        let mut g = OpGraph::new();
        for (p, c) in ops {
            g.add(p.clone(), *c);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn classify_four_classes() {
        assert_eq!(classify(&workloads::dense(8, 8, 8)), FusionKind::ComplexOutFusable);
        assert_eq!(
            classify(&workloads::conv2d(workloads::Conv2dParams::new(1, 8, 8, 3, 4, 3, 1, 1))),
            FusionKind::ComplexOutFusable
        );
        // fused_dense has an elementwise epilogue but the dense anchor wins.
        assert_eq!(classify(&workloads::fused_dense(8, 8, 8)), FusionKind::ComplexOutFusable);
        assert_eq!(classify(&workloads::softmax(1, 8, 8)), FusionKind::Reduction);
        assert_eq!(classify(&workloads::norm(1, 8, 8)), FusionKind::Reduction);
        assert_eq!(classify(&workloads::add2d(8, 8)), FusionKind::Injective);
        assert_eq!(classify(&workloads::relu(64)), FusionKind::Injective);
        // An opaque block forces the opaque class.
        let mut p = workloads::relu(64);
        let b = p.find_block("relu").unwrap();
        p.block_data_mut(b).body = BlockBody::Opaque { flops_per_instance: 1.0 };
        assert_eq!(classify(&p), FusionKind::Opaque);
    }

    #[test]
    fn io_buffer_analysis() {
        let p = workloads::fused_dense(8, 16, 8);
        // Out is the terminal param (Y is written AND read internally).
        let out = output_buffer(&p).unwrap();
        assert_eq!(p.buffers[out].name, "Out");
        let ins = input_buffers(&p);
        let names: Vec<&str> = ins.iter().map(|&b| p.buffers[b].name.as_str()).collect();
        assert_eq!(names, vec!["X", "W", "Bias"]);
    }

    #[test]
    fn graph_edges_and_projection() {
        let mut g = OpGraph::new();
        let a = g.add(workloads::dense(8, 8, 8), 2);
        let b = g.add(workloads::add2d(8, 8), 2);
        g.connect(a, b);
        g.connect(a, b); // duplicate collapses
        assert_eq!(g.consumers(a), &[b]);
        assert_eq!(g.producers(b), &[a]);
        let ops = g.ops();
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].1, 2);
        let lifted = OpGraph::from_ops(&ops);
        assert_eq!(lifted.len(), 2);
        assert!(lifted.consumers(0).is_empty());
    }
}
