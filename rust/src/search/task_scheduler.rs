//! Task scheduler: allocates the measurement budget across the subgraph
//! tasks extracted from an end-to-end model. Round-robin warmup followed
//! by policy-driven allocation rounds: the loop itself is a thin driver
//! that asks an [`AllocationPolicy`] to pick the next task from the
//! [`TaskLedger`] (per-task spend, best-latency history, saturation) and
//! runs one search round there. Policies — round-robin, the historical
//! weighted-best-latency greedy, Ansor-style gradient gain — live in
//! [`crate::search::allocation`].
//!
//! The warmup phase is embarrassingly parallel (every task runs exactly
//! one round with its own cost model and design space), so it executes
//! across worker threads against a [`SharedMeasurer`]; results merge in
//! task order, keeping the schedule deterministic. Allocation rounds are
//! inherently sequential — each decision depends on all results so far —
//! and stay on the coordinator, but the searches they launch still
//! parallelize internally (chain parallelism + the measurement pipeline).

use crate::cost_model::{GbtCostModel, Objective};
use crate::ctx::TuneContext;
use crate::db::{Database, InMemoryDb, SharedDb};
use crate::search::allocation::{Allocation, AllocationPolicy, AllocationReport, TaskLedger};
use crate::search::evolutionary::{EvolutionarySearch, QualityPoint, SearchConfig, TuneResult};
use crate::search::parallel::{parallel_map, SharedMeasurer};
use crate::search::Measurer;
use crate::tir::{structural_hash, Program};
use std::sync::Arc;

/// One tuning task: a deduplicated subgraph with its occurrence count.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    pub prog: Program,
    /// How many times the subgraph occurs in the model.
    pub weight: usize,
}

/// Cached handles for the `sched_*` metric family. Observation-only:
/// nothing in the scheduling decisions reads a counter.
struct SchedTelemetry {
    warmup_rounds: Arc<crate::telemetry::Counter>,
    rounds: Arc<crate::telemetry::Counter>,
    trials: Arc<crate::telemetry::Counter>,
    saturated: Arc<crate::telemetry::Counter>,
    early_stops: Arc<crate::telemetry::Counter>,
}

impl SchedTelemetry {
    fn from_global() -> SchedTelemetry {
        let m = crate::telemetry::global();
        SchedTelemetry {
            warmup_rounds: m.counter(
                "sched_warmup_rounds_total",
                "per-task warmup rounds run by the task scheduler",
            ),
            rounds: m.counter(
                "sched_rounds_total",
                "post-warmup allocation rounds granted by the task scheduler",
            ),
            trials: m.counter(
                "sched_trials_total",
                "trials charged against scheduler budgets (warmup + allocation)",
            ),
            saturated: m.counter(
                "sched_saturated_total",
                "tasks retired as saturated (search dried up) during scheduling",
            ),
            early_stops: m.counter(
                "sched_early_stops_total",
                "scheduler runs that stopped before budget exhaustion (all tasks saturated)",
            ),
        }
    }
}

pub struct TaskScheduler {
    pub cfg: SearchConfig,
    pub allocation: Allocation,
    /// Training objective for the per-task cost models.
    pub objective: Objective,
    /// Trials given to a task per scheduling round.
    pub round_trials: usize,
}

impl TaskScheduler {
    pub fn new(cfg: SearchConfig) -> TaskScheduler {
        TaskScheduler {
            cfg,
            allocation: Allocation::Greedy,
            objective: Objective::Regression,
            round_trials: 32,
        }
    }

    /// Round config for a trial budget: tail rounds with small budgets
    /// scale the population down so fixed per-round costs stay
    /// proportional to the trials spent.
    fn round_cfg(&self, trials: usize, threads: usize) -> SearchConfig {
        let mut cfg = self.cfg.clone();
        cfg.num_trials = trials;
        cfg.population = cfg.population.min((trials * 6).max(8));
        cfg.threads = threads;
        cfg
    }

    /// Tune all tasks within a total trial budget; returns per-task results
    /// in task order.
    pub fn tune_tasks(
        &self,
        tasks: &[Task],
        ctx: &TuneContext,
        measurer: &mut dyn Measurer,
        total_trials: usize,
        seed: u64,
    ) -> Vec<TuneResult> {
        let mut scratch = InMemoryDb::new();
        self.tune_tasks_with_db(tasks, ctx, measurer, &mut scratch, total_trials, seed)
    }

    /// Like [`Self::tune_tasks`] but backed by a tuning database. Tasks
    /// whose workload already has records get their warmup round
    /// shortened to a quarter of the fair share — their searches resume
    /// from the recorded best instead of exploring from scratch — and the
    /// saved budget flows into the gradient rounds on the weighted-worst
    /// tasks. All searches read and commit through the
    /// shared database, so an end-to-end model tune is resumable
    /// mid-model: killed after task 3 of 12, the next run replays tasks
    /// 1-3 from records in seconds and spends its budget on 4-12.
    pub fn tune_tasks_with_db(
        &self,
        tasks: &[Task],
        ctx: &TuneContext,
        measurer: &mut dyn Measurer,
        db: &mut dyn Database,
        total_trials: usize,
        seed: u64,
    ) -> Vec<TuneResult> {
        self.tune_tasks_report(tasks, ctx, measurer, db, total_trials, seed).0
    }

    /// Like [`Self::tune_tasks_with_db`], additionally returning the
    /// [`AllocationReport`]: per-task budget shares and the scheduler-
    /// level time-to-quality curve. The report is observation-only — the
    /// tuning results and database bytes are identical with or without
    /// reading it.
    pub fn tune_tasks_report(
        &self,
        tasks: &[Task],
        ctx: &TuneContext,
        measurer: &mut dyn Measurer,
        db: &mut dyn Database,
        total_trials: usize,
        seed: u64,
    ) -> (Vec<TuneResult>, AllocationReport) {
        assert!(!tasks.is_empty());
        let started = std::time::Instant::now();
        let tel = SchedTelemetry::from_global();
        let threads = self.cfg.resolved_threads();
        // Register every workload up front, in task order, so ids (and
        // any new JSONL registry lines) are deterministic, and snapshot
        // which tasks have history before any of this run's commits land.
        let target_name = measurer.target_name();
        let wids: Vec<usize> = tasks
            .iter()
            .map(|t| db.register_workload(&t.name, structural_hash(&t.prog), &target_name))
            .collect();
        let has_history: Vec<bool> = wids.iter().map(|&w| db.best_latency(w).is_some()).collect();
        let shared_db = SharedDb::new(db);
        let mut models: Vec<GbtCostModel> = tasks
            .iter()
            .map(|_| GbtCostModel::with_objective(self.objective))
            .collect();
        // Design spaces generated ONCE per task; later rounds re-execute
        // the recorded traces (§4 execution tracing) instead of re-running
        // the space construction.
        let designs: Vec<Vec<crate::trace::Trace>> = tasks
            .iter()
            .map(|t| {
                ctx.generate(&t.prog, seed)
                    .into_iter()
                    .map(|d| d.trace)
                    .collect()
            })
            .collect();

        // Warmup: one round each, with the full fair share (capped by
        // round_trials): matching the per-task baseline's round structure
        // keeps the scheduler's fixed costs per measurement at parity
        // (§Perf / Table 1); any budget beyond `round_trials` per task
        // flows into gradient rounds on the weighted-worst tasks. All
        // warmup rounds run concurrently — inner searches drop to one
        // thread each so the machine is shared across tasks, and each
        // task's result is a pure function of (task, seed).
        let warmup_trials = (total_trials / tasks.len()).clamp(1, self.round_trials);
        let shared = SharedMeasurer::new(measurer);
        let items: Vec<(usize, GbtCostModel)> = models.drain(..).enumerate().collect();
        let warmed: Vec<(TuneResult, GbtCostModel)> =
            parallel_map(items, threads, |_, (ti, mut model)| {
                // Split the thread budget across concurrent tasks; the
                // inner search is thread-count-invariant, so this only
                // affects wall-clock. Tasks with database history warm-
                // start (elites + pretrained model + dedup) and need only
                // a short confirmation round.
                let inner_threads = (threads / tasks.len()).max(1);
                let trials = if has_history[ti] { (warmup_trials / 4).max(1) } else { warmup_trials };
                let search = EvolutionarySearch::new(self.round_cfg(trials, inner_threads));
                let mut local: &SharedMeasurer = &shared;
                let mut local_db: &SharedDb = &shared_db;
                let r = search.tune_with_db(
                    &tasks[ti].prog,
                    ctx,
                    &designs[ti],
                    &[],
                    &mut model,
                    &mut local,
                    &mut local_db,
                    None,
                    seed.wrapping_add(ti as u64 * 7919),
                );
                (r, model)
            });
        // The ledger is the single source of truth for budget accounting:
        // warmup charges follow the historical `trials.max(1)` convention
        // and the allocation loop's grant capping keeps total spend
        // within one round of the budget (asserted inside the ledger).
        let task_meta: Vec<(String, usize)> =
            tasks.iter().map(|t| (t.name.clone(), t.weight)).collect();
        let mut ledger = TaskLedger::new(&task_meta, total_trials, self.round_trials);
        let mut results: Vec<Option<TuneResult>> = Vec::with_capacity(tasks.len());
        for (ti, (r, model)) in warmed.into_iter().enumerate() {
            ledger.charge_warmup(ti, r.trials, r.best_latency_s);
            tel.warmup_rounds.inc();
            tel.trials.add(r.trials as u64);
            models.push(model);
            results.push(Some(r));
        }
        let mut curve: Vec<QualityPoint> = vec![QualityPoint {
            trials: ledger.spent,
            best_latency_s: ledger.e2e_latency(),
            wall_ms: started.elapsed().as_secs_f64() * 1e3,
        }];

        // Allocation rounds: the policy picks, the loop runs one search
        // round there, the ledger records the outcome. Sequential by
        // design — each decision depends on all results so far.
        let mut policy: Box<dyn AllocationPolicy> = self.allocation.policy();
        let mut early_stop = false;
        while ledger.spent < total_trials {
            let ti = match policy.pick(&ledger) {
                Some(ti) => ti,
                None => {
                    // Every task saturated: spending the rest of the
                    // budget would only re-measure dead ends.
                    early_stop = true;
                    tel.early_stops.inc();
                    break;
                }
            };
            let round = ledger.next_round;
            let trials = self.round_trials.min(total_trials - ledger.spent);
            let search = EvolutionarySearch::new(self.round_cfg(trials, self.cfg.threads));
            // Warm-start with the task's best trace so later rounds refine
            // rather than restart from scratch (the database adds its own
            // top-k on top, and dedups against everything measured so far).
            let warm: Vec<crate::trace::Trace> = results[ti]
                .iter()
                .map(|r| r.best_trace.clone())
                .collect();
            let mut local: &SharedMeasurer = &shared;
            let mut local_db: &SharedDb = &shared_db;
            let r = search.tune_with_db(
                &tasks[ti].prog,
                ctx,
                &designs[ti],
                &warm,
                &mut models[ti],
                &mut local,
                &mut local_db,
                None,
                seed.wrapping_add(round as u64 * 7919),
            );
            ledger.charge_round(ti, r.trials, r.best_latency_s);
            tel.rounds.inc();
            tel.trials.add(r.trials as u64);
            // Keep the better of old/new results.
            let better = results[ti]
                .as_ref()
                .map(|old| r.best_latency_s < old.best_latency_s)
                .unwrap_or(true);
            if better {
                results[ti] = Some(r);
            }
            ledger.next_round += 1;
            curve.push(QualityPoint {
                trials: ledger.spent,
                best_latency_s: ledger.e2e_latency(),
                wall_ms: started.elapsed().as_secs_f64() * 1e3,
            });
        }
        tel.saturated.add(ledger.entries.iter().filter(|e| e.saturated).count() as u64);
        let report = AllocationReport::from_ledger(
            policy.name(),
            self.objective.label(),
            &ledger,
            curve,
            early_stop,
        );
        let results = results
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.unwrap_or_else(|| panic!("task {i} never tuned")))
            .collect();
        (results, report)
    }

    /// End-to-end latency estimate: weighted sum of per-task best latency.
    pub fn e2e_latency(tasks: &[Task], results: &[TuneResult]) -> f64 {
        tasks
            .iter()
            .zip(results)
            .map(|(t, r)| t.weight as f64 * r.best_latency_s)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::SimMeasurer;
    use crate::sim::Target;
    use crate::workloads;

    fn tiny_tasks() -> Vec<Task> {
        vec![
            Task {
                name: "gmm".into(),
                prog: workloads::matmul(1, 128, 128, 128),
                weight: 4,
            },
            Task {
                name: "sfm".into(),
                prog: workloads::softmax(1, 128, 128),
                weight: 1,
            },
        ]
    }

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            population: 16,
            generations: 2,
            measure_batch: 8,
            ..SearchConfig::default()
        }
    }

    #[test]
    fn all_tasks_get_tuned_within_budget() {
        let target = Target::cpu_avx512();
        let ctx = TuneContext::generic(target.clone());
        let mut measurer = SimMeasurer::new(target);
        let ts = TaskScheduler::new(quick_cfg());
        let tasks = tiny_tasks();
        let results = ts.tune_tasks(&tasks, &ctx, &mut measurer, 64, 0);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert!(r.best_latency_s.is_finite() && r.best_latency_s > 0.0);
        }
        let e2e = TaskScheduler::e2e_latency(&tasks, &results);
        assert!(e2e > 0.0);
    }

    #[test]
    fn greedy_allocation_prefers_heavy_task() {
        // With the default greedy allocation the heavy task (weight x
        // latency larger) should receive at least as many trials as the
        // light one.
        let target = Target::cpu_avx512();
        let ctx = TuneContext::generic(target.clone());
        let mut measurer = SimMeasurer::new(target);
        let mut ts = TaskScheduler::new(quick_cfg());
        assert_eq!(ts.allocation, Allocation::Greedy);
        assert_eq!(ts.objective, Objective::Regression);
        ts.round_trials = 16;
        let tasks = tiny_tasks();
        let results = ts.tune_tasks(&tasks, &ctx, &mut measurer, 96, 1);
        assert!(results[0].trials >= results[1].trials);
    }

    #[test]
    fn gradient_rank_configuration_tunes_all_tasks() {
        // The new policy/objective pair must run end-to-end: every task
        // tuned, budget respected within one round, report consistent.
        let target = Target::cpu_avx512();
        let ctx = TuneContext::generic(target.clone());
        let mut measurer = SimMeasurer::new(target);
        let mut ts = TaskScheduler::new(quick_cfg());
        ts.allocation = Allocation::Gradient;
        ts.objective = Objective::PairwiseRank;
        ts.round_trials = 16;
        let tasks = tiny_tasks();
        let mut db = crate::db::InMemoryDb::new();
        let (results, report) =
            ts.tune_tasks_report(&tasks, &ctx, &mut measurer, &mut db, 96, 5);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert!(r.best_latency_s.is_finite() && r.best_latency_s > 0.0);
        }
        assert_eq!(report.policy, "gradient");
        assert_eq!(report.objective, "rank");
        assert_eq!(report.total_trials, 96);
        assert!(report.spent <= 96 + ts.round_trials);
        assert_eq!(report.per_task.len(), 2);
        assert_eq!(
            report.per_task.iter().map(|s| s.trials).sum::<usize>(),
            report.spent,
            "per-task shares must add up to the global spend"
        );
        // The curve tracks warmup plus each allocation round and its
        // end-to-end estimate never worsens (bests are monotone).
        assert_eq!(report.curve.len(), 1 + report.rounds);
        for w in report.curve.windows(2) {
            assert!(w[1].best_latency_s <= w[0].best_latency_s + 1e-12);
            assert!(w[1].trials >= w[0].trials);
        }
    }

    #[test]
    fn resumed_model_tune_reuses_records_and_stays_valid() {
        // First pass populates the db; a resumed pass must (a) see the
        // history, (b) not re-measure committed candidates, (c) end at
        // least as good per task.
        let target = Target::cpu_avx512();
        let ctx = TuneContext::generic(target.clone());
        let tasks = tiny_tasks();
        let mut db = crate::db::InMemoryDb::new();
        let run = |db: &mut dyn crate::db::Database| {
            let mut measurer = SimMeasurer::new(target.clone());
            let ts = TaskScheduler::new(quick_cfg());
            ts.tune_tasks_with_db(&tasks, &ctx, &mut measurer, db, 48, 3)
        };
        let first = run(&mut db);
        let n_records = db.num_records();
        assert!(n_records > 0);
        let second = run(&mut db);
        for (a, b) in first.iter().zip(&second) {
            assert!(b.best_latency_s <= a.best_latency_s, "task {} regressed on resume", a.task);
        }
        assert!(second.iter().any(|r| r.warm_records > 0), "resume never warm-started");
        // Candidate dedup held across the two passes, per workload.
        for e in db.workload_entries() {
            let hashes = db.candidate_hashes(e.id);
            let unique: std::collections::HashSet<u64> = hashes.iter().copied().collect();
            assert_eq!(unique.len(), hashes.len(), "workload {} re-measured a candidate", e.name);
        }
    }

    // Thread-count determinism for the scheduler is covered by
    // rust/tests/determinism.rs::task_scheduler_identical_across_thread_counts
    // (including the shared-database variant).
}
